"""(σ, μ, λ) tradeoff mini-study — the paper's core experiment on a laptop.

Sweeps protocols and mini-batch sizes through the declarative experiment
surface (``ExperimentSpec`` → ``Sweep`` → ``run_sweep``, DESIGN.md §5) on
the teacher-classification task and prints the tradeoff table the paper
plots in Figs. 6/7 (error vs time), including the μλ = constant rule.  The
runtime axis is read directly off each run's trace: ``duration=
"calibrated:base"`` schedules with the calibrated per-minibatch cost model
(core/tradeoff.py), so ``RunResult.runtime["simulated_time"]`` IS the
modeled wall-clock.  A final row shows the beyond-paper Pareto-straggler
scenario (``RunConfig.duration_model``).

    PYTHONPATH=src:. python examples/staleness_tradeoff.py
"""

from repro.config import RunConfig
from repro.experiments import ExperimentSpec, Sweep, run, run_sweep


def main():
    epochs = 8
    base = ExperimentSpec(
        run=RunConfig(minibatch=128, base_lr=0.35, ref_batch=128,
                      optimizer="sgd", seed=1),
        problem="mlp_teacher", epochs=epochs, duration="calibrated:base")
    sweep = Sweep.over(base, cases=[
        {"protocol": "hardsync", "n_learners": 1, "minibatch": 128,
         "lr_policy": "sqrt_scale"},              # the paper's baseline
        {"protocol": "hardsync", "n_learners": 30, "minibatch": 128,
         "lr_policy": "sqrt_scale"},
        {"protocol": "hardsync", "n_learners": 30, "minibatch": 4,
         "lr_policy": "sqrt_scale"},
        {"protocol": "softsync", "n_softsync": 1, "n_learners": 30,
         "minibatch": 128, "lr_policy": "staleness_inverse"},
        {"protocol": "softsync", "n_softsync": 1, "n_learners": 30,
         "minibatch": 4, "lr_policy": "staleness_inverse"},
        {"protocol": "softsync", "n_softsync": 30, "n_learners": 30,
         "minibatch": 128, "lr_policy": "staleness_inverse"},   # ≈ async
        {"protocol": "softsync", "n_softsync": 30, "n_learners": 30,
         "minibatch": 4, "lr_policy": "staleness_inverse"},
    ])

    print(f"{'config':<38} {'test err':>9} {'time(trace)':>12} "
          f"{'<sigma>':>8}")
    rows = []
    for res in run_sweep(sweep):
        cfg = res.spec["run"]
        err = res.metrics["test_error"]
        t = res.runtime["simulated_time"]
        sig = res.staleness["mean"]
        label = (f"{cfg['protocol']}(n={cfg['n_softsync']}) "
                 f"mu={cfg['minibatch']} lam={cfg['n_learners']}")
        print(f"{label:<38} {err:>9.4f} {t:>11.0f}s {sig:>8.2f}")
        rows.append((cfg["minibatch"] * cfg["n_learners"], err))

    print("\nμλ = constant rule: error grouped by μλ product")
    for prod in sorted({p for p, _ in rows}):
        errs = [e for p, e in rows if p == prod]
        print(f"  μλ={prod:<6} errors: "
              + ", ".join(f"{e:.4f}" for e in errs))

    # beyond-paper scenario: heavy-tail stragglers stretch the runtime axis
    # at (nearly) unchanged error — the staleness bound still holds.
    res = run(base.replace(
        run=base.run.replace(protocol="softsync", n_softsync=1,
                             n_learners=30, minibatch=4,
                             lr_policy="staleness_inverse",
                             duration_model="pareto", pareto_alpha=1.5,
                             pareto_scale=1.0),
        duration="config"))
    print(f"\npareto stragglers: softsync(n=1) mu=4 lam=30  "
          f"err={res.metrics['test_error']:.4f}  "
          f"<sigma>={res.staleness['mean']:.2f}  "
          f"sim_time={res.runtime['simulated_time']:.0f} "
          f"(homogeneous clock would be shorter)")


if __name__ == "__main__":
    main()
