"""(σ, μ, λ) tradeoff mini-study — the paper's core experiment on a laptop.

Sweeps protocols and mini-batch sizes with the event-driven PS simulator on
the teacher-classification task and prints the tradeoff table the paper
plots in Figs. 6/7 (error vs time), including the μλ = constant rule.

    PYTHONPATH=src python examples/staleness_tradeoff.py
"""

import numpy as np

from benchmarks.common import MLPProblem, updates_for_epochs
from repro.config import RunConfig
from repro.core import tradeoff as to
from repro.core.simulator import simulate


def main():
    prob = MLPProblem()
    hw = to.calibrate_to_baseline()
    epochs = 8
    print(f"{'config':<38} {'test err':>9} {'time(model)':>12} "
          f"{'<sigma>':>8}")
    rows = []
    for proto, n_of, mu, lam in [
        ("hardsync", lambda l: 1, 128, 1),       # the paper's baseline
        ("hardsync", lambda l: 1, 128, 30),
        ("hardsync", lambda l: 1, 4, 30),
        ("softsync", lambda l: 1, 128, 30),      # 1-softsync
        ("softsync", lambda l: 1, 4, 30),
        ("softsync", lambda l: l, 128, 30),      # λ-softsync (≈ async)
        ("softsync", lambda l: l, 4, 30),
    ]:
        n = n_of(lam)
        policy = "sqrt_scale" if proto == "hardsync" else "staleness_inverse"
        cfg = RunConfig(protocol=proto, n_softsync=n, n_learners=lam,
                        minibatch=mu, base_lr=0.35, lr_policy=policy,
                        ref_batch=128, optimizer="sgd", seed=1)
        steps = updates_for_epochs(epochs, mu, cfg.gradients_per_update,
                                   prob.task.n_train)
        res = simulate(cfg, steps=steps, grad_fn=prob.grad_fn,
                       init_params=prob.init,
                       batch_fn=prob.batch_fn_for(mu))
        err = prob.test_error(res.params)
        t = to.training_time(
            "base", proto, mu, lam, hw,
            to.WorkloadModel(dataset_size=prob.task.n_train, epochs=epochs))
        sig = res.clock_log.mean_staleness()
        label = f"{proto}(n={n}) mu={mu} lam={lam}"
        print(f"{label:<38} {err:>9.4f} {t:>11.0f}s {sig:>8.2f}")
        rows.append((mu * lam, err))

    print("\nμλ = constant rule: error grouped by μλ product")
    for prod in sorted({p for p, _ in rows}):
        errs = [e for p, e in rows if p == prod]
        print(f"  μλ={prod:<6} errors: "
              + ", ".join(f"{e:.4f}" for e in errs))


if __name__ == "__main__":
    main()
