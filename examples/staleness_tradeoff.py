"""(σ, μ, λ) tradeoff mini-study — the paper's core experiment on a laptop.

Sweeps protocols and mini-batch sizes with the compiled trace/replay PS
simulator on the teacher-classification task and prints the tradeoff table
the paper plots in Figs. 6/7 (error vs time), including the μλ = constant
rule.  The runtime axis is read directly off the trace: the schedule pass
runs with the calibrated per-minibatch cost model as its duration sampler
(core/tradeoff.minibatch_duration_sampler), so the simulated clock of the
last update IS the modeled wall-clock.  A final row shows the beyond-paper
Pareto-straggler scenario (RunConfig.duration_model).

    PYTHONPATH=src python examples/staleness_tradeoff.py
"""

import numpy as np

from benchmarks.common import MLPProblem, updates_for_epochs
from repro.config import RunConfig
from repro.core import tradeoff as to
from repro.core.engine import replay
from repro.core.trace import schedule


def main():
    prob = MLPProblem()
    hw = to.calibrate_to_baseline()
    epochs = 8
    wl = to.WorkloadModel(dataset_size=prob.task.n_train, epochs=epochs)
    print(f"{'config':<38} {'test err':>9} {'time(trace)':>12} "
          f"{'<sigma>':>8}")
    rows = []
    for proto, n_of, mu, lam in [
        ("hardsync", lambda l: 1, 128, 1),       # the paper's baseline
        ("hardsync", lambda l: 1, 128, 30),
        ("hardsync", lambda l: 1, 4, 30),
        ("softsync", lambda l: 1, 128, 30),      # 1-softsync
        ("softsync", lambda l: 1, 4, 30),
        ("softsync", lambda l: l, 128, 30),      # λ-softsync (≈ async)
        ("softsync", lambda l: l, 4, 30),
    ]:
        n = n_of(lam)
        policy = "sqrt_scale" if proto == "hardsync" else "staleness_inverse"
        cfg = RunConfig(protocol=proto, n_softsync=n, n_learners=lam,
                        minibatch=mu, base_lr=0.35, lr_policy=policy,
                        ref_batch=128, optimizer="sgd", seed=1)
        steps = updates_for_epochs(epochs, mu, cfg.gradients_per_update,
                                   prob.task.n_train)
        # schedule with the calibrated cost model; one trace per scenario
        sampler = to.minibatch_duration_sampler("base", lam, hw, wl)
        trace = schedule(cfg, steps, duration_sampler=sampler)
        res = replay(trace, cfg, grad_fn=prob.grad_fn,
                     init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
        err = prob.test_error(res.params)
        # epochs·dataset samples have been consumed when the trace ends —
        # the runtime axis is the trace's own clock (scaled per epoch).
        t = trace.simulated_time
        sig = res.clock_log.mean_staleness()
        label = f"{proto}(n={n}) mu={mu} lam={lam}"
        print(f"{label:<38} {err:>9.4f} {t:>11.0f}s {sig:>8.2f}")
        rows.append((mu * lam, err))

    print("\nμλ = constant rule: error grouped by μλ product")
    for prod in sorted({p for p, _ in rows}):
        errs = [e for p, e in rows if p == prod]
        print(f"  μλ={prod:<6} errors: "
              + ", ".join(f"{e:.4f}" for e in errs))

    # beyond-paper scenario: heavy-tail stragglers stretch the runtime axis
    # at (nearly) unchanged error — the staleness bound still holds.
    cfg = RunConfig(protocol="softsync", n_softsync=1, n_learners=30,
                    minibatch=4, base_lr=0.35,
                    lr_policy="staleness_inverse", optimizer="sgd", seed=1,
                    duration_model="pareto", pareto_alpha=1.5,
                    pareto_scale=1.0)
    steps = updates_for_epochs(epochs, 4, cfg.gradients_per_update,
                               prob.task.n_train)
    trace = schedule(cfg, steps)
    res = replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
                 batch_fn=prob.batch_fn_for(4))
    print(f"\npareto stragglers: softsync(n=1) mu=4 lam=30  "
          f"err={prob.test_error(res.params):.4f}  "
          f"<sigma>={res.clock_log.mean_staleness():.2f}  "
          f"sim_time={trace.simulated_time:.0f} "
          f"(homogeneous clock would be shorter)")


if __name__ == "__main__":
    main()
