"""End-to-end driver: train a language model with the Rudra protocol stack.

Default preset trains a ~20M-parameter qwen2-family model for 200 rounds on
CPU (minutes); ``--preset 100m`` selects a ~100M-parameter model (same code,
longer wall-clock) — the configuration used for the EXPERIMENTS.md §Repro
end-to-end run.

    PYTHONPATH=src python examples/train_lm.py [--preset 20m|100m]
        [--steps 200] [--protocol softsync --n 4 --engine fused]
"""

import argparse
import dataclasses
import os

import jax.numpy as jnp

from repro.checkpoint.io import save_checkpoint
from repro.config import ModelConfig, RunConfig
from repro.configs import get_config
from repro.models import count_params, init_model
from repro.serve.engine import generate
from repro.train.loop import train

PRESETS = {
    # ~20M: d512 8L — fast CPU demo
    "20m": dict(n_layers=8, n_units=8, d_model=512, n_heads=8, n_kv_heads=2,
                d_ff=1408, vocab_size=8192),
    # ~100M: d768 12L — the EXPERIMENTS.md end-to-end run
    "100m": dict(n_layers=12, n_units=12, d_model=768, n_heads=12,
                 n_kv_heads=4, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--protocol", default="softsync")
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--engine", default="fused",
                    choices=["sequential", "fused"])
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--out", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen2-1.5b")      # family features: GQA + QKV bias
    cfg = dataclasses.replace(base, name=f"qwen2-family-{args.preset}",
                              **PRESETS[args.preset])
    run = RunConfig(protocol=args.protocol, n_softsync=args.n,
                    n_learners=8, minibatch=max(1, args.batch // 8),
                    base_lr=args.lr, lr_policy="staleness_inverse",
                    optimizer="momentum",
                    attn_q_chunk=min(1024, args.seq),
                    attn_kv_chunk=min(1024, args.seq))

    import jax
    params = init_model(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params={count_params(params):,}")
    print(f"protocol: {run.protocol} n={run.n_softsync} engine={args.engine} "
          f"α=α₀/⟨σ⟩={run.learning_rate():.5f}")

    res = train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
                engine=args.engine, eval_every=max(1, args.steps // 10),
                params=params, log=print)
    print(f"trained {args.steps} rounds in {res.wallclock:.0f}s "
          f"({res.wallclock/args.steps*1e3:.0f} ms/round)")
    first, last = res.history[0]["ce"], res.history[-1]["ce"]
    print(f"CE: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'NOT LEARNING'})")

    os.makedirs(args.out, exist_ok=True)
    save_checkpoint(os.path.join(args.out, "final.npz"), res.params,
                    step=args.steps)
    sample = generate(cfg, run, res.params,
                      jnp.zeros((1, 8), jnp.int32), 16)
    print("sample tokens:", sample[0].tolist())
    print(f"checkpoint -> {args.out}/final.npz")


if __name__ == "__main__":
    main()
