"""Quickstart: the repo in three moves.

1. **Experiments** — the one public surface for the paper's studies: a
   declarative ``ExperimentSpec`` executed by ``run()``, grids by
   ``Sweep``/``run_sweep`` (shape-compatible cells replay as one vmapped
   device program).  Every run returns a JSON-stable ``RunResult``.
2. **Train** — the round-based softsync SPMD engine on a small LM.
3. **Serve** — greedy generation with the KV-cache engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.experiments import ExperimentSpec, Sweep, run, run_sweep
from repro.serve.engine import generate
from repro.train.loop import train


def main():
    cfg = ModelConfig(name="quickstart-lm", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=128, qk_norm=True)
    run_cfg = RunConfig(protocol="softsync", n_softsync=4, n_learners=8,
                        minibatch=2, base_lr=0.02,
                        lr_policy="staleness_inverse", optimizer="momentum",
                        attn_q_chunk=64, attn_kv_chunk=64)

    # 1a. measure mode: the paper's staleness bookkeeping for this protocol
    #     (an ExperimentSpec with no problem runs the schedule pass alone)
    meas = run(ExperimentSpec(run=run_cfg, steps=500))
    print(f"[protocol] n-softsync n={run_cfg.n_softsync}, "
          f"λ={run_cfg.n_learners}, "
          f"c={run_cfg.gradients_per_update} gradients/update")
    print(f"[staleness] ⟨σ⟩={meas.staleness['mean']:.2f} (Eq.2), "
          f"max={meas.staleness['max']:.0f} ≤ 2n={2 * run_cfg.n_softsync}")
    print(f"[lr] α = α₀/⟨σ⟩ = {run_cfg.learning_rate():.5f} (Eq. 6)")

    # 1b. an accuracy experiment + a 2-seed × 2-LR grid, batched on-device
    spec = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=4, n_learners=8,
                      minibatch=8, base_lr=0.2,
                      lr_policy="staleness_inverse", optimizer="momentum"),
        problem="mlp_teacher", steps=200)
    res = run(spec)
    print(f"[experiment] test_error={res.metrics['test_error']:.4f} "
          f"sim_time={res.runtime['simulated_time']:.1f}s "
          f"(record keys: {sorted(res.record())})")
    grid = run_sweep(Sweep.over(spec, seed=[0, 1], base_lr=[0.1, 0.2]))
    for r in grid:
        print(f"[sweep] {r.tag}: {r.metrics['test_error']:.4f}")

    # 2. train with the round-based softsync engine
    res = train(cfg, run_cfg, steps=150, batch=16, seq=64, eval_every=25,
                log=lambda s: print("[train]", s))

    # 3. serve: greedy generation with the KV-cache engine
    prompt = jnp.zeros((2, 8), jnp.int32)
    out = generate(cfg, run_cfg, res.params, prompt, max_new_tokens=12)
    print("[generate]", out.tolist())


if __name__ == "__main__":
    main()
