"""Quickstart: train a small LM with the paper's n-softsync protocol and
staleness-modulated learning rate, then generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.core import simulate_measure
from repro.serve.engine import generate
from repro.train.loop import train


def main():
    cfg = ModelConfig(name="quickstart-lm", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=128, qk_norm=True)
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=8,
                    minibatch=2, base_lr=0.02, lr_policy="staleness_inverse",
                    optimizer="momentum", attn_q_chunk=64, attn_kv_chunk=64)

    # 1. the paper's staleness bookkeeping for this configuration
    meas = simulate_measure(run, steps=500)
    print(f"[protocol] n-softsync n={run.n_softsync}, λ={run.n_learners}, "
          f"c={run.gradients_per_update} gradients/update")
    print(f"[staleness] ⟨σ⟩={meas.clock_log.mean_staleness():.2f} "
          f"(Eq.2), max={meas.clock_log.all_staleness_values().max():.0f} "
          f"≤ 2n={2 * run.n_softsync}")
    print(f"[lr] α = α₀/⟨σ⟩ = {run.learning_rate():.5f} (Eq. 6)")

    # 2. train with the round-based softsync engine
    res = train(cfg, run, steps=150, batch=16, seq=64, eval_every=25,
                log=lambda s: print("[train]", s))

    # 3. serve: greedy generation with the KV-cache engine
    prompt = jnp.zeros((2, 8), jnp.int32)
    out = generate(cfg, run, res.params, prompt, max_new_tokens=12)
    print("[generate]", out.tolist())


if __name__ == "__main__":
    main()
