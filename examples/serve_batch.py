"""Batched serving demo: prefill a batch of prompts, then decode with the
per-family cache engine — including a sliding-window model and an
attention-free RWKV model (constant-state long-context decode).

    PYTHONPATH=src python examples/serve_batch.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.configs import get_smoke
from repro.models import count_params, init_caches, init_model
from repro.serve.engine import generate, init_serve_state, prefill, serve_step

RUN = RunConfig(attn_q_chunk=64, attn_kv_chunk=64)


def demo(cfg: ModelConfig, label: str, batch: int = 4, prompt_len: int = 16,
         new_tokens: int = 24):
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, RUN, params, prompt, new_tokens)
    dt = time.perf_counter() - t0
    toks = batch * (prompt_len + new_tokens)
    print(f"[{label:<22}] params={count_params(params):>10,} "
          f"batch={batch} {toks/dt:7.0f} tok/s  out[0][:8]={out[0][:8].tolist()}")


def continuous_batching_demo():
    from repro.serve.scheduler import ContinuousBatchingEngine
    cfg = get_smoke("qwen2-1.5b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, RUN, params, max_batch=4,
                                   max_len=64)
    rids = [eng.submit(list(range(2 + i, 10 + i)), max_new_tokens=8)
            for i in range(6)]           # 6 requests into 4 slots
    t0 = time.perf_counter()
    done = eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(done[r].generated) for r in rids)
    print(f"[continuous batching    ] 6 reqs / 4 slots, {toks} new tokens "
          f"in {dt:.1f}s — staggered depths, slots reused")


def main():
    # dense GQA model
    demo(get_smoke("qwen2-1.5b"), "dense (qwen2 family)")
    # sliding-window variant: ring-buffer cache smaller than the context
    swa = dataclasses.replace(get_smoke("qwen3-14b"), sliding_window=16)
    demo(swa, "sliding-window dense")
    # attention-free: constant-size recurrent state
    demo(get_smoke("rwkv6-7b"), "rwkv6 (attn-free)")
    # hybrid: shared-attention + mamba caches in one stack
    demo(get_smoke("zamba2-7b"), "zamba2 (hybrid)")
    # MoE decode: capacity-dispatch path with S=1
    demo(get_smoke("llama4-maverick-400b-a17b"), "llama4 (moe top-1)")
    # continuous batching: requests enter/leave the batch at any step
    continuous_batching_demo()


if __name__ == "__main__":
    main()
