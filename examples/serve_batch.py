"""Serving demos, now anchored on the train-while-serve publication
subsystem (DESIGN.md §14): a fleet of replicas answers live traffic from
staleness-bounded ring snapshots WHILE the run trains — then the decode
engines (batched generate + continuous batching) that would sit behind
each replica in a real deployment.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax

from repro.config import ModelConfig, RunConfig
from repro.configs import get_smoke
from repro.models import count_params, init_model
from repro.serve.engine import generate
from repro.serve.fleet import FleetConfig
from repro.serve.publication import PublicationPolicy

RUN = RunConfig(attn_q_chunk=64, attn_kv_chunk=64)


def train_while_serve_demo():
    """End-to-end fleet path: RunConfig.serving → schedule traffic +
    refreshes → replay with the serving lane → per-policy summary.  A
    replica crash mid-run shows the budget holding through churn."""
    from repro.experiments import ExperimentSpec, run

    print("== train-while-serve: publication from the PS ring ==")
    for policy in (PublicationPolicy(kind="staleness", max_version_lag=2),
                   PublicationPolicy(kind="every_n", every=16),
                   PublicationPolicy(kind="on_demand")):
        fleet = FleetConfig(replicas=2, policy=policy, request_rate=4.0,
                            request_samples=32,
                            membership=((4.0, 1, "crash"), (9.0, 1, "join")))
        spec = ExperimentSpec(
            run=RunConfig(protocol="softsync", n_softsync=1, n_learners=8,
                          minibatch=8, base_lr=0.05,
                          lr_policy="staleness_inverse",
                          optimizer="momentum", serving=fleet),
            problem="mlp_teacher", steps=96)
        s = run(spec).runtime["serving"]
        print(f"[{str(policy):<10}] {s['n_served']:>3} requests served by "
              f"{fleet.replicas} replicas (1 crashes mid-run)  "
              f"acc={s['accuracy']:.3f} lag<={s['staleness_max']} "
              f"(mean {s['staleness_mean']:.2f})  "
              f"p99={s['latency_p99_s'] * 1e3:.0f}ms  "
              f"refreshes={s['n_refreshes']}")


def decode_demo(cfg: ModelConfig, label: str, batch: int = 4,
                prompt_len: int = 16, new_tokens: int = 24):
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, RUN, params, prompt, new_tokens)
    dt = time.perf_counter() - t0
    toks = batch * (prompt_len + new_tokens)
    print(f"[{label:<22}] params={count_params(params):>10,} "
          f"batch={batch} {toks/dt:7.0f} tok/s  out[0][:8]={out[0][:8].tolist()}")


def continuous_batching_demo():
    from repro.serve.scheduler import ContinuousBatchingEngine
    cfg = get_smoke("qwen2-1.5b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, RUN, params, max_batch=4,
                                   max_len=64)
    rids = [eng.submit(list(range(2 + i, 10 + i)), max_new_tokens=8)
            for i in range(6)]           # 6 requests into 4 slots
    t0 = time.perf_counter()
    done = eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(done[r].generated) for r in rids)
    print(f"[continuous batching    ] 6 reqs / 4 slots, {toks} new tokens "
          f"in {dt:.1f}s — staggered depths, slots reused")


def main():
    # the fleet path: publication policies under live traffic + churn
    train_while_serve_demo()
    # the decode engine a replica would run: batched greedy generation
    print("== decode engines behind a replica ==")
    decode_demo(get_smoke("qwen2-1.5b"), "dense (qwen2 family)")
    # attention-free: constant-size recurrent state
    decode_demo(get_smoke("rwkv6-7b"), "rwkv6 (attn-free)")
    # continuous batching: requests enter/leave the batch at any step
    continuous_batching_demo()


if __name__ == "__main__":
    main()
