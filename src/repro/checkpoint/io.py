"""Pytree checkpointing without external dependencies (npz-based).

Flattens a pytree of arrays to ``key.path/like/this -> array`` entries in a
compressed ``.npz``, plus a tiny JSON manifest for non-array leaves (step
counters, RNG keys).  Restore rebuilds against a template pytree so dtypes
and structure are validated on load.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float16"):
            arr = arr.astype(np.float32)   # fp32 master copy on disk
        flat[key] = arr
    return flat


def save_checkpoint(path: str, state, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(state)
    np.savez_compressed(path, **flat)
    with open(path + ".meta.json", "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)


def load_checkpoint(path: str, template) -> Tuple[Any, int]:
    """Restore into the structure of ``template``; returns (state, step)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    meta_path = path.replace(".npz", "") + ".npz.meta.json"
    if not os.path.exists(meta_path):
        meta_path = path + ".meta.json"
    step = 0
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            step = json.load(f).get("step", 0)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = jax.numpy.asarray(data[key])
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
