"""Block zoo: one forward/decode/init triple per block type.

A model is a repeating *unit* (``ModelConfig.block_pattern``) of these blocks
stacked ``n_units`` times.  All blocks are pre-norm residual.  ``shared``
carries the weight-shared attention block used by zamba2 (BLOCK_SHARED_ATTN);
it is a closure constant under the layer scan, not a scanned parameter.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import config as C
from repro.config import ModelConfig, RunConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import init_rms_norm, init_swiglu, rms_norm, swiglu

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_fraction": 0.0}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_block(block_type: str, key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    M = cfg.d_model
    if block_type == C.BLOCK_ATTN:
        return {"norm1": init_rms_norm(M, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "norm2": init_rms_norm(M, dtype),
                "mlp": init_swiglu(ks[1], M, cfg.d_ff, dtype)}
    if block_type == C.BLOCK_MOE:
        return {"norm1": init_rms_norm(M, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "norm2": init_rms_norm(M, dtype),
                "moe": moe_mod.init_moe(ks[1], cfg, dtype)}
    if block_type == C.BLOCK_MOE_DENSE_RESIDUAL:
        return {"norm1": init_rms_norm(M, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "norm2": init_rms_norm(M, dtype),
                "mlp": init_swiglu(ks[1], M, cfg.d_ff, dtype),
                "moe": moe_mod.init_moe(ks[2], cfg, dtype)}
    if block_type == C.BLOCK_MAMBA:
        return {"norm1": init_rms_norm(M, dtype),
                "mamba": ssm_mod.init_mamba(ks[0], cfg, dtype)}
    if block_type == C.BLOCK_RWKV:
        return {"norm1": init_rms_norm(M, dtype),
                "norm2": init_rms_norm(M, dtype),
                "rwkv": rwkv_mod.init_rwkv(ks[0], cfg, dtype)}
    if block_type == C.BLOCK_SHARED_ATTN:
        # per-unit parameters only: the norms.  Attention/MLP weights live in
        # the shared trunk (init_shared_block).
        return {"norm1": init_rms_norm(M, dtype),
                "norm2": init_rms_norm(M, dtype)}
    raise ValueError(block_type)


def init_shared_block(key, cfg: ModelConfig, dtype) -> Optional[dict]:
    if C.BLOCK_SHARED_ATTN not in cfg.block_pattern:
        return None
    k1, k2 = jax.random.split(key)
    return {"attn": attn.init_attention(k1, cfg, dtype),
            "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)}


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def block_forward(block_type: str, cfg: ModelConfig, run: RunConfig,
                  p: dict, shared: Optional[dict], x: jax.Array,
                  positions: jax.Array) -> Tuple[jax.Array, dict]:
    if block_type == C.BLOCK_ATTN:
        h = attn.attention_forward(cfg, run, p["attn"],
                                   rms_norm(x, p["norm1"]["scale"],
                                            cfg.norm_eps), positions)
        x = x + h
        x = x + swiglu(rms_norm(x, p["norm2"]["scale"], cfg.norm_eps),
                       p["mlp"])
        return x, ZERO_AUX
    if block_type == C.BLOCK_MOE:
        h = attn.attention_forward(cfg, run, p["attn"],
                                   rms_norm(x, p["norm1"]["scale"],
                                            cfg.norm_eps), positions)
        x = x + h
        mo, aux = moe_mod.moe_forward(
            cfg, p["moe"], rms_norm(x, p["norm2"]["scale"], cfg.norm_eps))
        return x + mo, aux
    if block_type == C.BLOCK_MOE_DENSE_RESIDUAL:
        h = attn.attention_forward(cfg, run, p["attn"],
                                   rms_norm(x, p["norm1"]["scale"],
                                            cfg.norm_eps), positions)
        x = x + h
        xn = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        mo, aux = moe_mod.moe_forward(cfg, p["moe"], xn)
        return x + mo + swiglu(xn, p["mlp"]), aux
    if block_type == C.BLOCK_MAMBA:
        h = ssm_mod.mamba_forward(cfg, p["mamba"],
                                  rms_norm(x, p["norm1"]["scale"],
                                           cfg.norm_eps),
                                  use_pallas=run.use_pallas,
                                  unroll=run.unroll)
        return x + h, ZERO_AUX
    if block_type == C.BLOCK_RWKV:
        h = rwkv_mod.rwkv_forward(cfg, p["rwkv"],
                                  rms_norm(x, p["norm1"]["scale"],
                                           cfg.norm_eps),
                                  use_pallas=run.use_pallas,
                                  unroll=run.unroll)
        x = x + h
        h = rwkv_mod.rwkv_channel_mix(cfg, p["rwkv"],
                                      rms_norm(x, p["norm2"]["scale"],
                                               cfg.norm_eps))
        return x + h, ZERO_AUX
    if block_type == C.BLOCK_SHARED_ATTN:
        h = attn.attention_forward(cfg, run, shared["attn"],
                                   rms_norm(x, p["norm1"]["scale"],
                                            cfg.norm_eps), positions)
        x = x + h
        x = x + swiglu(rms_norm(x, p["norm2"]["scale"], cfg.norm_eps),
                       shared["mlp"])
        return x, ZERO_AUX
    raise ValueError(block_type)


# ---------------------------------------------------------------------------
# caches & decode
# ---------------------------------------------------------------------------
def init_block_cache(block_type: str, cfg: ModelConfig, batch: int,
                     max_len: int, dtype) -> Dict[str, Any]:
    if block_type in (C.BLOCK_ATTN, C.BLOCK_MOE, C.BLOCK_MOE_DENSE_RESIDUAL,
                      C.BLOCK_SHARED_ATTN):
        return attn.init_kv_cache(cfg, batch, max_len, dtype)
    if block_type == C.BLOCK_MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if block_type == C.BLOCK_RWKV:
        return rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(block_type)


def block_decode(block_type: str, cfg: ModelConfig, run: RunConfig,
                 p: dict, shared: Optional[dict], x: jax.Array,
                 position: jax.Array, cache: dict
                 ) -> Tuple[jax.Array, dict, dict]:
    if block_type == C.BLOCK_ATTN:
        h, cache = attn.attention_decode(
            cfg, run, p["attn"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), position, cache)
        x = x + h
        x = x + swiglu(rms_norm(x, p["norm2"]["scale"], cfg.norm_eps),
                       p["mlp"])
        return x, cache, ZERO_AUX
    if block_type == C.BLOCK_MOE:
        h, cache = attn.attention_decode(
            cfg, run, p["attn"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), position, cache)
        x = x + h
        mo, aux = moe_mod.moe_forward(
            cfg, p["moe"], rms_norm(x, p["norm2"]["scale"], cfg.norm_eps))
        return x + mo, cache, aux
    if block_type == C.BLOCK_MOE_DENSE_RESIDUAL:
        h, cache = attn.attention_decode(
            cfg, run, p["attn"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), position, cache)
        x = x + h
        xn = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        mo, aux = moe_mod.moe_forward(cfg, p["moe"], xn)
        return x + mo + swiglu(xn, p["mlp"]), cache, aux
    if block_type == C.BLOCK_MAMBA:
        h, cache = ssm_mod.mamba_decode(
            cfg, p["mamba"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), cache)
        return x + h, cache, ZERO_AUX
    if block_type == C.BLOCK_RWKV:
        h, cache = rwkv_mod.rwkv_decode_time_mix(
            cfg, p["rwkv"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), cache)
        x = x + h
        h, cache = rwkv_mod.rwkv_decode_channel_mix(
            cfg, p["rwkv"],
            rms_norm(x, p["norm2"]["scale"], cfg.norm_eps), cache)
        return x + h, cache, ZERO_AUX
    if block_type == C.BLOCK_SHARED_ATTN:
        h, cache = attn.attention_decode(
            cfg, run, shared["attn"],
            rms_norm(x, p["norm1"]["scale"], cfg.norm_eps), position, cache)
        x = x + h
        x = x + swiglu(rms_norm(x, p["norm2"]["scale"], cfg.norm_eps),
                       shared["mlp"])
        return x, cache, ZERO_AUX
    raise ValueError(block_type)
