"""RWKV6 ("Finch") block — attention-free token mixing with data-dependent
per-channel decay (arXiv:2404.05892).

Recurrence per head (key dim P_k = value dim P_v = P):

    S_t   = diag(exp(w_t)) · S_{t-1} + k_t ⊗ v_t      (w_t < 0, data-dependent)
    out_t = r_t · (S_{t-1} + diag(u) · (k_t ⊗ v_t))

The XLA fallback runs the recurrence with ``jax.lax.scan`` over time (exact,
memory O(state)); the Pallas kernel (``repro.kernels.wkv6``) computes the
same thing chunked in VMEM.  Decode is a single recurrence step — RWKV serves
long_500k with a constant-size state, which is the whole point of the family.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.layers import rms_norm, sqrelu_ffn, init_sqrelu_ffn

_DECAY_LORA = 64


def init_rwkv(key, cfg: ModelConfig, dtype) -> dict:
    M = cfg.d_model
    H, P = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    s = float(1.0 / np.sqrt(M))
    p = {
        # token-shift interpolation coefficients (static per-channel mix)
        "mu_r": jnp.full((M,), 0.5, dtype),
        "mu_k": jnp.full((M,), 0.5, dtype),
        "mu_v": jnp.full((M,), 0.5, dtype),
        "mu_w": jnp.full((M,), 0.5, dtype),
        "mu_g": jnp.full((M,), 0.5, dtype),
        "w_r": jax.random.normal(ks[0], (M, M), dtype) * s,
        "w_k": jax.random.normal(ks[1], (M, M), dtype) * s,
        "w_v": jax.random.normal(ks[2], (M, M), dtype) * s,
        "w_g": jax.random.normal(ks[3], (M, M), dtype) * s,
        "w_o": jax.random.normal(ks[4], (M, M), dtype) * s,
        # data-dependent decay LoRA:  w = w0 + tanh(x@A)@B
        "decay_w0": jnp.full((M,), -6.0, jnp.float32),
        "decay_A": jax.random.normal(ks[5], (M, _DECAY_LORA), dtype) * s,
        "decay_B": jax.random.normal(ks[6], (_DECAY_LORA, M), dtype)
        * float(1.0 / np.sqrt(_DECAY_LORA)),
        "bonus_u": jax.random.normal(ks[7], (H, P), jnp.float32) * 0.1,
        "ln_x_scale": jnp.ones((M,), dtype),     # per-head group norm
        # channel mix (d_ff from the config; RWKV default is 3.5–4×M)
        "mu_ck": jnp.full((M,), 0.5, dtype),
        "ffn": init_sqrelu_ffn(ks[8], M, cfg.d_ff, dtype),
    }
    return p


def _token_shift(x: jax.Array, last: jax.Array = None) -> jax.Array:
    """Previous-token tensor.  x: (B, S, M); last: (B, M) decode carry."""
    if last is None:
        last = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu


def wkv_recurrent(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, init_state=None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Exact recurrence via scan over time.

    r/k/v: (B, S, H, P); w: (B, S, H, P) log-decay (< 0); u: (H, P) bonus.
    Returns (out (B,S,H,P) fp32, final state (B,H,P,P))."""
    B, S, H, P = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)
    if init_state is None:
        init_state = jnp.zeros((B, H, P, P), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                       # (B,H,P)
        kv = jnp.einsum("bhp,bhq->bhpq", kt, vt)   # key ⊗ value
        out = jnp.einsum("bhp,bhpq->bhq", rt, state + u[None, :, :, None] * kv)
        state = jnp.exp(wt)[..., None] * state + kv
        return state, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    final, out = jax.lax.scan(step, init_state, xs)
    return out.transpose(1, 0, 2, 3), final


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, chunk: int = 32, init_state=None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV (python loop over chunks — the roofline-probe / unrolled
    path; same algorithm as ``repro.kernels.wkv6``).  All exponent arguments
    are ≤ 0 so the math is stable by construction."""
    B, S, H, P = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        rf, kf, vf = jnp.pad(rf, zp), jnp.pad(kf, zp), jnp.pad(vf, zp)
        wf = jnp.pad(wf, zp)
    state = (jnp.zeros((B, H, P, P), jnp.float32) if init_state is None
             else init_state)
    tri = (jnp.arange(Q)[:, None] > jnp.arange(Q)[None, :])
    outs = []
    for ci in range(nc):
        sl = slice(ci * Q, (ci + 1) * Q)
        rc, kc, vc, wc = rf[:, sl], kf[:, sl], vf[:, sl], wf[:, sl]
        cum = jnp.cumsum(wc, axis=1)                   # (B,Q,H,P) inclusive
        cum_excl = cum - wc
        e_in = jnp.exp(cum_excl)
        y_inter = jnp.einsum("bihp,bhpq->bihq", rc * e_in, state)
        diff = cum_excl[:, :, None] - cum[:, None]     # (B,Q,Q,H,P) ≤ 0 (j<i)
        E = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("bihp,bjhp,bijhp->bijh", rc, kc, E)
        y_intra = jnp.einsum("bijh,bjhq->bihq", A, vc)
        y_diag = jnp.einsum("bihp,bihp->bih", rc * u[None, None], kc
                            )[..., None] * vc
        outs.append(y_inter + y_intra + y_diag)
        decay_out = jnp.exp(cum[:, -1])                # (B,H,P)
        kw = kc * jnp.exp(cum[:, -1][:, None] - cum)
        state = (decay_out[..., None] * state
                 + jnp.einsum("bjhp,bjhq->bhpq", kw, vc))
    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out, state


def _time_mix(cfg: ModelConfig, p: dict, x: jax.Array, shifted: jax.Array,
              state=None, use_pallas: bool = False, unroll: bool = False):
    B, S, M = x.shape
    H, P = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    xr = _mix(x, shifted, p["mu_r"])
    xk = _mix(x, shifted, p["mu_k"])
    xv = _mix(x, shifted, p["mu_v"])
    xw = _mix(x, shifted, p["mu_w"])
    xg = _mix(x, shifted, p["mu_g"])
    r = (xr @ p["w_r"]).reshape(B, S, H, P)
    k = (xk @ p["w_k"]).reshape(B, S, H, P)
    v = (xv @ p["w_v"]).reshape(B, S, H, P)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32))
    lora = jnp.tanh((xw @ p["decay_A"]).astype(jnp.float32))
    wdec = p["decay_w0"] + lora @ p["decay_B"].astype(jnp.float32)
    # log decay: -exp(w)  in (-inf, 0)
    w = -jnp.exp(wdec).reshape(B, S, H, P)
    if use_pallas:
        from repro.kernels import ops as kops
        out, new_state = kops.wkv6(r, k, v, w, p["bonus_u"],
                                   init_state=state)
    elif unroll and S > 1:
        # roofline probe: cap the python-loop trip count at 128 chunks; the
        # intra-term overcount vs the kernel's chunk-32 is <5% of block FLOPs
        out, new_state = wkv_chunked(r, k, v, w, p["bonus_u"],
                                     chunk=max(32, S // 128),
                                     init_state=state)
    else:
        out, new_state = wkv_recurrent(r, k, v, w, p["bonus_u"],
                                       init_state=state)
    out = out.reshape(B, S, M)
    out = rms_norm(out.astype(x.dtype), p["ln_x_scale"], cfg.norm_eps)
    out = (out.astype(jnp.float32) * g).astype(x.dtype)
    return out @ p["w_o"], new_state


def rwkv_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                 use_pallas: bool = False, unroll: bool = False) -> jax.Array:
    """Full-sequence RWKV6 block (time mix + channel mix, pre-norm residuals
    are applied by the caller; this returns the time-mix output only —
    channel-mix is exposed separately so blocks.py can place both)."""
    shifted = _token_shift(x)
    out, _ = _time_mix(cfg, p, x, shifted, use_pallas=use_pallas,
                       unroll=unroll)
    return out


def rwkv_channel_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                     last: jax.Array = None) -> jax.Array:
    shifted = _token_shift(x, last)
    xk = _mix(x, shifted, p["mu_ck"])
    return sqrelu_ffn(xk, p["ffn"])


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, P, M = cfg.rwkv_n_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "wkv": jnp.zeros((batch, H, P, P), jnp.float32),
        "shift_tm": jnp.zeros((batch, M), dtype),
        "shift_cm": jnp.zeros((batch, M), dtype),
    }


def rwkv_decode_time_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                         cache: dict) -> Tuple[jax.Array, dict]:
    """x: (B, 1, M)."""
    shifted = cache["shift_tm"][:, None]
    out, new_state = _time_mix(cfg, p, x, shifted, state=cache["wkv"])
    new_cache = dict(cache)
    new_cache["wkv"] = new_state
    new_cache["shift_tm"] = x[:, 0]
    return out, new_cache


def rwkv_decode_channel_mix(cfg: ModelConfig, p: dict, x: jax.Array,
                            cache: dict) -> Tuple[jax.Array, dict]:
    out = rwkv_channel_mix(cfg, p, x, last=cache["shift_cm"])
    new_cache = dict(cache)
    new_cache["shift_cm"] = x[:, 0]
    return out, new_cache
