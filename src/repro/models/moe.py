"""Mixture-of-Experts with capacity-based scatter dispatch (GShard-style).

TPU adaptation notes (DESIGN.md §9): experts are sharded over the ``model``
mesh axis (expert parallelism); tokens are grouped so that the per-group
dispatch buffers stay small and the dispatch crossing the data→model axes
lowers to all-to-all-style collectives under GSPMD.

We deliberately avoid the one-hot dispatch *einsum* of the original GShard
formulation: its (groups, tokens, experts, capacity) tensor is ~10 TB at our
train_4k shape.  Instead tokens are scattered into per-expert capacity
buffers and gathered back (Megablocks-style dense-capacity variant), which
keeps memory O(tokens · d_model) while remaining fully static-shaped.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    M = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = float(1.0 / np.sqrt(M)), float(1.0 / np.sqrt(F))
    return {
        "w_router": jax.random.normal(ks[0], (M, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (E, M, F), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (E, M, F), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (E, F, M), dtype) * s_out,
    }


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    E, k = cfg.n_experts, cfg.top_k
    c = int(math.ceil(tokens_per_group * k / E * cfg.capacity_factor))
    return max(c, 1)


def _dispatch_one_group(tokens, fidx, pos, keep, E, C):
    """tokens (T*k, M) already gathered per slot; scatter to (E, C, M)."""
    M = tokens.shape[-1]
    buf = jnp.zeros((E, C, tokens.shape[-1]), tokens.dtype)
    contrib = tokens * keep[:, None].astype(tokens.dtype)
    return buf.at[fidx, pos].add(contrib)


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array
                ) -> Tuple[jax.Array, dict]:
    """x: (B, S, M).  Returns (out (B,S,M), aux dict with losses/metrics)."""
    B, S, M = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # Group tokens.  Groups must (a) hold ≥ E tokens so the capacity stays
    # integral with bounded waste, and (b) stay ≤ GROUP_T tokens and aligned
    # with the sequence sharding so the slot bookkeeping (cumsum over the
    # group) and the scatter stay shard-local — long sequences are split into
    # (B · S/GROUP_T) groups instead of one 32k-token group per batch row
    # (EXPERIMENTS.md §Perf iteration A1).  Decode batches (S == 1) fold into
    # one group.
    GROUP_T = 2048
    if S >= E:
        T = min(S, GROUP_T)
        while S % T:
            T //= 2
        G = B * (S // T)
        xg = x.reshape(G, T, M)
    else:
        G, T = 1, B * S
        xg = x.reshape(1, T, M)
    C = capacity(T, cfg)

    logits = jnp.einsum("gtm,me->gte", xg.astype(jnp.float32), p["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, T, E)
    gate_w, idx = jax.lax.top_k(probs, k)                      # (G, T, k)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # ---- aux losses ------------------------------------------------------
    # Switch-style load balance: E * Σ_e fraction_e · prob_e
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    one_hot_top1 = jax.nn.one_hot(idx[..., 0], E)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- slot assignment: position of each routed token within its expert -
    fidx = idx.reshape(G, T * k)                               # (G, T*k)
    onehot = jax.nn.one_hot(fidx, E, dtype=jnp.int32)          # (G, T*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                       # (G, T*k, E)
    pos = jnp.take_along_axis(pos, fidx[..., None], axis=-1)[..., 0]
    keep = pos < C                                             # capacity drop

    # ---- dispatch --------------------------------------------------------
    slot_tokens = jnp.repeat(xg, k, axis=1)                    # (G, T*k, M)
    buf = jax.vmap(_dispatch_one_group, in_axes=(0, 0, 0, 0, None, None))(
        slot_tokens, fidx, pos, keep, E, C)                    # (G, E, C, M)

    # ---- expert compute (SwiGLU) ------------------------------------------
    g = jnp.einsum("gecm,emf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecm,emf->gecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    eo = jnp.einsum("gecf,efm->gecm", h, p["w_down"])          # (G, E, C, M)

    # ---- combine -----------------------------------------------------------
    gathered = jax.vmap(lambda o, f, q: o[f, q])(eo, fidx, pos)  # (G,T*k,M)
    w = (gate_w.reshape(G, T * k) * keep).astype(gathered.dtype)
    out = (gathered * w[..., None]).reshape(G, T, k, M).sum(axis=2)
    out = out.reshape(B, S, M)

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "lb_loss": lb_loss * cfg.load_balance_loss,
        "z_loss": z_loss * cfg.router_z_loss,
        "dropped_fraction": dropped,
    }
    return out, aux
