"""Mamba2 (SSD) block: chunked state-space scan + single-step decode.

Forward path follows the "SSD minimal" formulation of the Mamba2 paper:
within a chunk the recurrence is computed as a (masked, decay-weighted)
attention-like quadratic form; across chunks a small state (H, N, P) is
carried by ``jax.lax.scan``, so memory stays O(chunk) in sequence length and
the context can grow to 524k tokens (long_500k).

The Pallas kernel in ``repro.kernels.ssm_scan`` implements the same chunked
algorithm tiled for VMEM; ``repro.kernels.ref`` holds the step-by-step
recurrent oracle both are tested against.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    """Parameter leaves are split on head boundaries so tensor parallelism
    shards cleanly (DESIGN.md §9): w_z/w_x/w_dt and the per-head scalars
    shard channel/head dims over `model`; the small shared B/C projection and
    its conv stay replicated (B/C are shared across heads, n_groups = 1)."""
    M = cfg.d_model
    Din = cfg.ssm_d_inner
    H = cfg.ssm_n_heads
    N = cfg.ssm_state
    ks = jax.random.split(key, 8)
    s = float(1.0 / np.sqrt(M))
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt = np.exp(np.random.RandomState(0).uniform(
        np.log(1e-3), np.log(1e-1), size=(H,))).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "w_z": jax.random.normal(ks[0], (M, Din), dtype) * s,
        "w_x": jax.random.normal(ks[1], (M, Din), dtype) * s,
        "w_bc": jax.random.normal(ks[2], (M, 2 * N), dtype) * s,
        "w_dt": jax.random.normal(ks[3], (M, H), dtype) * s,
        "conv_x": jax.random.normal(ks[4], (cfg.ssm_conv, Din), dtype)
        * float(1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_bc": jax.random.normal(ks[5], (cfg.ssm_conv, 2 * N), dtype)
        * float(1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_bx": jnp.zeros((Din,), dtype),
        "conv_bbc": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.asarray(np.log(np.arange(1, H + 1, dtype=np.float32))),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias),
        "norm_scale": jnp.ones((Din,), dtype),
        "w_out": jax.random.normal(ks[6], (Din, M), dtype)
        * float(1.0 / np.sqrt(Din)),
    }


# ---------------------------------------------------------------------------
# Chunked SSD scan
# ---------------------------------------------------------------------------
def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q).  Returns (..., Q, Q) with out[i, j] = sum_{t=j+1..i} a_t
    for i >= j, -inf below the diagonal (i < j)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j+1..i}
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, init_state=None,
                unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective-state-space scan.

    x: (Bt, S, H, P) inputs (already multiplied by dt)
    a: (Bt, S, H)    per-step log decay (= dt * A, negative)
    B: (Bt, S, N)    input projection  (n_groups = 1, shared across heads)
    C: (Bt, S, N)    output projection
    Returns (y (Bt,S,H,P), final_state (Bt,H,N,P)).

    Recurrence: S_t = exp(a_t)·S_{t-1} + B_t ⊗ x_t ;  y_t = C_t · S_t.
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xb = x.reshape(Bt, nc, Q, H, P).transpose(1, 0, 3, 2, 4)   # (nc,Bt,H,Q,P)
    ab = a.reshape(Bt, nc, Q, H).transpose(1, 0, 3, 2)         # (nc,Bt,H,Q)
    Bb = B.reshape(Bt, nc, Q, N).transpose(1, 0, 2, 3)         # (nc,Bt,Q,N)
    Cb = C.reshape(Bt, nc, Q, N).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((Bt, H, N, P), jnp.float32)

    def chunk_step(state, inp):
        xc, ac, Bc, Cc = inp
        # xc (Bt,H,Q,P) fp32; ac (Bt,H,Q); Bc/Cc (Bt,Q,N)
        xc = xc.astype(jnp.float32)
        Bc = Bc.astype(jnp.float32)
        Cc = Cc.astype(jnp.float32)
        cum = jnp.cumsum(ac, axis=-1)                          # (Bt,H,Q)
        seg = _segsum(ac)                                      # (Bt,H,Q,Q)
        decay = jnp.exp(seg)                                   # lower-tri
        # intra-chunk: y_i += Σ_{j<=i} C_i·B_j exp(Σ_{j+1..i} a) x_j
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)            # (Bt,Q,Q)
        y_intra = jnp.einsum("bij,bhij,bhjp->bhip",
                             scores, decay, xc)
        # inter-chunk: y_i += C_i · (exp(cum_i) * state)
        y_inter = jnp.einsum("bin,bhnp,bhi->bhip",
                             Cc, state, jnp.exp(cum))
        y = y_intra + y_inter                                  # (Bt,H,Q,P)
        # state update: S' = exp(total) S + Σ_j exp(total - cum_j) B_j x_j
        total = cum[..., -1]                                   # (Bt,H)
        w = jnp.exp(total[..., None] - cum)                    # (Bt,H,Q)
        state_new = (jnp.exp(total)[..., None, None] * state
                     + jnp.einsum("bjn,bhj,bhjp->bhnp", Bc, w, xc))
        return state_new, y.transpose(0, 2, 1, 3)              # (Bt,Q,H,P)

    if unroll:
        state = init_state
        ys = []
        for ci in range(nc):
            state, yc = chunk_step(state, (xb[ci], ab[ci], Bb[ci], Cb[ci]))
            ys.append(yc)
        final_state, yb = state, jnp.stack(ys)
    else:
        final_state, yb = jax.lax.scan(chunk_step, init_state,
                                       (xb, ab, Bb, Cb))
    y = yb.transpose(1, 0, 2, 3, 4).reshape(Bt, nc * Q, H, P)
    return y[:, :S].astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, a: jax.Array,
                    B: jax.Array, C: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence.  state (Bt,H,N,P); x (Bt,H,P); a (Bt,H);
    B/C (Bt,N).  Returns (y (Bt,H,P), new state)."""
    xf = x.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    state = (jnp.exp(a)[..., None, None] * state
             + jnp.einsum("bn,bhp->bhnp", Bf, xf))
    y = jnp.einsum("bn,bhnp->bhp", Cf, state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------
def _causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  xc (B, S, D); w (K, D).  Returns output and
    the trailing K-1 inputs (decode cache)."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((xc.shape[0], K - 1, xc.shape[-1]), xc.dtype)
    xin = jnp.concatenate([history, xc], axis=1)
    out = sum(xin[:, i:i + xc.shape[1]] * w[i] for i in range(K))
    out = jax.nn.silu((out + b).astype(jnp.float32)).astype(xc.dtype)
    new_hist = xin[:, -(K - 1):]
    return out, new_hist


def _project(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, M) -> z (B,S,Din), xs (B,S,Din), BC (B,S,2N), dt (B,S,H)."""
    z = jnp.einsum("bsm,md->bsd", x, p["w_z"])
    xs = jnp.einsum("bsm,md->bsd", x, p["w_x"])
    bc = jnp.einsum("bsm,md->bsd", x, p["w_bc"])
    dt = jnp.einsum("bsm,mh->bsh", x, p["w_dt"])
    return z, xs, bc, dt


def mamba_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                  use_pallas: bool = False, unroll: bool = False
                  ) -> jax.Array:
    """Full-sequence Mamba2 block.  x: (B, S, M) -> (B, S, M)."""
    Bt, S, M = x.shape
    Din, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                    cfg.ssm_head_dim)
    z, xs, bc, dt = _project(cfg, p, x)
    xs, _ = _causal_conv(xs, p["conv_x"], p["conv_bx"])
    bc, _ = _causal_conv(bc, p["conv_bc"], p["conv_bbc"])
    xs = xs.reshape(Bt, S, H, P)
    Bm = bc[..., :N]
    Cm = bc[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,) < 0
    a = dt * A                                                    # log decay
    xdt = xs.astype(jnp.float32) * dt[..., None]
    if use_pallas:
        from repro.kernels import ops as kops
        y, _ = kops.ssm_scan(xdt, a, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xdt, a, Bm, Cm, cfg.ssm_chunk, unroll=unroll)
    y = y.astype(x.dtype) + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bt, S, Din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_scale"], cfg.norm_eps)
    return jnp.einsum("bsd,dm->bsm", y, p["w_out"])


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "state": jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner),
                            dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                             dtype),
    }


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                 cache: dict) -> Tuple[jax.Array, dict]:
    """One-token decode.  x: (B, 1, M)."""
    Bt, _, M = x.shape
    Din, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                    cfg.ssm_head_dim)
    z, xs, bc, dt = _project(cfg, p, x)
    xs, new_conv_x = _causal_conv(xs, p["conv_x"], p["conv_bx"],
                                  cache["conv_x"])
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"], p["conv_bbc"],
                                   cache["conv_bc"])
    xs = xs[:, 0].reshape(Bt, H, P)
    Bm = bc[:, 0, :N]
    Cm = bc[:, 0, N:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = dt * A
    xdt = xs.astype(jnp.float32) * dt[..., None]
    y, new_state = ssd_decode_step(cache["state"], xdt, a, Bm, Cm)
    y = y.astype(x.dtype) + xs * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(Bt, 1, Din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,dm->bsm", y, p["w_out"])
    return out, {"state": new_state, "conv_x": new_conv_x,
                 "conv_bc": new_conv_bc}
