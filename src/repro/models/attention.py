"""Grouped-query attention with RoPE, qk-norm, QKV-bias and sliding window.

Three implementations, selected by ``RunConfig.attn_impl``:

* ``naive``   — materializes the full score matrix; the test oracle.
* ``chunked`` — online-softmax over KV chunks (vmapped over Q chunks);
                memory O(Sq·Kc); the default for dry-run lowering on CPU and
                the pure-XLA production fallback.
* ``pallas``  — the TPU flash-attention kernel in ``repro.kernels``.

The decode path (single new token against a cache) is a plain einsum — the
score row is (B, H, S) which is small.  Sliding-window models keep a
ring-buffer cache of ``window`` entries instead of the full sequence.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RunConfig
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    M, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(M))
    p = {
        "w_q": jax.random.normal(ks[0], (M, H, Dh), dtype) * s,
        "w_k": jax.random.normal(ks[1], (M, KV, Dh), dtype) * s,
        "w_v": jax.random.normal(ks[2], (M, KV, Dh), dtype) * s,
        "w_o": jax.random.normal(ks[3], (H, Dh, M), dtype) * float(1.0 / np.sqrt(H * Dh)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H, Dh), dtype)
        p["b_k"] = jnp.zeros((KV, Dh), dtype)
        p["b_v"] = jnp.zeros((KV, Dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def project_qkv(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, M) -> q (B,S,H,Dh), k/v (B,S,KV,Dh), RoPE applied."""
    q = jnp.einsum("bsm,mhd->bshd", x, p["w_q"])
    k = jnp.einsum("bsm,mkd->bskd", x, p["w_k"])
    v = jnp.einsum("bsm,mkd->bskd", x, p["w_v"])
    if cfg.qkv_bias:
        q = q + p["b_q"]
        k = k + p["b_k"]
        v = v + p["b_v"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_proj(p: dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshd,hdm->bsm", o, p["w_o"])


# ---------------------------------------------------------------------------
# Score-matrix (naive) implementation — the oracle
# ---------------------------------------------------------------------------
def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int = 0,
                    q_positions: Optional[jax.Array] = None,
                    k_positions: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,Sq,H,Dh) k/v: (B,Sk,KV,Dh). Returns (B,Sq,H,Dh)."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, Sq, KV, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = (q_positions if q_positions is not None
          else jnp.arange(Sq))[:, None]                       # (Sq, 1)
    kp = (k_positions if k_positions is not None
          else jnp.arange(k.shape[1]))[None, :]               # (1, Sk)
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax implementation
# ---------------------------------------------------------------------------
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      unroll: bool = False) -> jax.Array:
    """Blockwise attention: vmap over Q chunks, scan over KV chunks.

    Equivalent to naive_attention for self-attention with aligned positions.
    ``unroll`` replaces the loops with trace-time python loops (roofline cost
    probes — cost_analysis counts while bodies once).
    """
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    pq, pk = nq * q_chunk - Sq, nk * kv_chunk - Sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(Dh)

    qb = qp.reshape(B, nq, q_chunk, KV, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    kb = kp_.reshape(B, nk, kv_chunk, KV, Dh).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(B, nk, kv_chunk, KV, Dh).transpose(1, 0, 3, 2, 4)
    # qb: (nq, B, KV, G, Qc, Dh); kb/vb: (nk, B, KV, Kc, Dh)

    q_pos_base = jnp.arange(nq) * q_chunk
    k_pos_base = jnp.arange(nk) * kv_chunk

    def one_q_block(qc, q0):
        # qc: (B, KV, G, Qc, Dh)
        qpos = q0 + jnp.arange(q_chunk)                        # (Qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, k0 = inp                                   # (B,KV,Kc,Dh)
            s = jnp.einsum("bkgqd,bksd->bkgqs",
                           qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            kpos = k0 + jnp.arange(kv_chunk)
            mask = kpos[None, :] < Sk                          # padding mask
            mask = jnp.broadcast_to(mask, (q_chunk, kv_chunk))
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            # bf16 probabilities into the PV matmul (accumulate fp32):
            # halves the dominant score-tensor HBM traffic (§Perf A2)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(jnp.bfloat16),
                vc.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, Dh), jnp.float32)
        if unroll:
            # python loop (roofline probe / TPU-kernel model): skip blocks
            # that are fully masked — the Pallas kernel's pl.when skip (§Perf
            # A2).  q0/q_end are trace-time ints here.
            carry = (m0, l0, a0)
            q0i = int(q0)
            for j in range(nk):
                k0 = j * kv_chunk
                if causal and k0 > q0i + q_chunk - 1:
                    continue                      # strictly-above-diagonal
                if window > 0 and (k0 + kv_chunk - 1) <= q0i - window:
                    continue                      # beyond the window
                carry, _ = kv_step(carry, (kb[j], vb[j], k_pos_base[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (kb, vb, k_pos_base))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if unroll:
        out = jnp.stack([one_q_block(qb[i], i * q_chunk)
                         for i in range(nq)])
    else:
        out = jax.vmap(one_q_block)(qb, q_pos_base)  # (nq, B, KV, G, Qc, Dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, Dh)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode step against a cache
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     cache_len: jax.Array, *, window: int = 0) -> jax.Array:
    """q: (B, 1, H, Dh); cache_k/v: (B, C, KV, Dh); cache_len: () or (B,).

    Full-attention models: C = max seq, positions [0, cache_len) are valid.
    Sliding-window models: C = window (ring buffer) and all slots < min(len, C)
    are valid (ring order does not matter for attention, which is a set
    operation over (k, v) pairs — RoPE was already applied at insert time).
    Per-sequence ``cache_len`` supports continuous batching.
    """
    B, _, H, Dh = q.shape
    C, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, KV, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * scale
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    valid = jnp.arange(C)[None, None, None, :] < lens[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cache plumbing
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Cache for ONE attention layer.  Sliding-window models only keep the
    window (ring buffer)."""
    C = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.d_head), dtype),
    }


def cache_insert(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 position: jax.Array) -> dict:
    """Insert a single (B, 1, KV, Dh) entry at ``position`` (ring if full).

    ``position`` is a scalar (whole batch aligned — the dry-run shapes) or a
    (B,) vector (continuous batching: every sequence at its own depth)."""
    C = cache["k"].shape[1]
    if jnp.ndim(position) == 0:
        slot = position % C
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        return {"k": k, "v": v}
    slots = position % C                                   # (B,)

    def upd(c_b, n_b, s_b):
        return jax.lax.dynamic_update_slice(c_b, n_b, (s_b, 0, 0))
    k = jax.vmap(upd)(cache["k"], k_new, slots)
    v = jax.vmap(upd)(cache["v"], v_new, slots)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Top-level attention entry points
# ---------------------------------------------------------------------------
def attention_forward(cfg: ModelConfig, run: RunConfig, p: dict,
                      x: jax.Array, positions: jax.Array) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = project_qkv(cfg, p, x, positions)
    window = cfg.sliding_window
    if run.attn_impl == "naive":
        o = naive_attention(q, k, v, causal=cfg.causal, window=window)
    elif run.attn_impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=cfg.causal, window=window)
    else:
        o = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                              q_chunk=run.attn_q_chunk,
                              kv_chunk=run.attn_kv_chunk,
                              unroll=run.unroll)
    return output_proj(p, o)


def attention_decode(cfg: ModelConfig, run: RunConfig, p: dict,
                     x: jax.Array, position: jax.Array,
                     cache: dict) -> Tuple[jax.Array, dict]:
    """One-token decode.  x: (B, 1, M); position: () int32 (aligned batch)
    or (B,) int32 (continuous batching — per-sequence depths)."""
    B = x.shape[0]
    if jnp.ndim(position) == 0:
        pos = jnp.reshape(position, (1, 1))                 # broadcast rope
    else:
        pos = position[:, None]                             # (B, 1)
    q, k, v = project_qkv(cfg, p, x, pos)
    cache = cache_insert(cache, k, v, position)
    C = cache["k"].shape[1]
    cache_len = jnp.minimum(position + 1, C)
    o = decode_attention(q, cache["k"], cache["v"], cache_len,
                         window=cfg.sliding_window)
    return output_proj(p, o), cache
