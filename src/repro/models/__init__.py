from repro.models.transformer import (init_model, model_forward, model_loss,
                                      model_decode_step, init_caches,
                                      count_params)

__all__ = ["init_model", "model_forward", "model_loss", "model_decode_step",
           "init_caches", "count_params"]
