"""Model spine: scan-over-units language model / encoder.

Parameters for the ``n_units`` repeats of ``block_pattern`` are stacked on a
leading axis and the forward pass is a ``jax.lax.scan`` over that axis, so
HLO size (and compile time) is independent of depth — essential for
llama3-405b's 126 layers on the 512-device dry-run.

Modality frontends are stubs per the assignment: audio models consume
precomputed frame embeddings, VLMs consume precomputed patch embeddings,
each passed through a learned linear projector (the one carve-out to
"implement everything").
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import config as C
from repro.config import ModelConfig, RunConfig
from repro.models import blocks as B
from repro.models.layers import (dtype_of, embed, init_embedding,
                                 init_lm_head, init_rms_norm, lm_head,
                                 rms_norm, softmax_cross_entropy)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_unit(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"block_{i}": B.init_block(bt, ks[i], cfg, dtype)
            for i, bt in enumerate(cfg.block_pattern)}


def init_model(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    k_embed, k_units, k_shared, k_head, k_front = jax.random.split(key, 5)
    unit_keys = jax.random.split(k_units, cfg.n_units)
    units = jax.vmap(lambda k: init_unit(k, cfg, dtype))(unit_keys)
    params = {
        "embed": init_embedding(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "units": units,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_lm_head(k_head, cfg.d_model,
                                      cfg.padded_vocab, dtype)
    shared = B.init_shared_block(k_shared, cfg, dtype)
    if shared is not None:
        params["shared"] = shared
    if cfg.frontend != "none":
        params["frontend_proj"] = (
            jax.random.normal(k_front, (cfg.d_model, cfg.d_model), dtype)
            * float(1.0 / np.sqrt(cfg.d_model)))
    return params


# ---------------------------------------------------------------------------
# input embedding (handles the three modality layouts)
# ---------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array]
                 ) -> jax.Array:
    """Returns (B, S, M) input activations."""
    if cfg.frontend == "audio":
        # batch["frames"]: (B, S, M) precomputed frame embeddings (stub)
        return jnp.einsum("bsm,mn->bsn", batch["frames"],
                          params["frontend_proj"])
    if cfg.frontend == "vision":
        # early fusion: projected patches prepended to token embeddings
        patches = jnp.einsum("bpm,mn->bpn", batch["patches"],
                             params["frontend_proj"])
        toks = embed(batch["tokens"], params["embed"])
        return jnp.concatenate([patches, toks], axis=1)
    return embed(batch["tokens"], params["embed"])


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def model_forward(cfg: ModelConfig, run: RunConfig, params: dict,
                  batch: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward.  Returns (logits fp32 (B,S,V), aux)."""
    x = embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    shared = params.get("shared")

    def unit_body(carry, unit_params):
        x, lb, zl = carry
        for i, bt in enumerate(cfg.block_pattern):
            if run.residual_spec is not None:
                x = jax.lax.with_sharding_constraint(
                    x, jax.sharding.PartitionSpec(*run.residual_spec))
            x, aux = B.block_forward(bt, cfg, run, unit_params[f"block_{i}"],
                                     shared, x, positions)
            lb = lb + aux["lb_loss"]
            zl = zl + aux["z_loss"]
        return (x, lb, zl), None

    if run.remat:
        unit_body = jax.checkpoint(unit_body, prevent_cse=False)

    carry = (x, jnp.float32(0.0), jnp.float32(0.0))
    if run.unroll:
        # python loop for the roofline cost probes (see RunConfig.unroll)
        for u in range(cfg.n_units):
            unit_params = jax.tree.map(lambda a: a[u], params["units"])
            carry, _ = unit_body(carry, unit_params)
        (x, lb_loss, z_loss) = carry
    else:
        (x, lb_loss, z_loss), _ = jax.lax.scan(unit_body, carry,
                                               params["units"])

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head_w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = _mask_padded(cfg, lm_head(x, head_w))
    return logits, {"lb_loss": lb_loss, "z_loss": z_loss}


def _mask_padded(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Vocab is padded to a multiple of 256 for sharding (config.padded_vocab);
    padded ids get -inf so CE/argmax/sampling never see them."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(valid, logits, -1e30)


def model_loss(cfg: ModelConfig, run: RunConfig, params: dict,
               batch: Dict[str, jax.Array], sample_weights=None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cross-entropy (+ MoE aux losses).  batch carries labels & loss_mask.
    ``sample_weights`` (B,) — per-sample loss weights used by the fused
    softsync engine (staleness-weighted gradient combination)."""
    logits, aux = model_forward(cfg, run, params, batch)
    mask = batch.get("loss_mask")
    if sample_weights is not None:
        w = sample_weights[:, None]
        mask = w if mask is None else mask * w
    ce = softmax_cross_entropy(logits, batch["labels"], mask)
    loss = ce + aux["lb_loss"] + aux["z_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked (n_units leading axis) per-block caches."""
    dtype = dtype_of(cfg.dtype)

    def one_unit(_):
        return {f"block_{i}": B.init_block_cache(bt, cfg, batch, max_len,
                                                 dtype)
                for i, bt in enumerate(cfg.block_pattern)}

    unit_cache = one_unit(None)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_units,) + a.shape),
        unit_cache)


def model_decode_step(cfg: ModelConfig, run: RunConfig, params: dict,
                      token: jax.Array, position: jax.Array, caches: dict
                      ) -> Tuple[jax.Array, dict]:
    """One decode step.  token: (B, 1) int32 (or (B,1,M) embeds for audio —
    not used: encoder-only models have no decode).  position: () int32.
    Returns (logits (B, 1, V) fp32, new caches)."""
    x = embed(token, params["embed"])
    shared = params.get("shared")

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, bt in enumerate(cfg.block_pattern):
            x, c, _ = B.block_decode(bt, cfg, run,
                                     unit_params[f"block_{i}"], shared, x,
                                     position, unit_cache[f"block_{i}"])
            new_cache[f"block_{i}"] = c
        return x, new_cache

    if run.unroll:
        new_caches = []
        for u in range(cfg.n_units):
            scanned = jax.tree.map(lambda a: a[u], (params["units"], caches))
            x, nc = unit_body(x, scanned)
            new_caches.append(nc)
        new_caches = jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
    else:
        x, new_caches = jax.lax.scan(unit_body, x, (params["units"], caches))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head_w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return _mask_padded(cfg, lm_head(x, head_w)), new_caches


# ---------------------------------------------------------------------------
# convenience: parameter counting on the real pytree
# ---------------------------------------------------------------------------
def count_params(params: dict) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
