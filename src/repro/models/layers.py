"""Primitive layers shared by every architecture family.

All layers are pure functions over explicit parameter pytrees (dicts of
jnp arrays).  Norm/softmax accumulation happens in fp32 regardless of the
compute dtype; outputs are cast back.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta))      # (d_head/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU MLP.  p: {w_gate (M,F), w_up (M,F), w_down (F,M)}."""
    g = jnp.einsum("...m,mf->...f", x, p["w_gate"])
    u = jnp.einsum("...m,mf->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fm->...m", h, p["w_down"])


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 * float(1.0 / np.sqrt(d_model)))
    s_out = float(1.0 * float(1.0 / np.sqrt(d_ff)))
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def sqrelu_ffn(x: jax.Array, p: dict) -> jax.Array:
    """RWKV channel-mix FFN: squared-relu.  p: {w_k (M,F), w_v (F,M)}."""
    k = jnp.einsum("...m,mf->...f", x, p["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("...f,fm->...m", k, p["w_v"])


def init_sqrelu_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_k": jax.random.normal(k1, (d_model, d_ff), dtype) * float(1.0 / np.sqrt(d_model)),
        "w_v": jax.random.normal(k2, (d_ff, d_model), dtype) * float(1.0 / np.sqrt(d_ff)),
    }


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), dtype) * 0.02


def lm_head(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., M), w: (M, V) -> logits (..., V) in fp32."""
    return jnp.einsum("...m,mv->...v", x, w).astype(jnp.float32)


def init_lm_head(key, d_model: int, vocab: int, dtype) -> jax.Array:
    return jax.random.normal(key, (d_model, vocab), dtype) * float(1.0 / np.sqrt(d_model))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over (optionally masked) positions.  logits fp32 (..., V)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def accuracy(logits: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(correct)
    mask = mask.astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)
