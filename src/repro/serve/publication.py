"""Staleness-bounded weight publication: PS ring → serving fleet (DESIGN.md §14).

The paper measures the accuracy cost of stale weights during *training*;
the north-star scenario — serving live traffic while learners keep pushing
— poses the same staleness/runtime tradeoff on the *inference* side.  This
module is the schedule half of that serving lane: given a scheduled
:class:`~repro.core.trace.ArrivalTrace` and a declarative
:class:`~repro.serve.fleet.FleetConfig`, resolve — entirely host-side, in
numpy — when each serving replica *publishes* (reads the newest row of the
(K, D) weight ring; never a copy of live training state), which published
version serves each inference request, and what each request's staleness
and latency are.  The result is a :class:`ServingTrace` riding on the
arrival trace; the replay engine (``core/engine.py``) captures exactly the
published ring rows in its compiled scan and evaluates request batches
against them.

Publication semantics (the exactly-testable contract):

* A publication at time t reads the **newest** ring row — the snapshot of
  version v(t) = |{update events with fire time ≤ t}|.  Version swap is
  atomic at the read instant; ``publish_cost_s`` models the transfer pause
  (it blocks the replica's request queue, surfacing in latency — never in
  which version a request sees).
* Refreshes and membership events apply before same-instant requests (the
  same tie rule the schedule pass uses), so a ``staleness`` policy's
  budget holds at *every* request: version lag ≤ B, always.
* Serving resolution draws from an rng stream tagged independently of the
  arrival schedule (cf. ``_SHARD_RNG_TAG`` in ``core/trace.py``), so a
  run with serving schedules bit-identical arrivals to one without.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

PUBLICATION_KINDS = ("every_n", "staleness", "time", "on_demand")

# rng stream tag for serving traffic: request arrivals must never perturb
# the main arrival stream (with/without serving schedule identical traces)
_SERVE_RNG_TAG = 0x5345


@dataclasses.dataclass(frozen=True)
class PublicationPolicy:
    """When a replica refreshes its published weights from the PS ring.

    * ``every_n``   — publish each N-th version as it is born (N =
      ``every``): the replica's held version is always the latest multiple
      of N, so version lag ≤ N − 1.
    * ``staleness`` — staleness budget in versions: refresh the instant the
      lag *would* exceed ``max_version_lag`` = B, reading the newest
      version (catch-up).  Lag ≤ B at every request, exactly.
    * ``time``      — staleness budget in seconds: refresh the instant the
      newest version's birth time exceeds the held version's by more than
      ``max_time_lag`` = T.  Seconds-lag ≤ T at every request.
    * ``on_demand`` — each request reads the newest version at its arrival
      (lag 0 always; the publish cost is paid per version change, per
      request, on the serving path).
    """

    kind: str = "staleness"
    every: int = 1                 # every_n: publish each N-th version
    max_version_lag: int = 4       # staleness: budget B in versions
    max_time_lag: float = 10.0     # time: budget T in simulated seconds

    def __post_init__(self):
        if self.kind not in PUBLICATION_KINDS:
            raise ValueError(f"unknown publication kind {self.kind!r}: "
                             f"expected one of {PUBLICATION_KINDS}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.max_version_lag < 0:
            raise ValueError(f"max_version_lag must be >= 0, "
                             f"got {self.max_version_lag}")
        if self.max_time_lag <= 0:
            raise ValueError(f"max_time_lag must be > 0, "
                             f"got {self.max_time_lag}")

    def __str__(self):
        if self.kind == "every_n":
            return f"every{self.every}"
        if self.kind == "staleness":
            return f"lag<={self.max_version_lag}"
        if self.kind == "time":
            return f"lag<={self.max_time_lag:g}s"
        return "on_demand"


@dataclasses.dataclass(frozen=True)
class ServingTrace:
    """The resolved serving lane of one arrival trace, as dense host arrays
    (frozen like :class:`~repro.core.trace.ArrivalTrace` — treat the arrays
    as immutable replay inputs).

    Per-request arrays have length R (``request_time`` order); a dropped
    request (no replica alive at arrival) has ``replica`` −1 and zeros in
    the result columns.  ``pub_versions`` is the sorted set of versions the
    fleet ever published (version 0 = the init weights every replica boots
    with); ``req_pub[i]`` indexes the version serving request i, which is
    how the replay engine's snapshot buffer maps captured ring rows to
    requests.
    """

    horizon: float
    n_replicas: int
    request_time: np.ndarray     # (R,) float64 — arrival times, sorted
    replica: np.ndarray          # (R,) int32 — serving replica, −1 dropped
    version: np.ndarray          # (R,) int32 — published version served
    staleness: np.ndarray        # (R,) int32 — version lag at arrival
    staleness_s: np.ndarray      # (R,) float64 — seconds lag at arrival
    latency: np.ndarray          # (R,) float64 — completion − arrival
    refresh_time: np.ndarray     # (F,) float64 — publication instants
    refresh_replica: np.ndarray  # (F,) int32
    refresh_version: np.ndarray  # (F,) int32 — version read at the refresh
    pub_versions: np.ndarray     # (P,) int32 — sorted unique published
    req_pub: np.ndarray          # (R,) int32 — index into pub_versions
    truncated: bool = False      # traffic hit FleetConfig.max_requests

    @property
    def n_requests(self) -> int:
        return int(self.request_time.shape[0])

    @property
    def served(self) -> np.ndarray:
        """(R,) bool — requests a live replica answered."""
        return self.replica >= 0

    @property
    def n_refreshes(self) -> int:
        return int(self.refresh_time.shape[0])


def _poisson_arrivals(rng: np.random.Generator, fleet,
                      horizon: float) -> Tuple[np.ndarray, bool]:
    """Traffic generator: homogeneous Poisson at ``request_rate``, or — with
    ``diurnal_amplitude`` A > 0 — the inhomogeneous diurnal rate
    ``rate·(1 + A·sin(2πt/period))`` via thinning (period 0 = one cycle
    over the horizon).  Returns (arrival times, truncated-at-cap flag)."""
    rate = fleet.request_rate
    A = fleet.diurnal_amplitude
    period = fleet.diurnal_period or max(horizon, 1e-9)
    rmax = rate * (1.0 + A)
    out: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rmax)
        if t >= horizon:
            return np.asarray(out, np.float64), False
        if A > 0:
            lam_t = rate * (1.0 + A * math.sin(2.0 * math.pi * t / period))
            if rng.uniform() * rmax > lam_t:
                continue
        out.append(t)
        if len(out) >= fleet.max_requests:
            return np.asarray(out, np.float64), True


def _live_intervals(timeline, n: int) -> List[List[Tuple[float, float]]]:
    """Per-replica [start, end) liveness windows from a membership timeline
    (kinds collapse to alive/dead: ``leave`` and ``crash`` both take the
    replica out until its next ``join``; ``validate_for`` already ran)."""
    active0 = timeline.initial_active(n)
    out: List[List[Tuple[float, float]]] = [[] for _ in range(n)]
    cur = [0.0 if active0[r] else None for r in range(n)]
    for ev in timeline.events:
        r = ev.learner
        if ev.kind == "join":
            cur[r] = ev.t
        elif cur[r] is not None:
            out[r].append((cur[r], ev.t))
            cur[r] = None
    for r in range(n):
        if cur[r] is not None:
            out[r].append((cur[r], math.inf))
    return out


def _replica_refreshes(policy: PublicationPolicy, segments, times, birth,
                       steps: int):
    """One replica's publication instants and the versions each read.

    Every live segment boots with a publication at its start (version 0 on
    a t = 0 boot: the init weights); scheduled refreshes then follow the
    policy, each reading the newest version at the refresh instant
    (catch-up — a ring read is always of the latest row).  ``on_demand``
    schedules no refreshes beyond boot (requests read at arrival)."""
    r_t: List[float] = []
    r_v: List[int] = []
    for (s, e) in segments:
        h = int(np.searchsorted(times, s, side="right"))
        r_t.append(s)
        r_v.append(h)
        if policy.kind == "on_demand":
            continue
        while True:
            if policy.kind == "every_n":
                v = (h // policy.every + 1) * policy.every
            elif policy.kind == "staleness":
                v = h + policy.max_version_lag + 1
            else:                                  # "time"
                v = int(np.searchsorted(
                    birth, birth[h] + policy.max_time_lag, side="right"))
            if v > steps:
                break
            tv = float(times[v - 1])               # version v's birth instant
            if tv >= e:
                break                              # replica dies first
            h = int(np.searchsorted(times, tv, side="right"))   # catch up
            r_t.append(tv)
            r_v.append(h)
    return np.asarray(r_t, np.float64), np.asarray(r_v, np.int64)


def schedule_serving(trace, fleet, seed: int = 0) -> ServingTrace:
    """Resolve the serving lane of a scheduled trace (host-side, numpy).

    Interleaves — in time order, with refresh/membership-before-request at
    ties — the fleet's publication refreshes, the traffic generator's
    request arrivals, and replica churn, against the trace's update-event
    clock.  Pure in (trace, fleet, seed); the rng stream is independent of
    the arrival schedule's.
    """
    times = np.asarray(trace.event_time, np.float64)        # (steps,)
    steps = int(times.shape[0])
    horizon = float(times[-1]) if steps else 0.0
    birth = np.concatenate([[0.0], times])  # birth[v] = when version v arose
    n = fleet.replicas

    rng = np.random.default_rng([seed, _SERVE_RNG_TAG])
    req_t, truncated = _poisson_arrivals(rng, fleet, horizon)
    R = int(req_t.shape[0])
    v_now = np.searchsorted(times, req_t, side="right").astype(np.int64)

    segments = _live_intervals(fleet.membership, n)
    per_t, per_v = [], []
    for r in range(n):
        rt, rv = _replica_refreshes(fleet.policy, segments[r], times, birth,
                                    steps)
        per_t.append(rt)
        per_v.append(rv)

    # --- request → replica: round-robin over the replicas alive at arrival
    alive = np.zeros((R, n), bool)
    for r in range(n):
        for (s, e) in segments[r]:
            alive[:, r] |= (req_t >= s) & (req_t < e)
    replica = np.full(R, -1, np.int32)
    rr = 0
    for i in range(R):
        live = np.flatnonzero(alive[i])
        if live.size:
            replica[i] = live[rr % live.size]
            rr += 1

    # --- request → published version (the replica's last refresh ≤ t;
    # refreshes apply before same-instant requests via side="right")
    version = np.zeros(R, np.int64)
    for r in range(n):
        m = replica == r
        if not m.any():
            continue
        if fleet.policy.kind == "on_demand":
            version[m] = v_now[m]                 # read at arrival: lag 0
        else:
            k = np.searchsorted(per_t[r], req_t[m], side="right") - 1
            version[m] = per_v[r][np.maximum(k, 0)]
    served = replica >= 0
    version[~served] = 0
    staleness = np.where(served, v_now - version, 0).astype(np.int64)
    staleness_s = np.where(served, birth[v_now] - birth[version], 0.0)

    # --- latency: per-replica FIFO queue; a scheduled publication blocks
    # the replica for publish_cost_s, a request for the service time (on
    # demand additionally pays the publish cost whenever its read actually
    # advances the replica's version)
    service = (fleet.service_base_s
               + fleet.service_per_sample_s * fleet.request_samples)
    latency = np.zeros(R, np.float64)
    for r in range(n):
        req_idx = np.flatnonzero(replica == r)
        # merge refreshes (prio 0: before same-instant requests) + requests
        ev = ([(float(t), 0, int(v)) for t, v in zip(per_t[r], per_v[r])]
              + [(float(req_t[i]), 1, int(i)) for i in req_idx])
        ev.sort(key=lambda e: (e[0], e[1]))
        free = 0.0
        held = -1
        for (t, prio, payload) in ev:
            if prio == 0:
                dur = fleet.publish_cost_s
                held = payload
            else:
                dur = service
                if (fleet.policy.kind == "on_demand"
                        and int(v_now[payload]) != held):
                    dur += fleet.publish_cost_s
                    held = int(v_now[payload])
            start = max(t, free)
            free = start + dur
            if prio == 1:
                latency[payload] = free - t

    refresh_time = np.concatenate(per_t) if per_t else np.zeros(0)
    refresh_replica = np.concatenate(
        [np.full(per_t[r].shape[0], r, np.int32) for r in range(n)]
    ) if per_t else np.zeros(0, np.int32)
    refresh_version = (np.concatenate(per_v).astype(np.int64)
                       if per_v else np.zeros(0, np.int64))
    order = np.lexsort((refresh_replica, refresh_time))
    pub_versions = np.unique(np.concatenate(
        [np.zeros(1, np.int64), refresh_version, version[served]]))
    req_pub = np.searchsorted(pub_versions, version).astype(np.int32)
    req_pub[~served] = 0

    return ServingTrace(
        horizon=horizon, n_replicas=n,
        request_time=req_t,
        replica=replica,
        version=version.astype(np.int32),
        staleness=staleness.astype(np.int32),
        staleness_s=staleness_s.astype(np.float64),
        latency=latency,
        refresh_time=refresh_time[order],
        refresh_replica=refresh_replica[order],
        refresh_version=refresh_version[order].astype(np.int32),
        pub_versions=pub_versions.astype(np.int32),
        req_pub=req_pub,
        truncated=truncated)
