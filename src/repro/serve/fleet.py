"""Serving-fleet declaration and results for train-while-serve (DESIGN.md §14).

:class:`FleetConfig` is the declarative half: how many serving replicas, the
:class:`~repro.serve.publication.PublicationPolicy` they refresh under, the
traffic they face, and their cost model.  It is frozen and hashable so it
rides on ``RunConfig`` (and therefore through ``schedule_cached`` and the
sweep axes) like every other knob.  Replica churn reuses
:class:`~repro.membership.MembershipTimeline` from the elastic subsystem —
the timeline indexes serving replicas here, not learners.

:class:`ServingResult` is the measured half: the resolved
:class:`~repro.serve.publication.ServingTrace` plus the per-request quality
metric the replay engine evaluated against each request's *published*
weight version, with the summary statistics the benchmarks and the
experiment driver report.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.membership import MembershipTimeline
from repro.serve.publication import PublicationPolicy, ServingTrace


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """N serving replicas refreshing published weights from the PS ring.

    The cost model is deliberately minimal (a per-replica FIFO: a
    publication blocks for ``publish_cost_s``, a request for
    ``service_base_s + service_per_sample_s * request_samples``), because
    its only job is to surface the policy tradeoff: tighter staleness
    budgets → more publication pauses → higher tail latency; looser
    budgets → staler served versions → lower serving accuracy.
    """

    replicas: int = 2
    policy: PublicationPolicy = PublicationPolicy()
    request_rate: float = 4.0            # mean requests/s across the fleet
    request_samples: int = 32            # samples per request batch
    diurnal_amplitude: float = 0.0       # 0 = homogeneous Poisson traffic
    diurnal_period: float = 0.0          # seconds; 0 = one cycle per horizon
    service_base_s: float = 0.02
    service_per_sample_s: float = 5e-4
    publish_cost_s: float = 0.05
    max_requests: int = 200_000          # traffic cap (ServingTrace.truncated)
    membership: MembershipTimeline = MembershipTimeline()

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if not isinstance(self.policy, PublicationPolicy):
            raise ValueError("policy must be a PublicationPolicy, "
                             f"got {type(self.policy).__name__}")
        if self.request_rate <= 0:
            raise ValueError(f"request_rate must be > 0, "
                             f"got {self.request_rate}")
        if self.request_samples < 1:
            raise ValueError(f"request_samples must be >= 1, "
                             f"got {self.request_samples}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1), "
                             f"got {self.diurnal_amplitude}")
        if self.diurnal_period < 0:
            raise ValueError(f"diurnal_period must be >= 0, "
                             f"got {self.diurnal_period}")
        for name in ("service_base_s", "service_per_sample_s",
                     "publish_cost_s"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if self.max_requests < 1:
            raise ValueError(f"max_requests must be >= 1, "
                             f"got {self.max_requests}")
        membership = self.membership
        if not isinstance(membership, MembershipTimeline):
            membership = MembershipTimeline(membership)
            object.__setattr__(self, "membership", membership)
        membership.validate_for(self.replicas)

    def __str__(self):
        tag = f"{self.replicas}x[{self.policy}]@{self.request_rate:g}rps"
        if self.membership.events:
            tag += f"+{self.membership}"
        return tag


@dataclasses.dataclass(frozen=True)
class ServingResult:
    """Serving-lane output of one replay: the resolved trace plus the
    per-request quality metric (e.g. accuracy of the request batch under
    the published weights that served it; 0 for dropped requests)."""

    trace: ServingTrace
    request_metric: np.ndarray           # (R,) float32
    metric_name: str = "accuracy"

    def summary(self) -> dict:
        """Aggregate statistics for benchmarks / the experiment driver."""
        t = self.trace
        served = t.served
        n_served = int(served.sum())
        lat = t.latency[served]
        stale = t.staleness[served]

        def _q(a, q):
            return float(np.quantile(a, q)) if a.size else 0.0

        return {
            "metric_name": self.metric_name,
            "n_requests": t.n_requests,
            "n_served": n_served,
            "n_dropped": t.n_requests - n_served,
            "n_refreshes": t.n_refreshes,
            "accuracy": (float(self.request_metric[served].mean())
                         if n_served else 0.0),
            "staleness_mean": float(stale.mean()) if n_served else 0.0,
            "staleness_max": int(stale.max()) if n_served else 0,
            "staleness_s_mean": (float(t.staleness_s[served].mean())
                                 if n_served else 0.0),
            "latency_p50_s": _q(lat, 0.50),
            "latency_p99_s": _q(lat, 0.99),
            "requests_per_s": (n_served / t.horizon if t.horizon > 0
                               else 0.0),
            "truncated": bool(t.truncated),
        }
