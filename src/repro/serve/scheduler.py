"""Continuous batching: requests enter and leave the decode batch at any
step, each sequence at its own depth (per-sequence positions/cache lengths —
see models.attention.cache_insert/decode_attention).

The engine keeps a fixed-size slot array (the compiled decode batch shape
never changes ⇒ one XLA program for the whole serving lifetime):

  * ``submit()`` queues a prompt;
  * free slots are filled by prefilling the prompt at batch=1 and scattering
    the resulting caches into the slot (works for KV, SSM and RWKV caches —
    anything with the batch on axis 1 of the stacked cache pytree);
  * ``step()`` decodes ONE token for every active slot with a single batched
    ``serve_step``; finished sequences free their slot for the queue.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RunConfig
from repro.models import init_caches, model_decode_step
from repro.serve.engine import init_serve_state, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _scatter_slot(big, small, slot: int):
    """Write a batch-1 cache pytree into batch slot ``slot`` of the engine's
    stacked caches (every leaf: (units, B, ...))."""
    def upd(b, s):
        start = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), start)
    return jax.tree.map(upd, big, small)


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 max_batch: int = 8, max_len: int = 256,
                 eos_id: Optional[int] = None):
        self.cfg, self.run, self.params = cfg, run, params
        self.max_batch, self.max_len, self.eos_id = max_batch, max_len, eos_id
        self.caches = init_caches(cfg, max_batch, max_len)
        self.positions = np.zeros((max_batch,), np.int32)
        self.last_tokens = np.zeros((max_batch, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._next_rid = 0
        self.completed: Dict[int, Request] = {}

        def decode(params, tokens, positions, caches):
            return model_decode_step(cfg, run, params, tokens, positions,
                                     caches)
        self._decode = jax.jit(decode)

    # ---- request lifecycle -------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            # batch-1 prefill, then scatter the caches into the slot
            state = init_serve_state(self.cfg, 1, self.max_len)
            prompt = jnp.asarray([req.prompt], jnp.int32)
            _, state = prefill(self.cfg, self.run, self.params,
                               {"tokens": prompt}, state)
            self.caches = _scatter_slot(self.caches, state.caches, slot)
            self.positions[slot] = len(req.prompt)
            self.last_tokens[slot, 0] = req.prompt[-1]
            self.slot_req[slot] = req

    # ---- one decode step for the whole batch --------------------------------
    def step(self) -> int:
        """Admit, decode one token for every active slot; returns number of
        active sequences this step."""
        self._admit()
        active = [s for s in range(self.max_batch)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_tokens),
            jnp.asarray(self.positions), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.generated.append(tok)
            self.positions[s] += 1
            self.last_tokens[s, 0] = tok
            if (len(req.generated) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self.positions[s] >= self.max_len):
                req.done = True
                self.completed[req.rid] = req
                self.slot_req[s] = None
                self.positions[s] = 0
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.completed
