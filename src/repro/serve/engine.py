"""Serving engine: batched prefill + decode with per-family caches.

``serve_step`` is the function the decode dry-run shapes lower: ONE new token
for every sequence in the batch against a seq_len-deep cache (KV for
attention blocks, ring-buffer of ``window`` entries for sliding-window
models, constant-size recurrent state for SSM/RWKV).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models import (init_caches, model_decode_step, model_forward)


@dataclasses.dataclass
class ServeState:
    caches: dict
    position: jax.Array          # () int32 — next write index
    last_tokens: jax.Array       # (B, 1) most recent token per sequence


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> ServeState:
    return ServeState(
        caches=init_caches(cfg, batch, max_len),
        position=jnp.zeros((), jnp.int32),
        last_tokens=jnp.zeros((batch, 1), jnp.int32),
    )


def prefill(cfg: ModelConfig, run: RunConfig, params: dict,
            batch: Dict[str, jax.Array], state: ServeState
            ) -> Tuple[jax.Array, ServeState]:
    """Process the full prompt, fill caches by replaying decode steps.

    For throughput-critical paths the dry-run uses ``prefill_step`` (the
    full-sequence forward); this incremental variant is the functional
    reference that also leaves the caches ready for decode."""
    tokens = batch["tokens"]
    B, S = tokens.shape

    def body(carry, t):
        state_caches, pos = carry
        logits, new_caches = model_decode_step(
            cfg, run, params, tokens[:, t][:, None], pos, state_caches)
        return (new_caches, pos + 1), logits[:, 0]

    (caches, pos), all_logits = jax.lax.scan(
        body, (state.caches, state.position), jnp.arange(S))
    new_state = ServeState(caches, pos, tokens[:, -1:])
    return all_logits.transpose(1, 0, 2), new_state


def prefill_step(cfg: ModelConfig, run: RunConfig, params: dict,
                 batch: Dict[str, jax.Array]) -> jax.Array:
    """Full-sequence forward — what the prefill_32k dry-run shape lowers."""
    logits, _ = model_forward(cfg, run, params, batch)
    return logits


def serve_step(cfg: ModelConfig, run: RunConfig, params: dict,
               tokens: jax.Array, position: jax.Array, caches: dict,
               *, greedy: bool = True, temperature: float = 1.0,
               rng: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, dict]:
    """One decode step for the whole batch: (B,1) token in, (B,1) token out."""
    logits, caches = model_decode_step(cfg, run, params, tokens, position,
                                       caches)
    logits = logits[:, 0]                       # (B, V)
    if greedy:
        nxt = jnp.argmax(logits, axis=-1)
    else:
        nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
    return nxt[:, None].astype(jnp.int32), caches


def generate(cfg: ModelConfig, run: RunConfig, params: dict,
             prompt: jax.Array, max_new_tokens: int,
             max_len: Optional[int] = None) -> jax.Array:
    """Greedy generation: prefill the prompt then decode autoregressively."""
    B, S = prompt.shape
    max_len = max_len or (S + max_new_tokens)
    state = init_serve_state(cfg, B, max_len)
    _, state = prefill(cfg, run, params, {"tokens": prompt}, state)

    def body(carry, _):
        tok, pos, caches = carry
        nxt, caches = serve_step(cfg, run, params, tok, pos, caches)
        return (nxt, pos + 1, caches), nxt[:, 0]

    (_, _, _), out = jax.lax.scan(
        body, (state.last_tokens, state.position, state.caches),
        None, length=max_new_tokens)
    return out.T                                 # (B, max_new_tokens)
