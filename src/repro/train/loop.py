"""Training loop: protocol-aware trainer over the distributed engines.

This is the single-process/jit path used by examples and tests (the
launcher in ``repro.launch.train`` adds the mesh/sharding).  One "round" of
softsync = n PS update events (DESIGN.md §2); metrics include the running
staleness bookkeeping so the (σ, μ, λ) tradeoff driver can read it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.core.distributed import make_train_step
from repro.data.pipeline import PrefetchIterator, make_batch_fn
from repro.models import init_model, model_loss
from repro.optim import init_state, spec_from_run


@dataclasses.dataclass
class TrainResult:
    params: object
    opt_state: object
    history: List[Dict]
    steps: int
    wallclock: float


def train(cfg: ModelConfig, run: RunConfig, *, steps: int,
          batch: int, seq: int, engine: str = "sequential",
          eval_every: int = 0,
          eval_fn: Optional[Callable] = None,
          params=None,
          warmstart_steps: int = 0,
          log: Optional[Callable[[str], None]] = None) -> TrainResult:
    """Train ``steps`` rounds of the configured protocol on synthetic data.

    ``warmstart_steps`` implements the paper's §5.5 strategy: initialize a
    softsync run from hardsync training (the paper warm-starts ImageNet
    1-softsync from 1 hardsync epoch to stabilize AdaGrad)."""
    import dataclasses as _dc
    key = jax.random.PRNGKey(run.seed)
    if params is None:
        params = init_model(cfg, key)
    opt = init_state(spec_from_run(run), params)

    def loss_fn(p, b, sample_weights=None):
        return model_loss(cfg, run, p, b, sample_weights=sample_weights)

    if warmstart_steps and run.protocol != "hardsync":
        warm_run = _dc.replace(run, protocol="hardsync",
                               lr_policy="sqrt_scale")
        warm = train(cfg, warm_run, steps=warmstart_steps, batch=batch,
                     seq=seq, eval_every=0, params=params, log=log)
        params = warm.params
        if log:
            log(f"warm-start: {warmstart_steps} hardsync rounds done")

    step_fn = jax.jit(make_train_step(run, loss_fn, engine=engine))
    batch_fn = make_batch_fn(cfg, batch, seq, seed=run.seed)
    it = iter(PrefetchIterator(batch_fn, steps))

    history: List[Dict] = []
    t0 = time.perf_counter()
    for step, b in enumerate(it):
        params, opt, metrics = step_fn(params, opt, b)
        if eval_every and (step + 1) % eval_every == 0:
            entry = {"step": step + 1,
                     "loss": float(metrics["loss"]),
                     "ce": float(metrics["ce"])}
            if eval_fn is not None:
                entry.update(eval_fn(params))
            history.append(entry)
            if log:
                log(f"step {step+1}: " + " ".join(
                    f"{k}={v:.4f}" for k, v in entry.items() if k != "step"))
    wall = time.perf_counter() - t0
    return TrainResult(params, opt, history, steps, wall)
