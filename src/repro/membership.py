"""Elastic cluster membership: join / leave / crash-restart timelines
(DESIGN.md §7).

The paper's tradeoff study holds the learner population λ fixed, but its
runtime model is most interesting on a *changing* cluster (Chen et al.,
"Revisiting Distributed Synchronous SGD"; Dutta et al., "Slow and Stale
Gradients Can Win the Race").  A :class:`MembershipTimeline` declares that
change as a sorted sequence of per-learner transitions:

* ``join``  — the learner (re-)enters the cluster: it pulls the current
  weights (fresh timestamps) and starts computing.  A learner whose FIRST
  event is a join starts the run inactive.
* ``leave`` — graceful departure: the learner's in-flight push still
  arrives (the work was already under way), then it stops pulling.
* ``crash`` — failure: the learner's in-flight push is DROPPED; it only
  returns via a later ``join`` (crash + join = crash-restart).

The timeline is *declarative data* on :class:`~repro.config.RunConfig`
(hence an ``ExperimentSpec``/``Sweep`` axis): membership resolves entirely
in the schedule pass of the simulator (``core/trace.py``) — joins/leaves
move the effective λ(t) that n-softsync's splitting threshold c(t) =
max(1, ⌊P(t)/n⌋) is computed from, and cancelled pushes become a per-event
validity mask on the :class:`~repro.core.trace.ArrivalTrace`, so the
compiled replay engine needs no per-event branching.  An empty timeline is
**static** and reproduces the pre-elastic schedule bit-for-bit
(``tests/test_elastic.py``).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Iterable, Optional, Tuple

import numpy as np

EVENT_KINDS = ("join", "leave", "crash")


@dataclasses.dataclass(frozen=True, order=True)
class MembershipEvent:
    """One membership transition at simulated time ``t`` (seconds on the
    schedule clock).  Ordering is (t, learner, kind): events at the same
    instant apply in learner order, and a same-time crash precedes a join
    ("crash" < "join" alphabetically), so crash-at-t + join-at-t is a
    valid zero-delay restart."""

    t: float
    learner: int
    kind: str

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"membership event kind must be one of "
                             f"{EVENT_KINDS}, got {self.kind!r}")
        if self.t < 0:
            raise ValueError(f"membership event time must be >= 0, "
                             f"got {self.t}")
        if self.learner < 0:
            raise ValueError(f"membership event learner must be >= 0, "
                             f"got {self.learner}")


def _as_event(e) -> MembershipEvent:
    if isinstance(e, MembershipEvent):
        return e
    if isinstance(e, dict):
        return MembershipEvent(**e)
    return MembershipEvent(*e)


@dataclasses.dataclass(frozen=True)
class MembershipTimeline:
    """A sorted tuple of :class:`MembershipEvent`.  Hashable and frozen —
    usable as a RunConfig field and a Sweep axis value.  Events may be
    given as ``MembershipEvent``, ``(t, learner, kind)`` tuples, or dicts;
    they are normalized and sorted on construction."""

    events: Tuple[MembershipEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events", tuple(sorted(_as_event(e) for e in self.events)))

    # -- constructors --------------------------------------------------------
    @classmethod
    def crash_restart(cls, learners: Iterable[int], crash_at: float,
                      restart_after: Optional[float] = None
                      ) -> "MembershipTimeline":
        """Crash ``learners`` at ``crash_at``; restart each ``restart_after``
        seconds later (None = no restart: the learners stay gone)."""
        evs = []
        for l in learners:
            evs.append(MembershipEvent(crash_at, int(l), "crash"))
            if restart_after is not None:
                evs.append(MembershipEvent(crash_at + restart_after,
                                           int(l), "join"))
        return cls(tuple(evs))

    @classmethod
    def leaves(cls, learners: Iterable[int], at: float
               ) -> "MembershipTimeline":
        """Graceful departure of ``learners`` at time ``at``."""
        return cls(tuple(MembershipEvent(at, int(l), "leave")
                         for l in learners))

    def merged(self, other: "MembershipTimeline") -> "MembershipTimeline":
        """The union of two timelines (events re-sorted)."""
        return MembershipTimeline(self.events + other.events)

    # -- queries -------------------------------------------------------------
    @property
    def static(self) -> bool:
        """True iff the cluster never changes (the pre-elastic world)."""
        return not self.events

    def validate_for(self, n_learners: int) -> "MembershipTimeline":
        """Check learner ids against λ and per-learner transition sanity:
        join only while inactive, leave/crash only while active (a learner
        whose first event is a join starts inactive)."""
        per = {}
        for ev in self.events:
            per.setdefault(ev.learner, []).append(ev)
        for l, evs in per.items():
            if l >= n_learners:
                raise ValueError(
                    f"membership event names learner {l} but the run has "
                    f"n_learners={n_learners} (ids are 0-based)")
            active = evs[0].kind != "join"
            for ev in evs:
                if ev.kind == "join":
                    if active:
                        raise ValueError(
                            f"learner {l} joins at t={ev.t} while already "
                            f"active (missing leave/crash before it)")
                    active = True
                else:
                    if not active:
                        raise ValueError(
                            f"learner {l} {ev.kind}s at t={ev.t} while "
                            f"inactive (missing join before it)")
                    active = False
        return self

    def initial_active(self, n_learners: int) -> np.ndarray:
        """(λ,) bool — who is in the cluster at t = 0.  A learner is
        initially active unless its first event is a ``join``."""
        active = np.ones(n_learners, bool)
        seen = set()
        for ev in self.events:
            if ev.learner not in seen:
                seen.add(ev.learner)
                if ev.kind == "join":
                    active[ev.learner] = False
        return active

    def __str__(self):
        if not self.events:
            return "static"
        kinds = Counter(ev.kind for ev in self.events)
        return "+".join(f"{kinds[k]}{k}" for k in EVENT_KINDS if kinds[k])
