"""Pytree ⇄ single flat fp32 buffer (the Pallas backend's layout).

The fused ``ps_update`` kernel wants ONE contiguous (D,) parameter vector so
the whole model updates in a single ``pallas_call`` — one grid, one HBM pass
— instead of a Python loop of per-leaf launches.  These helpers concatenate
every leaf (ravelled, cast to fp32) and split/reshape/cast back afterwards.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TreeLayout:
    """Static description of a flattened pytree (shapes, dtypes, offsets)."""

    treedef: object
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[object, ...]
    sizes: Tuple[int, ...]

    @property
    def total(self) -> int:
        return int(sum(self.sizes))


def layout_of(tree) -> TreeLayout:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return TreeLayout(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves),
        dtypes=tuple(l.dtype for l in leaves),
        sizes=tuple(int(np.prod(l.shape)) if l.shape else 1 for l in leaves))


def tree_to_flat(tree) -> jax.Array:
    """Concatenate all leaves into one fp32 (D,) vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])


def stack_grads_flat(grads: Sequence) -> jax.Array:
    """c gradient pytrees → one (c, D) fp32 matrix."""
    return jnp.stack([tree_to_flat(g) for g in grads])


def batched_tree_to_flat(tree) -> jax.Array:
    """Pytree whose leaves share a leading batch axis → (B, D) fp32 (the
    vmapped-gradient counterpart of :func:`stack_grads_flat`)."""
    leaves = jax.tree_util.tree_leaves(tree)
    b = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(b, -1).astype(jnp.float32) for l in leaves], axis=1)


def batched_flat_to_tree(flat: jax.Array, layout: TreeLayout):
    """(B, D) matrix → tree with a leading (B,) axis on every leaf — the
    batched inverse used on ring-buffer gathers (one slice/reshape per leaf
    instead of per (row, leaf))."""
    b = flat.shape[0]
    out: List = []
    off = 0
    for shape, dtype, size in zip(layout.shapes, layout.dtypes, layout.sizes):
        out.append(flat[:, off:off + size].reshape((b,) + shape)
                   .astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(layout.treedef, out)


def pad_flat(flat: jax.Array, width: int) -> jax.Array:
    """Zero-pad the last axis of a flat buffer out to ``width`` (a kernel
    tile multiple or S·Dp shard width).  Trailing zeros are inert through
    sgd/momentum/adagrad events — padding is pure layout, and slicing
    ``[..., :D]`` is its exact inverse."""
    d = flat.shape[-1]
    if width == d:
        return flat
    return jnp.pad(flat, [(0, 0)] * (flat.ndim - 1) + [(0, width - d)])


def shard_pack(flat: jax.Array, shards: int, width: int) -> jax.Array:
    """(D,) flat buffer → (S, Dp) per-shard rows, zero-padding the last
    shard to the equal width Dp = ⌈D/S⌉ (core/topology.py layout).  Zeros
    are inert through sgd/momentum/adagrad events, so packing is pure
    layout — ``shard_unpack`` is its exact inverse."""
    d = flat.shape[-1]
    return jnp.pad(flat, [(0, 0)] * (flat.ndim - 1)
                   + [(0, shards * width - d)]).reshape(
        flat.shape[:-1] + (shards, width))


def shard_pack_grads(g: jax.Array, shards: int, width: int) -> jax.Array:
    """(c, D) stacked gradients → (S, c, Dp): the per-shard gradient slices
    the vmapped shard apply consumes."""
    return jnp.moveaxis(shard_pack(g, shards, width), -2, 0)


def shard_unpack(mat: jax.Array, dim: int) -> jax.Array:
    """(S, Dp) per-shard rows → the (D,) flat buffer (padding dropped)."""
    return mat.reshape(mat.shape[:-2] + (-1,))[..., :dim]


def flat_to_tree(flat: jax.Array, layout: TreeLayout):
    """Split a (D,) vector back into the original tree (leaf dtypes restored)."""
    out: List = []
    off = 0
    for shape, dtype, size in zip(layout.shapes, layout.dtypes, layout.sizes):
        out.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(layout.treedef, out)
