"""The single source of truth for staleness-aware applyUpdate (DESIGN.md §3).

Every synchronization protocol in the paper — hardsync (Eq. 3), n-softsync
(Eq. 5), async (Eq. 4) — reduces at the parameter server to the same step:
a staleness-weighted combination of the c pending gradients folded into one
optimizer event,

    θ' = θ − α · Σ_i coef_i · G_i        (+ optimizer state update)

with the staleness-dependent LR modulation of Eq. 6 / footnote 3 deciding α
(scalar) or the per-gradient α_i (Zhang et al., "Staleness-aware Async-SGD",
2016).  This module defines that update rule ONCE:

* :class:`UpdateSpec`   — which optimizer + its hyperparameters.
* :func:`update_event`  — one optimizer event on plain fp32 arrays.  This
  exact function body is what the Pallas ``ps_update`` kernel executes per
  tile and what the pytree backends map over leaves — there is no second
  implementation of the math anywhere in the repo.
* :func:`init_state`    — optimizer state pytree (fp32 accumulators).
* :func:`sequential_fold` — the algebra that folds c *sequential* momentum
  events (per-gradient LRs) into one affine update, used by the fused
  softsync engine and by ``fused_coefficients``.

Two update modes (both supported by every backend, see ``backends.py``):

* ``combine``    — g = Σ_i coef_i·G_i, then ONE optimizer event with lr[0].
  This is the paper's Eq. 3/5 semantics (average, then apply).
* ``sequential`` — c optimizer events, event i applying gradient
  coef_i·G_i with its own lr_i.  This is the footnote-3 per-gradient
  modulation done right: momentum/adagrad state advances per event, fixing
  the seed bug where per-gradient LRs silently bypassed the optimizer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

OPTIMIZERS = ("sgd", "momentum", "adagrad", "adamw")

# optimizers whose update is expressible as one fused Pallas kernel pass
# (adamw needs a scalar step counter — pytree backends only).
KERNEL_OPTIMIZERS = ("sgd", "momentum", "adagrad")


@dataclasses.dataclass(frozen=True)
class UpdateSpec:
    """Optimizer kind + hyperparameters.  Hashable → usable as a jit static."""

    optimizer: str = "sgd"
    momentum: float = 0.9
    eps: float = 1e-8
    beta1: float = 0.9
    beta2: float = 0.95
    weight_decay: float = 0.0

    def __post_init__(self):
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(f"unknown optimizer {self.optimizer!r}")

    @property
    def state_keys(self) -> Tuple[str, ...]:
        return {"sgd": (), "momentum": ("velocity",), "adagrad": ("accum",),
                "adamw": ("mu", "nu", "count")}[self.optimizer]

    @property
    def kernel_supported(self) -> bool:
        return self.optimizer in KERNEL_OPTIMIZERS


def spec_from_run(run) -> UpdateSpec:
    """Build an UpdateSpec from a RunConfig (the repo-wide convention)."""
    return UpdateSpec(optimizer=run.optimizer, momentum=run.momentum,
                      weight_decay=run.weight_decay)


def init_state(spec: UpdateSpec, params) -> dict:
    """Optimizer state pytree.  Accumulators are fp32 regardless of the
    parameter dtype (bf16 params train with fp32 velocity/variance)."""
    f32 = lambda p: jnp.zeros(jnp.shape(p), jnp.float32)
    if spec.optimizer == "momentum":
        return {"velocity": jax.tree.map(f32, params)}
    if spec.optimizer == "adagrad":
        return {"accum": jax.tree.map(f32, params)}
    if spec.optimizer == "adamw":
        return {"mu": jax.tree.map(f32, params),
                "nu": jax.tree.map(f32, params),
                "count": jnp.zeros((), jnp.int32)}
    return {}


# ---------------------------------------------------------------------------
# THE applyUpdate rule.  One optimizer event on fp32 arrays.
# ---------------------------------------------------------------------------
def update_event(spec: UpdateSpec, w, s, g, lr):
    """θ' = θ − α·step(g) with the optimizer state folded in.

    ``w``/``g`` are fp32 arrays of one leaf; ``s`` is that leaf's fp32 state
    (velocity or adagrad accumulator; ignored for sgd).  ``lr`` may be a
    traced scalar.  Returns ``(w', s')``.

    Called per-leaf by the pytree backends and per-tile *inside* the Pallas
    ``ps_update`` kernel — the kernel and the references share this body.
    (adamw carries two moments + a counter and is handled in backends.py.)
    """
    if spec.optimizer == "sgd":
        return w - lr * g, s
    if spec.optimizer == "momentum":
        v = spec.momentum * s + g
        return w - lr * v, v
    if spec.optimizer == "adagrad":
        a = s + jnp.square(g)
        return w - lr * g / (jnp.sqrt(a) + spec.eps), a
    raise ValueError(f"update_event does not support {spec.optimizer!r}")


# ---------------------------------------------------------------------------
# Folding algebra: c sequential momentum events → one affine update.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoundFold:
    """One-shot equivalent of c sequential momentum events.

    Sequential:  v_j = m·v_{j-1} + g_j ;  θ ← θ − lr_j·v_j   (j = 0..c−1)
    folds exactly into

        θ' = θ − Σ_i theta_coef_i·g_i − v0_coef·v
        v' = v_decay·v + Σ_i m^{c−1−i}·g_i

    ``v_gain`` = Σ_i m^{c−1−i} is the velocity gain when all g_i coincide
    (the fused engine's single weighted-mean gradient); with distinct g_i the
    velocity carry is a documented round-level approximation while the θ
    update stays exact for round 1 (see EXPERIMENTS.md §Perf).
    """

    theta_coef: np.ndarray     # (c,) per-gradient θ coefficients
    v0_coef: float             # θ's carry from the incoming velocity
    v_decay: float             # m^c
    v_gain: float              # Σ_i m^{c−1−i}


def sequential_fold(lrs: Sequence[float], momentum: float) -> RoundFold:
    """Fold per-event LRs + momentum into the affine round update."""
    lrs = np.asarray(lrs, np.float64)
    c = len(lrs)
    m = float(momentum)
    coef = np.zeros((c,))
    for i in range(c):
        for j in range(i, c):
            coef[i] += lrs[j] * (m ** (j - i))
    v0 = float(sum(lrs[j] * (m ** (j + 1)) for j in range(c)))
    gain = float(sum(m ** (c - 1 - i) for i in range(c)))
    return RoundFold(theta_coef=coef.astype(np.float64), v0_coef=v0,
                     v_decay=m ** c, v_gain=gain)
