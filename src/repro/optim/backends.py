"""Three interchangeable backends for the unified applyUpdate (DESIGN.md §3).

* ``reference`` — eager pure-jnp, leaf-by-leaf Python loop.  The oracle.
* ``jit``       — the same pytree math under ``jax.jit`` (cached per
  (spec, mode, c)).  What the SPMD engines trace into their step functions.
* ``pallas``    — every leaf concatenated into one flat fp32 buffer and the
  whole model updated by a single fused ``ps_update`` kernel launch
  (interpret mode off-TPU).  The PS hot path.

All three execute :func:`repro.optim.spec.update_event` — the backends differ
only in how they schedule it over memory, never in the math.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import flatten
from repro.optim.spec import RoundFold, UpdateSpec, update_event

BACKENDS = ("reference", "jit", "pallas")

# host-side count of fused-kernel dispatches (tests/benchmarks assert the
# Pallas path really is the one being exercised).
pallas_dispatches = 0


def _f32(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float32), tree)


def _combine(grads: Sequence, coef) -> object:
    """Σ_i coef_i·G_i in fp32 — the staleness-weighted sumGradients."""
    return jax.tree.map(
        lambda *g: sum(coef[i] * g[i].astype(jnp.float32)
                       for i in range(len(g))), *grads)


# ---------------------------------------------------------------------------
# pytree event application (reference + jit backends)
# ---------------------------------------------------------------------------
def _adamw_event(spec: UpdateSpec, params, state, g32, lr):
    b1, b2, eps = spec.beta1, spec.beta2, spec.eps
    cnt = state["count"] + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], g32)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g),
                      state["nu"], g32)
    c1 = 1 - b1 ** cnt.astype(jnp.float32)
    c2 = 1 - b2 ** cnt.astype(jnp.float32)
    new_p = jax.tree.map(
        lambda p, m, n: (p.astype(jnp.float32)
                         - lr * ((m / c1) / (jnp.sqrt(n / c2) + eps)
                                 + spec.weight_decay * p.astype(jnp.float32))
                         ).astype(p.dtype),
        params, mu, nu)
    return new_p, {"mu": mu, "nu": nu, "count": cnt}


def apply_single(spec: UpdateSpec, params, state, grad, lr):
    """ONE optimizer event with gradient ``grad`` (pytree) and lr ``lr``.

    Pure and jit-friendly (``lr`` may be traced) — this is what the
    distributed engines inline into their step functions."""
    g32 = _f32(grad)
    if spec.optimizer == "adamw":
        return _adamw_event(spec, params, state, g32, lr)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(g32)
    if spec.optimizer == "sgd":
        new_p = [update_event(spec, p.astype(jnp.float32), None, g, lr)[0]
                 .astype(p.dtype) for p, g in zip(flat_p, flat_g)]
        return jax.tree_util.tree_unflatten(treedef, new_p), state
    key = spec.state_keys[0]
    flat_s = jax.tree_util.tree_leaves(state[key])
    res = [update_event(spec, p.astype(jnp.float32), s.astype(jnp.float32),
                        g, lr)
           for p, s, g in zip(flat_p, flat_s, flat_g)]
    new_p = jax.tree_util.tree_unflatten(
        treedef, [r[0].astype(p.dtype) for r, p in zip(res, flat_p)])
    new_s = jax.tree_util.tree_unflatten(
        treedef, [r[1].astype(s.dtype) for r, s in zip(res, flat_s)])
    return new_p, {key: new_s}


def apply_update_tree(spec: UpdateSpec, params, state, grads: Sequence,
                      coef, lrs, mode: str = "combine"):
    """The unified update on pytrees (reference semantics, jittable).

    ``grads`` is a sequence of c gradient pytrees; ``coef``/``lrs`` are
    length-c vectors (combination weights, per-event LRs)."""
    c = len(grads)
    if mode == "combine":
        return apply_single(spec, params, state, _combine(grads, coef),
                            lrs[0])
    if mode != "sequential":
        raise ValueError(f"unknown mode {mode!r}")
    for i in range(c):
        gi = jax.tree.map(lambda g: coef[i] * g.astype(jnp.float32),
                          grads[i])
        params, state = apply_single(spec, params, state, gi, lrs[i])
    return params, state


def apply_round_folded(spec: UpdateSpec, params, state, ghat,
                       fold: RoundFold):
    """Apply a whole round of c sequential momentum events in one shot, given
    only their weighted-mean gradient ``ghat`` (the fused engine's single
    backward pass).  θ gets the exact affine fold — including the
    ``v0_coef`` carry from the incoming velocity that the seed engine
    dropped — and v advances by (v_decay, v_gain)."""
    if spec.optimizer != "momentum":
        raise ValueError("apply_round_folded is momentum-only; other "
                         "optimizers use apply_single with the folded lr")
    total = float(np.sum(fold.theta_coef))
    g32 = _f32(ghat)
    v = state["velocity"]
    new_v = jax.tree.map(lambda vv, g: fold.v_decay * vv + fold.v_gain * g,
                         v, g32)
    new_p = jax.tree.map(
        lambda p, g, vv: (p.astype(jnp.float32) - total * g
                          - fold.v0_coef * vv).astype(p.dtype),
        params, g32, v)
    return new_p, {"velocity": new_v}


def apply_event_flat(spec: UpdateSpec, w, s, g, coef, lrs,
                     mode: str = "combine"):
    """The unified multi-gradient update on flat fp32 buffers — the jit/scan
    friendly twin of the Pallas kernel's per-tile body (``ps_update._events``)
    with the identical ``update_event`` math and combine einsum.

    ``w``/``s``: (D,) fp32 (``s`` None for sgd); ``g``: (c, D); ``coef``/
    ``lrs``: (c,).  This is what the compiled replay engine's scan executes
    per update event (``core/engine.py``): one fused event over the whole
    concatenated model instead of a per-leaf pytree walk."""
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no flat event path")
    g32 = g.astype(jnp.float32)
    if mode == "combine":
        ghat = jnp.einsum("cd,c->d", g32, coef.astype(jnp.float32))
        return update_event(spec, w, s, ghat, lrs[0])
    if mode != "sequential":
        raise ValueError(f"unknown mode {mode!r}")
    for i in range(g.shape[0]):                     # c is static
        w, s = update_event(spec, w, s, coef[i] * g32[i], lrs[i])
    return w, s


RING_IMPLS = ("auto", "pallas", "fused", "stock")
RING_DTYPES = ("fp32", "bf16")


def resolve_ring_impl(impl: str, spec: UpdateSpec) -> str:
    """Resolve a RunConfig's ``ring_impl`` axis to a concrete scan body.

    ``auto`` picks the Pallas megakernel on TPU and its fused jnp twin
    everywhere else (same math, no interpret-mode launch overhead on the
    CPU hot loop).  Optimizers without a flat event path (adamw) always
    take the stock pytree body — their RunConfig validation already
    rejected a bf16 ring."""
    if impl not in RING_IMPLS:
        raise ValueError(f"unknown ring_impl {impl!r}: expected one of "
                         f"{RING_IMPLS}")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "fused"
    if not spec.kernel_supported:
        return "stock"
    return impl


def apply_event_ring(spec: UpdateSpec, ring, s, res, g, coef, lrs,
                     prev, slot, mode: str = "combine"):
    """ONE fused ring event on flat buffers — the jnp twin of the Pallas
    replay megakernel (``kernels/replay_ring.ring_apply``), and the
    engine's ``ring_impl="fused"`` scan body.

    ``ring``: (K, Dp) in the ring dtype (fp32 or bf16); ``s``: (Dp,) fp32
    optimizer state or None (sgd); ``res``: (Dp,) fp32 error-feedback
    residue or None (fp32 ring); ``g``: (c, Dp) fp32; ``prev``/``slot``:
    ring row scalars.  The master chain is exact: the fp32 weights entering
    ``apply_event_flat`` are ``q(w) + (w − q(w)) = w``, so with a bf16 ring
    the only approximation anywhere is gradients being *evaluated* at
    quantized snapshots (DESIGN.md §12).  With an fp32 ring the casts are
    no-ops and this is bitwise the stock gather/update/set body."""
    w = ring[prev].astype(jnp.float32)
    if res is not None:
        w = w + res
    w, s = apply_event_flat(spec, w, s, g, coef, lrs, mode)
    q = w.astype(ring.dtype)
    ring = ring.at[slot].set(q)
    if res is not None:
        res = w - q.astype(jnp.float32)
    return ring, s, res


def apply_event_ring_whatif(spec: UpdateSpec, ring, s, res, a, wstar, ts,
                            coef, lrs, prev, slot):
    """Fused ring event with closed-form gradients g_j = a⊙(w_ts_j − w*),
    streamed over the c slots with a ``fori_loop`` so the (c, Dp)
    pulled-weight/gradient matrices never materialize — peak extra memory
    is O(Dp), which is what makes trace-driven what-if replay feasible at
    10–100× larger D (the jnp twin of ``replay_ring.ring_apply_whatif``;
    combine mode only).  The accumulation order (slot 0 → c−1) matches the
    kernel's inner grid axis bitwise."""
    c = ts.shape[0]
    coef = coef.astype(jnp.float32)

    def body(j, acc):
        row = ring[ts[j]].astype(jnp.float32)
        return acc + coef[j] * (a * (row - wstar))

    ghat = jax.lax.fori_loop(0, c, body,
                             jnp.zeros(ring.shape[-1:], jnp.float32))
    w = ring[prev].astype(jnp.float32)
    if res is not None:
        w = w + res
    w, s = update_event(spec, w, s, ghat, lrs[0])
    q = w.astype(ring.dtype)
    ring = ring.at[slot].set(q)
    if res is not None:
        res = w - q.astype(jnp.float32)
    return ring, s, res


def apply_event_sharded(spec: UpdateSpec, w, s, g, coef, lrs,
                        mode: str = "combine"):
    """:func:`apply_event_flat` vmapped over a leading shard axis — the
    sharded-PS replay's per-event apply (DESIGN.md §6).

    ``w``: (S, Dp) per-shard weight rows; ``s``: (S, Dp) state rows or None
    (sgd); ``g``: (S, c, Dp) per-shard gradient slices; ``coef``/``lrs``:
    (c,) shared across shards (every shard folds the same c pushes — the
    update events are aligned, only the *pulled* slices differ).  Because
    ``update_event`` is elementwise, the per-shard apply is exactly the
    shard slice of the unsharded apply (partition invariance, pinned by
    ``tests/test_topology.py``)."""
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no flat event path")
    fn = jax.vmap(
        lambda ws, ss, gs: apply_event_flat(spec, ws, ss, gs, coef, lrs,
                                            mode),
        in_axes=(0, None if s is None else 0, 0))
    return fn(w, s, g)


# ---------------------------------------------------------------------------
# SPMD replay collectives (DESIGN.md §13)
# ---------------------------------------------------------------------------
def ring_all_gather(x, axis_name: str, size: int):
    """``lax.all_gather(x, axis_name)`` rebuilt from size − 1 neighbor
    ``ppermute`` exchanges — the parameter-server ring-pull pattern, where
    each PS device forwards the slice it just received to its neighbor.

    Returns the (size, *x.shape) stack in device order.  Pure data
    movement (a permutation, no arithmetic), so the result is **bitwise**
    equal to ``lax.all_gather`` (pinned by ``tests/test_spmd.py``); it
    trades one fused collective for S − 1 dependent hops, so the engine
    uses it only when asked (``spmd_assembly='ppermute'``)."""
    if size == 1:
        return x[None]
    perm = [(i, (i + 1) % size) for i in range(size)]
    chunks = [x]
    cur = x
    for _ in range(size - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    # chunk k on device i originated at device (i − k) mod size; reorder so
    # position s holds device s's slice, matching all_gather
    stacked = jnp.stack(chunks)
    i = jax.lax.axis_index(axis_name)
    order = jnp.mod(i - jnp.arange(size), size)
    return jnp.take(stacked, order, axis=0)


def combine_spmd(g, coef, axis_name: str):
    """The combine-mode einsum ĝ = Σ_j coef_j·g_j with the slot axis split
    over ``axis_name``: each learner device reduces its local slot block,
    then one ``psum`` folds the partials.  For a single learner device the
    psum is the identity and this is bitwise ``apply_event_flat``'s einsum;
    with L > 1 the partial-sum tree reorders the fp32 reduction (the
    documented ~1 ulp/event tolerance, DESIGN.md §13)."""
    part = jnp.einsum("cd,c->d", g.astype(jnp.float32),
                      coef.astype(jnp.float32))
    return jax.lax.psum(part, axis_name)


# ---------------------------------------------------------------------------
# pallas backend: one fused kernel launch over the concatenated model
# ---------------------------------------------------------------------------
def apply_update_flat(spec: UpdateSpec, params, state, grads: Sequence,
                      coef, lrs, mode: str = "combine",
                      interpret: bool = True):
    """Flatten → single ``ps_update`` pallas_call → unflatten."""
    from repro.kernels import ps_update as _psu   # lazy: breaks import cycle

    p_layout = flatten.layout_of(params)
    w = flatten.tree_to_flat(params)
    g = flatten.stack_grads_flat(grads)
    if spec.optimizer == "sgd":
        w2, _ = _psu.ps_apply(w, None, g, coef, lrs, spec=spec, mode=mode,
                              interpret=interpret)
        return flatten.flat_to_tree(w2, p_layout), state
    key = spec.state_keys[0]
    s_layout = flatten.layout_of(state[key])
    s = flatten.tree_to_flat(state[key])
    w2, s2 = _psu.ps_apply(w, s, g, coef, lrs, spec=spec, mode=mode,
                           interpret=interpret)
    return (flatten.flat_to_tree(w2, p_layout),
            {key: flatten.flat_to_tree(s2, s_layout)})


# ---------------------------------------------------------------------------
# host-facing dispatch (jit-cached per static configuration)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jitted(spec: UpdateSpec, mode: str, c: int, backend: str,
            interpret: bool):
    if backend == "pallas":
        def fn(params, state, grads, coef, lrs):
            return apply_update_flat(spec, params, state, list(grads),
                                     coef, lrs, mode, interpret)
    else:
        def fn(params, state, grads, coef, lrs):
            return apply_update_tree(spec, params, state, list(grads),
                                     coef, lrs, mode)
    return jax.jit(fn)


def apply_update(spec: UpdateSpec, params, state, grads: Sequence,
                 coef, lrs, *, mode: str = "combine", backend: str = "jit",
                 interpret: Optional[bool] = None):
    """The one entry point every consumer routes through.

    ``grads``: sequence of c gradient pytrees.  ``coef``: (c,) combination
    weights.  ``lrs``: (c,) per-event LRs (``combine`` mode reads lrs[0]).
    """
    global pallas_dispatches
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    grads = tuple(grads)
    coef = jnp.asarray(coef, jnp.float32)
    lrs = jnp.asarray(lrs, jnp.float32)
    if backend == "reference":
        return apply_update_tree(spec, params, state, list(grads),
                                 coef, lrs, mode)
    if backend == "pallas" and not spec.kernel_supported:
        backend = "jit"                      # adamw: pytree path
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "pallas":
        pallas_dispatches += 1
    fn = _jitted(spec, mode, len(grads), backend, bool(interpret))
    return fn(params, state, grads, coef, lrs)


def sgd_step(params, grad, lr):
    """Convenience plain-SGD event (baseline simulators)."""
    return apply_single(UpdateSpec(optimizer="sgd"), params, {}, grad, lr)[0]
