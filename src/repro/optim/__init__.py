"""Unified staleness-aware optimizer subsystem (DESIGN.md §3).

The single source of truth for the paper's applyUpdate hot-spot: every
protocol's weight update — hardsync Eq. 3, n-softsync Eq. 5, async Eq. 4,
with the staleness LR modulation of Eq. 6 / footnote 3 — is expressed once
(``spec.update_event``) and executed by three interchangeable backends
(``reference`` / ``jit`` / ``pallas``).  ``core/protocols.py``,
``core/distributed.py``, ``core/simulator.py`` and ``train/loop.py`` all
route through this module; the fused Pallas ``ps_update`` kernel shares the
same event body, making the optimized path the measured path.
"""

from repro.optim.spec import (KERNEL_OPTIMIZERS, OPTIMIZERS, RoundFold,
                              UpdateSpec, init_state, sequential_fold,
                              spec_from_run, update_event)
from repro.optim.backends import (BACKENDS, RING_DTYPES, RING_IMPLS,
                                  apply_event_flat, apply_event_ring,
                                  apply_event_ring_whatif,
                                  apply_event_sharded, apply_round_folded,
                                  apply_single, apply_update,
                                  apply_update_tree, apply_update_flat,
                                  combine_spmd, resolve_ring_impl,
                                  ring_all_gather, sgd_step)
from repro.optim import flatten  # noqa: F401

__all__ = [
    "OPTIMIZERS", "KERNEL_OPTIMIZERS", "BACKENDS",
    "RING_IMPLS", "RING_DTYPES",
    "UpdateSpec", "RoundFold", "init_state", "spec_from_run",
    "update_event", "sequential_fold",
    "apply_update", "apply_update_tree", "apply_update_flat",
    "apply_event_flat", "apply_event_ring", "apply_event_ring_whatif",
    "apply_event_sharded", "apply_single",
    "apply_round_folded", "resolve_ring_impl", "sgd_step",
    "combine_spmd", "ring_all_gather",
]
