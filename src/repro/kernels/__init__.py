"""Pallas TPU kernels for the perf-critical compute layers (DESIGN.md §10):

* ``ps_update``        — fused PS applyUpdate (the paper's hot-spot)
* ``flash_attention``  — blockwise attention, causal/window tile skipping
* ``ssm_scan``         — Mamba2 SSD chunked scan
* ``wkv6``             — RWKV6 data-dependent-decay recurrence

``ops`` holds the jit'd public wrappers (interpret mode on CPU);
``ref`` the pure-jnp oracles every kernel is tested against.
"""
from repro.kernels import ops, ref  # noqa: F401
