"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (batch, heads, chunks); the chunk axis is sequential ("arbitrary") and
carries the (N, P) inter-chunk state in VMEM scratch — the TPU-native
version of the SSD algorithm: quadratic intra-chunk attention-form on the
MXU, tiny recurrent state carried between grid steps instead of a serial
scan over time.

Inputs follow ``repro.models.ssm.ssd_chunked``:
    x (B, S, H, P) — dt-premultiplied inputs
    a (B, S, H)    — per-step log decay (negative)
    Bm/Cm (B, S, N) — input/output projections (n_groups = 1)
Returns (y (B, S, H, P), final_state (B, H, N, P)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
                *, Q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    Bc = b_ref[0].astype(jnp.float32)               # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)               # (Q, N)

    cum = jnp.cumsum(a)                             # (Q,)
    # intra-chunk decay matrix: exp(cum_i - cum_j) masked to i >= j
    diff = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    decay = jnp.where(tri, jnp.exp(diff), 0.0)      # (Q, Q)

    scores = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jnp.dot(scores * decay, x,
                      preferred_element_type=jnp.float32)           # (Q, P)

    state = state_scr[...]                          # (N, P)
    y_inter = jnp.exp(cum)[:, None] * jnp.dot(
        Cc, state, preferred_element_type=jnp.float32)              # (Q, P)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[-1]
    w = jnp.exp(total - cum)                        # (Q,)
    state_new = (jnp.exp(total) * state
                 + jnp.dot(Bc.T * w[None, :], x,
                           preferred_element_type=jnp.float32))
    state_scr[...] = state_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


def ssm_scan(x: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array, *,
             chunk: int = 256, interpret: bool = False):
    """Chunked SSD scan.  Shapes as in the module docstring."""
    Bt, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = nc * Q

    kernel = functools.partial(_ssd_kernel, Q=Q, n_chunks=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, Sp, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, Bm, Cm)
    return y[:, :S], final_state
