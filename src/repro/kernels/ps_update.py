"""Fused parameter-server update kernel (the paper's applyUpdate hot-spot).

The PS receives c gradient shards and applies the unified staleness-aware
update (repro.optim, DESIGN.md §3) in one pass over the parameters.  Two
modes, matching the optimizer subsystem:

* ``combine``    — g = Σ_i coef_i·G_i, then ONE optimizer event (Eq. 3/5
  with the footnote-3 per-gradient coefficients as kernel operands).
* ``sequential`` — c in-register optimizer events, event i applying
  coef_i·G_i with its own lr_i (exact per-gradient staleness semantics;
  momentum/adagrad state advances per event without extra HBM traffic).

Supported optimizers: sgd (stateless), momentum (velocity), adagrad
(accumulator) — the kernel body calls ``repro.optim.spec.update_event``,
the SAME function the pytree backends map over leaves, so there is exactly
one implementation of the update math in the repo.

Unfused this is c + 4 HBM round-trips over the model; fused it is one read
of (W, S, G_0..c) and one write of (W', S') — the memory-bound term of the
PS roofline drops by ~3× (see EXPERIMENTS.md §Perf).

Layout: the FULL parameter pytree is concatenated into a single fp32 vector
(repro.optim.flatten), padded and reshaped to (R, 128) lanes; the grid tiles
rows, so the whole model updates in ONE ``pallas_call`` instead of a
per-leaf Python loop.  Per-gradient coefficients and LRs arrive as (c, 1)
fp32 operands broadcast to every tile.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.optim.spec import UpdateSpec, update_event
from repro.optim import flatten as _flatten

LANES = 128
DEFAULT_ROW_BLOCK = 256


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------
def _events(spec: UpdateSpec, mode: str, c: int, coef_ref, lrs_ref, w, s, g_ref):
    """Run the update events on one (rblk, LANES) tile.  ``w``/``s`` are fp32
    tile arrays; gradients are read from ``g_ref`` ((c, rblk, LANES))."""
    if mode == "combine":
        coef = coef_ref[...].astype(jnp.float32)            # (c, 1)
        g = jnp.einsum("crl,co->rl", g_ref[...].astype(jnp.float32), coef)
        return update_event(spec, w, s, g, lrs_ref[0, 0])
    for i in range(c):                                       # c is static
        gi = coef_ref[i, 0] * g_ref[i].astype(jnp.float32)
        w, s = update_event(spec, w, s, gi, lrs_ref[i, 0])
    return w, s


def _stateful_kernel(coef_ref, lrs_ref, w_ref, s_ref, g_ref,
                     w_out_ref, s_out_ref, *, spec: UpdateSpec, mode: str,
                     c: int):
    w = w_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    w, s = _events(spec, mode, c, coef_ref, lrs_ref, w, s, g_ref)
    w_out_ref[...] = w.astype(w_out_ref.dtype)
    s_out_ref[...] = s.astype(s_out_ref.dtype)


def _stateless_kernel(coef_ref, lrs_ref, w_ref, g_ref, w_out_ref, *,
                      spec: UpdateSpec, mode: str, c: int):
    w = w_ref[...].astype(jnp.float32)
    w, _ = _events(spec, mode, c, coef_ref, lrs_ref, w, None, g_ref)
    w_out_ref[...] = w.astype(w_out_ref.dtype)


# ---------------------------------------------------------------------------
# flat entry point
# ---------------------------------------------------------------------------
def ps_apply(w_flat: jax.Array, s_flat: Optional[jax.Array],
             g_flat: jax.Array, coef: jax.Array, lrs: jax.Array, *,
             spec: UpdateSpec, mode: str = "combine",
             row_block: Optional[int] = None, interpret: bool = False
             ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """The fused applyUpdate.  w/s: (D,); g: (c, D); coef/lrs: (c,) fp32.

    ``s_flat`` is the optimizer-state vector (velocity or adagrad
    accumulator); pass None for sgd.  Pads D up to a multiple of
    row_block·128 and reshapes to (R, 128) tiles.
    """
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no kernel path")
    D = w_flat.shape[0]
    c = g_flat.shape[0]
    if row_block is None:
        row_block = int(min(DEFAULT_ROW_BLOCK, max(1, -(-D // LANES))))
    tile = row_block * LANES
    Dp = ((D + tile - 1) // tile) * tile
    pad = Dp - D
    wp = jnp.pad(w_flat, (0, pad)).reshape(-1, LANES)
    gp = jnp.pad(g_flat, ((0, 0), (0, pad))).reshape(c, -1, LANES)
    coef2 = coef.reshape(c, 1).astype(jnp.float32)
    lrs2 = lrs.reshape(c, 1).astype(jnp.float32)
    grid = (wp.shape[0] // row_block,)

    vec_spec = pl.BlockSpec((c, 1), lambda i: (0, 0))
    row_spec = pl.BlockSpec((row_block, LANES), lambda i: (i, 0))
    g_spec = pl.BlockSpec((c, row_block, LANES), lambda i: (0, i, 0))

    if spec.optimizer == "sgd":
        kernel = functools.partial(_stateless_kernel, spec=spec, mode=mode,
                                   c=c)
        w2 = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[vec_spec, vec_spec, row_spec, g_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(wp.shape, w_flat.dtype),
            interpret=interpret,
        )(coef2, lrs2, wp, gp)
        return w2.reshape(-1)[:D], None

    sp = jnp.pad(s_flat, (0, pad)).reshape(-1, LANES)
    kernel = functools.partial(_stateful_kernel, spec=spec, mode=mode, c=c)
    w2, s2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, row_spec, row_spec, g_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct(wp.shape, w_flat.dtype),
            jax.ShapeDtypeStruct(sp.shape, s_flat.dtype),
        ],
        interpret=interpret,
    )(coef2, lrs2, wp, sp, gp)
    return w2.reshape(-1)[:D], s2.reshape(-1)[:D]


# ---------------------------------------------------------------------------
# back-compat wrappers (seed API: momentum-only, combine mode)
# ---------------------------------------------------------------------------
def ps_update_flat(w_flat: jax.Array, v_flat: jax.Array, g_flat: jax.Array,
                   coef: jax.Array, *, momentum: float = 0.9,
                   lr: float = 1.0, row_block: int = DEFAULT_ROW_BLOCK,
                   interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Momentum combine-mode entry.  w/v: (D,); g: (c, D); coef: (c,)."""
    c = g_flat.shape[0]
    spec = UpdateSpec(optimizer="momentum", momentum=momentum)
    lrs = jnp.full((c,), lr, jnp.float32)
    w2, v2 = ps_apply(w_flat, v_flat, g_flat, jnp.asarray(coef, jnp.float32),
                      lrs, spec=spec, mode="combine", row_block=row_block,
                      interpret=interpret)
    return w2, v2


def ps_update_tree(params, velocity, grads_list, coef, *, momentum=0.9,
                   lr=1.0, interpret: bool = False):
    """Pytree convenience wrapper: ONE fused kernel launch over the whole
    concatenated model (repro.optim.flatten), not a per-leaf loop."""
    spec = UpdateSpec(optimizer="momentum", momentum=momentum)
    p_layout = _flatten.layout_of(params)
    v_layout = _flatten.layout_of(velocity)
    w = _flatten.tree_to_flat(params)
    v = _flatten.tree_to_flat(velocity)
    g = _flatten.stack_grads_flat(grads_list)
    c = g.shape[0]
    lrs = jnp.full((c,), lr, jnp.float32)
    w2, v2 = ps_apply(w, v, g, jnp.asarray(coef, jnp.float32), lrs,
                      spec=spec, mode="combine", interpret=interpret)
    return (_flatten.flat_to_tree(w2, p_layout),
            _flatten.flat_to_tree(v2, v_layout))
