"""Fused parameter-server update kernel (the paper's applyUpdate hot-spot).

The PS receives c gradient shards, averages them with staleness-modulated
per-gradient coefficients (paper footnote 3 / Eq. 6), folds the momentum
update and writes the new weights — all in one pass over the parameters:

    g      = Σ_i s_i · G_i          (staleness-weighted sumGradients)
    V'     = m · V + g              (momentum)
    W'     = W − lr · V'            (applyUpdate)

Unfused this is c + 4 HBM round-trips over the model; fused it is one read
of (W, V, G_0..c) and one write of (W', V') — the memory-bound term of the
PS roofline drops by ~3× (see EXPERIMENTS.md §Perf).

Layout: parameters are flattened and reshaped to (R, 128) lanes; the grid
tiles rows.  Per-gradient coefficients arrive as a (c, 1) fp32 operand
broadcast to every tile.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_ROW_BLOCK = 256


def _kernel(coef_ref, w_ref, v_ref, g_ref, w_out_ref, v_out_ref, *,
            momentum: float, lr: float):
    # w/v: (rblk, LANES); g: (c, rblk, LANES); coef: (c, 1)
    g = g_ref[...].astype(jnp.float32)
    coef = coef_ref[...].astype(jnp.float32)            # (c, 1)
    weighted = jnp.einsum("crl,co->rl", g, coef)
    v_new = momentum * v_ref[...].astype(jnp.float32) + weighted
    w_new = w_ref[...].astype(jnp.float32) - lr * v_new
    v_out_ref[...] = v_new.astype(v_out_ref.dtype)
    w_out_ref[...] = w_new.astype(w_out_ref.dtype)


def ps_update_2d(w: jax.Array, v: jax.Array, g: jax.Array, coef: jax.Array,
                 *, momentum: float, lr: float, row_block: int,
                 interpret: bool) -> Tuple[jax.Array, jax.Array]:
    """w/v: (R, 128); g: (c, R, 128); coef: (c,) fp32."""
    R = w.shape[0]
    c = g.shape[0]
    grid = (R // row_block,)
    coef2 = coef.reshape(c, 1).astype(jnp.float32)
    kernel = functools.partial(_kernel, momentum=momentum, lr=lr)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((row_block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((row_block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((c, row_block, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((row_block, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(coef2, w, v, g)


def ps_update_flat(w_flat: jax.Array, v_flat: jax.Array, g_flat: jax.Array,
                   coef: jax.Array, *, momentum: float = 0.9,
                   lr: float = 1.0, row_block: int = DEFAULT_ROW_BLOCK,
                   interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Flat-vector entry point.  w/v: (D,); g: (c, D); coef: (c,).

    Pads D up to a multiple of row_block*128 and reshapes to (R, 128) tiles.
    """
    D = w_flat.shape[0]
    c = g_flat.shape[0]
    tile = row_block * LANES
    Dp = ((D + tile - 1) // tile) * tile
    pad = Dp - D
    wp = jnp.pad(w_flat, (0, pad)).reshape(-1, LANES)
    vp = jnp.pad(v_flat, (0, pad)).reshape(-1, LANES)
    gp = jnp.pad(g_flat, ((0, 0), (0, pad))).reshape(c, -1, LANES)
    w2, v2 = ps_update_2d(wp, vp, gp, coef, momentum=momentum, lr=lr,
                          row_block=row_block, interpret=interpret)
    return w2.reshape(-1)[:D], v2.reshape(-1)[:D]


def ps_update_tree(params, velocity, grads_list, coef, *, momentum=0.9,
                   lr=1.0, interpret: bool = False):
    """Pytree convenience wrapper: stacks the c gradient pytrees, flattens
    every leaf and runs the fused kernel leaf-by-leaf."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_v = jax.tree_util.tree_leaves(velocity)
    flat_gs = [jax.tree_util.tree_leaves(g) for g in grads_list]
    coef = jnp.asarray(coef, jnp.float32)
    new_p, new_v = [], []
    for i, (p, v) in enumerate(zip(flat_p, flat_v)):
        g = jnp.stack([fg[i].reshape(-1) for fg in flat_gs])
        w2, v2 = ps_update_flat(p.reshape(-1), v.reshape(-1), g, coef,
                                momentum=momentum, lr=lr,
                                row_block=min(DEFAULT_ROW_BLOCK,
                                              max(1, p.size // LANES)),
                                interpret=interpret)
        new_p.append(w2.reshape(p.shape).astype(p.dtype))
        new_v.append(v2.reshape(v.shape).astype(v.dtype))
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            jax.tree_util.tree_unflatten(treedef, new_v))
