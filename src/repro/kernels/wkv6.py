"""RWKV6 WKV recurrence Pallas kernel (chunked, data-dependent decay).

Per head, recurrence over time with per-channel decay on the key dim:

    S_t   = diag(exp(w_t)) · S_{t-1} + k_t ⊗ v_t
    out_t = r_t · (S_{t-1} + diag(u) · (k_t ⊗ v_t))

Chunked closed form (chunk length Q, state S₀ entering the chunk):

    out_i = (r_i ∘ e_i) · S₀  +  Σ_{j<i} [Σ_p r_{i,p} k_{j,p} E_{ijp}] v_j
            + (r_i ∘ u ∘ k_i) · v_i
    E_ijp = exp(cum_{i-1,p} − cum_{j,p}) ∈ (0, 1]   (cum = inclusive cumsum w)
    e_i   = exp(cum_{i-1})
    S'    = diag(exp(cum_Q)) S₀ + Σ_j (k_j ∘ exp(cum_Q − cum_j)) ⊗ v_j

Because the decay is per-channel the intra-chunk pair term needs the
(Q, Q, P) tensor E — we keep Q small (32) so the tile is ≤ 256 kB fp32 in
VMEM.  All exponent arguments are ≤ 0, so the math is stable by
construction.  Grid (batch, heads, chunks), chunk axis sequential with the
(P, P) state in scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_out_ref,
                state_scr, *, Q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)       # log decay, < 0
    u = u_ref[0].astype(jnp.float32)                # (P,)

    cum = jnp.cumsum(w, axis=0)                     # (Q, P) inclusive
    cum_excl = cum - w                              # cum_{i-1}
    e_in = jnp.exp(cum_excl)                        # (Q, P) decay into step i

    state = state_scr[...]                          # (P_k, P_v)
    # inter-chunk: out_i += (r_i ∘ e_i) · S0
    y_inter = jnp.dot(r * e_in, state,
                      preferred_element_type=jnp.float32)            # (Q, Pv)

    # intra-chunk pair term: A_ij = Σ_p r_ip k_jp exp(cum_excl_i − cum_j), j<i
    diff = cum_excl[:, None, :] - cum[None, :, :]   # (Q, Q, P), ≤0 for j<i
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    E = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("ip,jp,ijp->ij", r, k, E)        # (Q, Q)
    y_intra = jnp.dot(A, v, preferred_element_type=jnp.float32)

    # diagonal (bonus) term: (r_i ∘ u ∘ k_i) · v_i
    y_diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v

    y_ref[0, :, 0, :] = (y_inter + y_intra + y_diag).astype(y_ref.dtype)

    # state update
    decay_out = jnp.exp(cum[-1][:, None])           # (P, 1)
    kw = k * jnp.exp(cum[-1][None, :] - cum)        # (Q, P)
    state_new = decay_out * state + jnp.dot(
        kw.T, v, preferred_element_type=jnp.float32)
    state_scr[...] = state_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
         init_state=None, interpret: bool = False):
    """r/k/v/w: (B, S, H, P); u: (H, P).  Returns (out (B,S,H,P) fp32,
    final_state (B, H, P, P)).

    Note: ``init_state`` must be zeros for the kernel path (scratch is
    zero-initialised); pass non-zero states only to the recurrent reference.
    """
    Bt, S, H, P = r.shape
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        w = jnp.pad(w, zpad)   # pad w with 0 ⇒ exp(0)=1 decay, harmless tail
    Sp = nc * Q

    kernel = functools.partial(_wkv_kernel, Q=Q, n_chunks=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, P), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, Sp, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, P, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
    return y[:, :S], final_state
