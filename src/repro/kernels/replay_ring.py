"""Fused replay megakernel over the weight ring buffer (DESIGN.md §12).

The compiled replay engine (``core/engine.py``) executes one update event
per ``lax.scan`` step against a (K, D) ring of parameter snapshots.  The
stock body is a chain of XLA ops — ring gather, combine einsum, optimizer
update, dynamic-update-slice write — each a separate pass over D.  This
module fuses the whole event into ONE ``pallas_call``:

    ring-read(prev row) → [+ error-feedback residue] → combine/sequential
    optimizer event → quantize → ring-write(slot row) [+ residue write]

tiled over D exactly like ``kernels/ps_update.py`` ((R, 128) lanes,
row-block grid).  Two properties make it one launch per scan step:

* **Scalar-prefetch ring indices** — ``prev``/``slot`` (and the per-slot
  ``ts`` rows for the what-if kernel) arrive as a scalar-prefetch operand
  (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps pick the
  ring *rows* dynamically per launch while the grid stays static.
* **In-place ring writes** — ``input_output_aliases`` aliases the ring (and
  state/residue) inputs onto the outputs, so the kernel updates one
  (1, row_block, 128) slot-row block in place instead of copying the whole
  K·D ring per event.  Under ``lax.scan`` with a donated carry this is the
  difference between the ring living in memory once vs. three times.

Compressed ring (``ring_dtype == bf16``): the ring rows store bf16
snapshots while the update math stays fp32.  The quantization error is not
lost — an fp32 **error-feedback residue** vector carries ``w − q(w)`` of the
*latest* row and is re-added before the next update, so the master weight
chain is exactly the fp32 trajectory *given the gradients*; the only
approximation is that gradients are evaluated at quantized snapshots
(tests/test_engine_megakernel.py pins both halves of that statement).

The **what-if** kernel goes one step further for trace-driven studies on
big-model shapes: for problems whose flat gradient is a closed form
(``g = a ⊙ (w_pulled − w*)``, the quadratic family), the c per-slot
gradients are computed *inside* the kernel, one (row_block, 128) tile at a
time over a (rows, c) grid — the (c, D) pulled-weight and gradient
matrices are never materialized, so peak memory drops from O((K + c)·D)
to O(K·D_bytes + D) and the feasible D grows ~10–100× (EXPERIMENTS.md
§Sim, max-feasible-D table).

Both kernels are **width-agnostic**: D is whatever the caller's last axis
is, so under ``placement="spmd"`` (DESIGN.md §13) the engine invokes them
per-device on the shard-local ``(K, padded_width(⌈D/S⌉))`` ring slice
inside ``shard_map`` — the grid/BlockSpec machinery never sees the mesh,
and the elementwise event math guarantees per-shard applies are exactly
the shard slices of the single-device apply.

Off-accelerator every entry point selects ``interpret=True`` automatically
(the CPU-CI fallback contract of ``kernels/ops.py``); the module-level
``pallas_dispatches``/``last_interpret`` counters record which dispatch
branch built the kernel so tests can assert the fused path is really the
one exercised.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ps_update import DEFAULT_ROW_BLOCK, LANES
from repro.optim.spec import UpdateSpec, update_event

# trace-time dispatch telemetry: how many times a replay megakernel was
# built (counted at trace time — once per compiled scan, not per step) and
# whether the last build ran in interpret mode.  Tests assert on these to
# pin the CPU-CI fallback branch.
pallas_dispatches = 0
last_interpret: Optional[bool] = None


def default_interpret() -> bool:
    """Pallas compiles on TPU only; everywhere else run the kernel in
    interpret mode (same math, XLA-executed) — tier-1 CI never skips the
    fused path, it just doesn't get TPU codegen."""
    return jax.default_backend() != "tpu"


def row_block_for(width: int) -> int:
    return int(min(DEFAULT_ROW_BLOCK, max(1, -(-width // LANES))))


def padded_width(width: int) -> int:
    """Ring width padded so (width / 128) rows tile evenly into row blocks
    (zero padding is inert through sgd/momentum/adagrad events)."""
    tile = row_block_for(width) * LANES
    return -(-width // tile) * tile


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------
def _tile_events(spec: UpdateSpec, mode: str, c: int, coef_ref, lrs_ref,
                 w, s, g_ref):
    """The update events on one (rb, LANES) tile — same math as
    ``ps_update._events`` but with the combine contraction phrased exactly
    like ``optim.apply_event_flat``'s ``einsum("cd,c->d")`` (the stock
    scan body), so the fp32 megakernel replay is BITWISE-equal to the
    stock path (the ``crl,co->rl`` einsum lowers with a different
    accumulation and drifts by 1 ulp)."""
    if mode == "combine":
        gf = g_ref[...].astype(jnp.float32).reshape(c, -1)
        ghat = jnp.einsum("cd,c->d", gf,
                          coef_ref[...].astype(jnp.float32).reshape(c))
        return update_event(spec, w, s, ghat.reshape(w.shape), lrs_ref[0, 0])
    for i in range(c):                                    # c is static
        gi = coef_ref[i, 0] * g_ref[i].astype(jnp.float32)
        w, s = update_event(spec, w, s, gi, lrs_ref[i, 0])
    return w, s


def _apply_kernel(idx_ref, *refs, spec: UpdateSpec, mode: str, c: int,
                  stateful: bool, ef: bool):
    """One fused ring event, external gradients.  Grid: (row_blocks,).

    ``idx_ref`` = [prev_row, slot_row].  Input blocks (after the scalar
    prefetch): coef (c,1), lrs (c,1), ring (1,rb,L) at row prev, state
    (rb,L) if stateful, residue (rb,L) if ef, grads (c,rb,L).  Outputs
    (aliased in-place): ring block at row slot, state, residue."""
    n_in = 3 + int(stateful) + int(ef) + 1
    ins, outs = refs[:n_in], refs[n_in:]
    coef_ref, lrs_ref, ring_ref = ins[0], ins[1], ins[2]
    k = 3
    s_ref = ins[k] if stateful else None
    k += int(stateful)
    res_ref = ins[k] if ef else None
    k += int(ef)
    g_ref = ins[k]
    ring_out = outs[0]
    s_out = outs[1] if stateful else None
    res_out = outs[1 + int(stateful)] if ef else None

    w = ring_ref[0].astype(jnp.float32)
    if ef:
        w = w + res_ref[...]                     # re-add quantization error
    s = s_ref[...].astype(jnp.float32) if stateful else None
    w, s = _tile_events(spec, mode, c, coef_ref, lrs_ref, w, s, g_ref)
    q = w.astype(ring_out.dtype)
    ring_out[0] = q
    if stateful:
        s_out[...] = s
    if ef:
        res_out[...] = w - q.astype(jnp.float32)


def _whatif_kernel(idx_ref, *refs, spec: UpdateSpec, c: int,
                   stateful: bool, ef: bool):
    """One fused ring event with IN-KERNEL quadratic gradients.

    Grid: (row_blocks, c) — the inner grid axis streams the c slots, each
    reading its pulled ring row block (``idx_ref[2 + j]``) and accumulating
    ``coef_j · a ⊙ (w_ts − w*)`` into a VMEM scratch tile; the last slot
    runs the optimizer event and writes ring/state/residue.  The (c, D)
    gradient matrix never exists.  Combine mode only; the caller guarantees
    K ≥ 2 so the slot row written here is never also a pulled row of a
    *later* row block in this launch's column range (blocks are column-
    disjoint, so even max-stale reads of the slot row are safe)."""
    n_in = 6 + int(stateful) + int(ef)
    ins, outs, acc_ref = refs[:n_in], refs[n_in:-1], refs[-1]
    coef_ref, lrs_ref = ins[0], ins[1]
    ring_ts_ref, ring_prev_ref = ins[2], ins[3]
    a_ref, ws_ref = ins[4], ins[5]
    k = 6
    s_ref = ins[k] if stateful else None
    k += int(stateful)
    res_ref = ins[k] if ef else None
    ring_out = outs[0]
    s_out = outs[1] if stateful else None
    res_out = outs[1 + int(stateful)] if ef else None

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g_j = a_ref[...] * (ring_ts_ref[0].astype(jnp.float32) - ws_ref[...])
    acc_ref[...] += coef_ref[j, 0] * g_j

    @pl.when(j == c - 1)
    def _apply():
        w = ring_prev_ref[0].astype(jnp.float32)
        if ef:
            w = w + res_ref[...]
        s = s_ref[...].astype(jnp.float32) if stateful else None
        w2, s2 = update_event(spec, w, s, acc_ref[...], lrs_ref[0, 0])
        q = w2.astype(ring_out.dtype)
        ring_out[0] = q
        if stateful:
            s_out[...] = s2
        if ef:
            res_out[...] = w2 - q.astype(jnp.float32)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def ring_apply(ring: jax.Array, s: Optional[jax.Array],
               res: Optional[jax.Array], g: jax.Array, coef: jax.Array,
               lrs: jax.Array, idx: jax.Array, *, spec: UpdateSpec,
               mode: str = "combine", row_block: Optional[int] = None,
               interpret: Optional[bool] = None
               ) -> Tuple[jax.Array, Optional[jax.Array],
                          Optional[jax.Array]]:
    """ONE fused ring event: read row ``idx[0]``, apply the c-gradient
    update, write row ``idx[1]`` in place.

    ``ring``: (K, Dp) in ring dtype (fp32 or bf16), Dp a
    :func:`padded_width` multiple; ``s``: (Dp,) fp32 optimizer state or
    None (sgd); ``res``: (Dp,) fp32 error-feedback residue or None (fp32
    ring); ``g``: (c, Dp) fp32; ``coef``/``lrs``: (c,); ``idx``: (2,)
    int32 [prev, slot].  Returns the updated (ring, s, res)."""
    global pallas_dispatches, last_interpret
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no kernel path")
    if interpret is None:
        interpret = default_interpret()
    pallas_dispatches += 1
    last_interpret = bool(interpret)

    K, Dp = ring.shape
    c = g.shape[0]
    if row_block is None:
        row_block = row_block_for(Dp)
    if Dp % (row_block * LANES):
        raise ValueError(f"ring width {Dp} is not a multiple of the "
                         f"{row_block}x{LANES} tile; pad via padded_width()")
    rows = Dp // LANES
    grid = (rows // row_block,)
    stateful, ef = s is not None, res is not None

    ringt = ring.reshape(K, rows, LANES)
    gt = g.reshape(c, rows, LANES)
    coef2 = coef.reshape(c, 1).astype(jnp.float32)
    lrs2 = lrs.reshape(c, 1).astype(jnp.float32)

    vec = pl.BlockSpec((c, 1), lambda i, idx: (0, 0))
    row = pl.BlockSpec((row_block, LANES), lambda i, idx: (i, 0))
    ring_in = pl.BlockSpec((1, row_block, LANES),
                           lambda i, idx: (idx[0], i, 0))
    ring_out = pl.BlockSpec((1, row_block, LANES),
                            lambda i, idx: (idx[1], i, 0))
    g_spec = pl.BlockSpec((c, row_block, LANES), lambda i, idx: (0, i, 0))

    operands = [coef2, lrs2, ringt]
    in_specs = [vec, vec, ring_in]
    out_shape = [jax.ShapeDtypeStruct(ringt.shape, ringt.dtype)]
    out_specs = [ring_out]
    # scalar prefetch counts as input 0, so the ring is input index 3
    aliases = {3: 0}
    if stateful:
        st = s.reshape(rows, LANES)
        aliases[len(operands) + 1] = len(out_shape)
        operands.append(st)
        in_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct(st.shape, st.dtype))
        out_specs.append(row)
    if ef:
        rt = res.reshape(rows, LANES)
        aliases[len(operands) + 1] = len(out_shape)
        operands.append(rt)
        in_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct(rt.shape, rt.dtype))
        out_specs.append(row)
    operands.append(gt)
    in_specs.append(g_spec)

    kernel = functools.partial(_apply_kernel, spec=spec, mode=mode, c=c,
                               stateful=stateful, ef=ef)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_specs),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(idx.astype(jnp.int32), *operands)

    ring2 = out[0].reshape(K, Dp)
    k = 1
    s2 = out[k].reshape(Dp) if stateful else None
    k += int(stateful)
    res2 = out[k].reshape(Dp) if ef else None
    return ring2, s2, res2


def ring_apply_whatif(ring: jax.Array, s: Optional[jax.Array],
                      res: Optional[jax.Array], a: jax.Array,
                      wstar: jax.Array, coef: jax.Array, lrs: jax.Array,
                      idx: jax.Array, *, spec: UpdateSpec,
                      row_block: Optional[int] = None,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array],
                                 Optional[jax.Array]]:
    """ONE fused ring event with in-kernel gradients g_j = a⊙(w_ts_j − w*).

    ``idx``: (2 + c,) int32 [prev, slot, ts_0 … ts_{c-1}].  ``a``/``wstar``:
    (Dp,) fp32 (zero-padded — padded a makes padded gradients zero, so the
    pad stays inert).  Combine mode only; requires K ≥ 2 (the engine falls
    back to the streamed jnp twin for K = 1)."""
    global pallas_dispatches, last_interpret
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no kernel path")
    if ring.shape[0] < 2:
        raise ValueError("whatif kernel needs K >= 2 (slot row must not be "
                         "a pulled row); use the jnp twin for K = 1")
    if interpret is None:
        interpret = default_interpret()
    pallas_dispatches += 1
    last_interpret = bool(interpret)

    K, Dp = ring.shape
    c = idx.shape[0] - 2
    if row_block is None:
        row_block = row_block_for(Dp)
    if Dp % (row_block * LANES):
        raise ValueError(f"ring width {Dp} is not a multiple of the "
                         f"{row_block}x{LANES} tile; pad via padded_width()")
    rows = Dp // LANES
    grid = (rows // row_block, c)
    stateful, ef = s is not None, res is not None

    ringt = ring.reshape(K, rows, LANES)
    coef2 = coef.reshape(c, 1).astype(jnp.float32)
    lrs2 = lrs.reshape(c, 1).astype(jnp.float32)

    vec = pl.BlockSpec((c, 1), lambda i, j, idx: (0, 0))
    row = pl.BlockSpec((row_block, LANES), lambda i, j, idx: (i, 0))
    ring_ts = pl.BlockSpec((1, row_block, LANES),
                           lambda i, j, idx: (idx[2 + j], i, 0))
    ring_prev = pl.BlockSpec((1, row_block, LANES),
                             lambda i, j, idx: (idx[0], i, 0))
    ring_out = pl.BlockSpec((1, row_block, LANES),
                            lambda i, j, idx: (idx[1], i, 0))

    at = a.reshape(rows, LANES)
    wt = wstar.reshape(rows, LANES)
    operands = [coef2, lrs2, ringt, ringt, at, wt]
    in_specs = [vec, vec, ring_ts, ring_prev, row, row]
    out_shape = [jax.ShapeDtypeStruct(ringt.shape, ringt.dtype)]
    out_specs = [ring_out]
    aliases = {4: 0}          # alias the prev-row ring operand (input idx 4)
    if stateful:
        st = s.reshape(rows, LANES)
        aliases[len(operands) + 1] = len(out_shape)
        operands.append(st)
        in_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct(st.shape, st.dtype))
        out_specs.append(row)
    if ef:
        rt = res.reshape(rows, LANES)
        aliases[len(operands) + 1] = len(out_shape)
        operands.append(rt)
        in_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct(rt.shape, rt.dtype))
        out_specs.append(row)

    kernel = functools.partial(_whatif_kernel, spec=spec, c=c,
                               stateful=stateful, ef=ef)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((row_block, LANES), jnp.float32)]),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(idx.astype(jnp.int32), *operands)

    ring2 = out[0].reshape(K, Dp)
    k = 1
    s2 = out[k].reshape(Dp) if stateful else None
    k += int(stateful)
    res2 = out[k].reshape(Dp) if ef else None
    return ring2, s2, res2
