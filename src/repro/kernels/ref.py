"""Pure-jnp oracles for every Pallas kernel (no Pallas, no chunking tricks).

Each oracle is the most literal possible implementation of the math — used
by tests (``tests/test_kernels.py``) and the hypothesis shape sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# ps_update
# ---------------------------------------------------------------------------
def ps_update_ref(w, v, g, coef, *, momentum: float, lr: float):
    """w/v: (D,); g: (c, D); coef: (c,)."""
    weighted = jnp.einsum("cd,c->d", g.astype(jnp.float32),
                          coef.astype(jnp.float32))
    v_new = momentum * v.astype(jnp.float32) + weighted
    w_new = w.astype(jnp.float32) - lr * v_new
    return w_new.astype(w.dtype), v_new.astype(v.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal: bool, window: int = 0):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) — materialized softmax."""
    from repro.models.attention import naive_attention
    return naive_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# ssm (sequential recurrence — the definitional oracle)
# ---------------------------------------------------------------------------
def ssm_ref(x, a, Bm, Cm):
    """x: (B,S,H,P); a: (B,S,H); Bm/Cm: (B,S,N).
    S_t = exp(a_t)·S_{t-1} + B_t ⊗ x_t ;  y_t = C_t · S_t."""
    Bt, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, t):
        state = (jnp.exp(af[:, t])[..., None, None] * state
                 + jnp.einsum("bn,bhp->bhnp", Bf[:, t], xf[:, t]))
        y = jnp.einsum("bn,bhnp->bhp", Cf[:, t], state)
        return state, y

    state0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    final, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


# ---------------------------------------------------------------------------
# wkv6 (sequential recurrence)
# ---------------------------------------------------------------------------
def wkv6_ref(r, k, v, w, u):
    """r/k/v/w: (B,S,H,P); u: (H,P).  Literal recurrence."""
    from repro.models.rwkv import wkv_recurrent
    return wkv_recurrent(r, k, v, w, u)
