"""Blockwise (flash) attention Pallas kernel for TPU.

Online-softmax attention with causal and sliding-window masks.  Grid is
(batch·kv_heads·groups, q_blocks, kv_blocks); the kv axis is the innermost
*arbitrary* (sequential) dimension so the output block is revisited with
running (m, l, acc) carried in VMEM scratch — the canonical TPU flash
pattern.  Q/K/V tiles are MXU-aligned (block sizes multiples of 128 on the
head dim enter the systolic array directly).

GQA is handled by folding the query-group dimension into the row dimension
of the Q tile: q is laid out (B, KV, G, S, D) and each program attends one
(b, kv) pair's G·blk_q query rows against that kv head's K/V stream.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 blk_q: int, blk_k: int, n_kv_blocks: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked tiles (strictly above the causal diagonal / beyond
    # the sliding window) — no MXU work, no VMEM traffic for those blocks
    k0 = ki * blk_k
    q_lo = qi * blk_q
    q_hi = q_lo + blk_q - 1
    live = jnp.bool_(True)
    if causal:
        live = live & (k0 <= q_hi)
    if window > 0:
        live = live & (k0 + blk_k - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        _attn_block(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, qi, ki,
                    scale=scale, causal=causal, window=window,
                    blk_q=blk_q, blk_k=blk_k, seq_k=seq_k)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def _attn_block(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, qi, ki, *,
                scale, causal, window, blk_q, blk_k, seq_k):
    q = q_ref[0].astype(jnp.float32)          # (G, blk_q, D)
    k = k_ref[0].astype(jnp.float32)          # (blk_k, D)
    v = v_ref[0].astype(jnp.float32)          # (blk_k, D)
    G, D = q.shape[0], q.shape[2]
    Gq = G * blk_q
    q2 = q.reshape(Gq, D)

    s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, (G, blk_q), 1).reshape(Gq)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, blk_k), 1).reshape(blk_k)
    mask = (k_pos[None, :] < seq_k)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new


def flash_attention_bkgsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool, window: int = 0,
                          blk_q: int = 128, blk_k: int = 128,
                          interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, Sq, D); k/v: (B, KV, Sk, D).  Returns q-shaped out."""
    Bb, KV, G, Sq, D = q.shape
    Sk = k.shape[2]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    nq = -(-Sq // blk_q)
    nk = -(-Sk // blk_k)
    pq, pk = nq * blk_q - Sq, nk * blk_k - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    qf = q.reshape(Bb * KV, G, nq * blk_q, D)
    kf = k.reshape(Bb * KV, nk * blk_k, D)
    vf = v.reshape(Bb * KV, nk * blk_k, D)

    kernel = functools.partial(
        _attn_kernel, scale=float(1.0 / np.sqrt(D)), causal=causal,
        window=window, blk_q=blk_q, blk_k=blk_k, n_kv_blocks=nk, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(Bb * KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, blk_q, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, blk_q, D), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * blk_q, 1), jnp.float32),
            pltpu.VMEM((G * blk_q, 1), jnp.float32),
            pltpu.VMEM((G * blk_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(Bb, KV, G, nq * blk_q, D)
    return out[:, :, :, :Sq, :]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Convenience layout adapter.  q: (B, Sq, H, D); k/v: (B, Sk, KV, D).
    Returns (B, Sq, H, D) — matches ``models.attention`` conventions."""
    Bb, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qt = q.transpose(0, 2, 1, 3).reshape(Bb, KV, G, Sq, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bkgsd(qt, kt, vt, causal=causal, window=window,
                                blk_q=blk_q, blk_k=blk_k,
                                interpret=interpret)
    return out.reshape(Bb, H, Sq, D).transpose(0, 2, 1, 3)
