"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced JAX ops per grid point, which validates the exact TPU
program logic.  On TPU backends they compile to Mosaic.  Callers never pass
``interpret`` themselves; it is derived from the backend once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ps_update as _ps
from repro.kernels import ssm_scan as _ssm
from repro.kernels import wkv6 as _wkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               blk_q=blk_q, blk_k=blk_k,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("momentum", "lr", "row_block"))
def ps_update(w_flat, v_flat, g_flat, coef, *, momentum: float = 0.9,
              lr: float = 1.0, row_block: int = _ps.DEFAULT_ROW_BLOCK):
    return _ps.ps_update_flat(w_flat, v_flat, g_flat, coef,
                              momentum=momentum, lr=lr, row_block=row_block,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("spec", "mode", "row_block"))
def ps_apply(w_flat, s_flat, g_flat, coef, lrs, *, spec, mode: str = "combine",
             row_block=None):
    """General fused applyUpdate (sgd/momentum/adagrad; see repro.optim)."""
    return _ps.ps_apply(w_flat, s_flat, g_flat, coef, lrs, spec=spec,
                        mode=mode, row_block=row_block,
                        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan(x, a, Bm, Cm, *, chunk: int = 256):
    return _ssm.ssm_scan(x, a, Bm, Cm, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, *, chunk: int = _wkv.DEFAULT_CHUNK, init_state=None):
    del init_state   # kernel path starts from zero state (see wkv6 docstring)
    return _wkv.wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret())
