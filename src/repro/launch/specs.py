"""ShapeDtypeStruct stand-ins for every model input + the step functions the
dry-run lowers.  No device allocation anywhere (weak-type-correct, shardable).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import (InputShape, ModelConfig, RunConfig, INPUT_SHAPES,
                          validate_pairing)
from repro.core.distributed import init_opt_state, make_train_step
from repro.launch import sharding as shd
from repro.launch.mesh import data_axes, n_learners
from repro.models import init_caches, init_model, model_loss
from repro.models.layers import dtype_of
from repro.serve.engine import prefill_step, serve_step


def _sds(shape, dtype, mesh: Mesh, spec: P) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _model_axis_size(mesh: Mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                with_labels: bool = True,
                mode_override: str = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Input batch ShapeDtypeStructs for (cfg, shape) on ``mesh``.
    Sequence-parallel archs shard the seq dim over `model` (train/prefill)."""
    B, S = shape.global_batch, shape.seq_len
    mode = mode_override or shd.parallelism_mode(cfg, _model_axis_size(mesh))
    bspec, sspec = shd.batch_spec_for(cfg, mesh, mode, B, S)
    dt = dtype_of(cfg.dtype)

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio":
        out["frames"] = _sds((B, S, cfg.d_model), dt, mesh,
                             P(bspec, sspec, None))
    elif cfg.frontend == "vision":
        npfx = cfg.n_prefix_embeds
        _, pspec = shd.batch_spec_for(cfg, mesh, mode, B, npfx)
        _, tspec = shd.batch_spec_for(cfg, mesh, mode, B, S - npfx)
        out["patches"] = _sds((B, npfx, cfg.d_model), dt, mesh,
                              P(bspec, pspec, None))
        out["tokens"] = _sds((B, S - npfx), jnp.int32, mesh,
                             P(bspec, tspec))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, P(bspec, sspec))
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32, mesh, P(bspec, sspec))
        out["loss_mask"] = _sds((B, S), jnp.float32, mesh, P(bspec, sspec))
    return out


def params_specs(cfg: ModelConfig, mesh: Mesh, fsdp: bool,
                 mode_override: str = None):
    shapes = jax.eval_shape(
        functools.partial(init_model, cfg), jax.random.PRNGKey(0))
    mode = mode_override or shd.parallelism_mode(cfg, _model_axis_size(mesh))
    # ZeRO-3 (§Perf B2): seq-parallel giants shard params over data AND
    # model (weights otherwise replicated over `model` would not fit HBM)
    from repro.launch.mesh import n_learners as _nl
    fsdp_wide = (mode == "seq" and fsdp and
                 cfg.param_count() * 2 / _nl(mesh) > 8e9)
    shardings = shd.param_shardings(shapes, mesh, fsdp, mode=mode,
                                    fsdp_wide=fsdp_wide)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def opt_specs(run: RunConfig, pspecs):
    """Optimizer state mirrors parameter shardings."""
    shapes = jax.eval_shape(functools.partial(init_opt_state, run), pspecs)

    def share(path, leaf):
        # momentum/adagrad/adam leaves mirror the corresponding param leaf;
        # scalar counters are replicated.
        return leaf
    # jax.eval_shape on ShapeDtypeStructs with shardings propagates them for
    # identical-shaped outputs; for safety rebuild explicitly:
    flat_p = {shd._path_str(p): l.sharding for p, l in
              jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    def assign(path, leaf):
        key = shd._path_str(path)
        # strip the opt-state prefix ("velocity/", "mu/", ...)
        sub = key.split("/", 1)[1] if "/" in key else key
        sh = flat_p.get(sub)
        if sh is None or leaf.ndim == 0:
            mesh = next(iter(flat_p.values())).mesh
            sh = NamedSharding(mesh, P())
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    shapes = jax.eval_shape(
        functools.partial(init_caches, cfg, shape.global_batch,
                          shape.seq_len))
    shardings = shd.cache_shardings(shapes, mesh, shape.global_batch)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


# ---------------------------------------------------------------------------
# the three lowerable step functions
# ---------------------------------------------------------------------------
def make_run_config(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    protocol: str = "softsync", n_softsync: int = 4,
                    engine: str = "sequential",
                    num_microbatches: int = 0,
                    attn_q_chunk: int = 1024,
                    attn_kv_chunk: int = 1024,
                    seq_par_residual: bool = False,
                    mode_override: str = None) -> Tuple[RunConfig, str]:
    lam = n_learners(mesh)
    mb = num_microbatches or shd.default_microbatches(cfg, shape)
    residual_spec = None
    mode = mode_override or shd.parallelism_mode(cfg, _model_axis_size(mesh))
    if seq_par_residual and shape.kind != "decode" and mode == "head":
        dax = data_axes(mesh)
        residual_spec = (dax if len(dax) > 1 else dax[0], "model", None)
    run = RunConfig(
        residual_spec=residual_spec,
        protocol=protocol if shape.kind == "train" else "hardsync",
        n_softsync=n_softsync,
        n_learners=lam,
        minibatch=max(1, shape.global_batch // lam),
        lr_policy=("staleness_inverse" if protocol == "softsync"
                   else "sqrt_scale"),
        optimizer="momentum",                      # the paper's optimizer
        num_microbatches=mb,
        remat=True,
        fsdp=shd.needs_fsdp(cfg, mesh),
        attn_impl="chunked",
        attn_q_chunk=attn_q_chunk,
        attn_kv_chunk=attn_kv_chunk,
    )
    return run, engine


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    run: RunConfig, engine: str = "sequential",
                    mode_override: str = None):
    """Returns (jitted_fn, arg_specs tuple) ready for .lower(*specs)."""
    skip = validate_pairing(cfg, shape)
    if skip:
        raise ValueError(f"({cfg.name} × {shape.name}) skipped: {skip}")

    pspecs = params_specs(cfg, mesh, run.fsdp, mode_override=mode_override)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        def loss_fn(p, b, sample_weights=None):
            return model_loss(cfg, run, p, b, sample_weights=sample_weights)
        step = make_train_step(run, loss_fn, engine=engine)
        ospecs = opt_specs(run, pspecs)
        bspecs = batch_specs(cfg, shape, mesh, mode_override=mode_override)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (pspecs, ospecs, bspecs)

    if shape.kind == "prefill":
        def pf(params, batch):
            return prefill_step(cfg, run, params, batch)
        bspecs = batch_specs(cfg, shape, mesh, with_labels=False,
                             mode_override=mode_override)
        fn = jax.jit(pf)
        return fn, (pspecs, bspecs)

    # decode
    def dec(params, tokens, position, caches):
        return serve_step(cfg, run, params, tokens, position, caches)
    cspecs = cache_specs(cfg, shape, mesh)
    B = shape.global_batch
    dax = data_axes(mesh)
    dsize = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a in dax:
            dsize *= s
    bspec = (dax if len(dax) > 1 else dax[0]) if B % dsize == 0 else None
    tok = _sds((B, 1), jnp.int32, mesh, P(bspec))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
    fn = jax.jit(dec, donate_argnums=(3,))
    return fn, (pspecs, tok, pos, cspecs)
