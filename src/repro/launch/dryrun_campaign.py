"""Dry-run campaign driver: all (arch × shape) × {main 16x16, main 2x16x16,
probe 16x16} as parallel subprocesses; results land in
benchmarks/results/dryrun/<job>.json.

    PYTHONPATH=src python -m repro.launch.dryrun_campaign [--workers 5]
        [--modes ...] [--force]

Each job is its own process so the 512-device XLA flag stays contained and
compiles run truly in parallel.

Caching is content-addressed, same contract as the experiments campaign
layer (DESIGN.md §15): every job's spec (arch/shape/mode/mesh + the extra
dryrun flags it implies) hashes to a ``job_hash`` stamped into the result
JSON under ``campaign``; a job is skipped only when its file exists AND the
stamp matches — so editing the job definition (or running with different
probe chunking) invalidates exactly the affected jobs.  ``--force`` re-runs
regardless.  Legacy results without a stamp count as stale.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.experiments.spec_hash import content_hash

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT_DIR = os.path.join(ROOT, "benchmarks", "results", "dryrun")

ARCHS = ["internvl2_2b", "hubert_xlarge", "rwkv6_7b", "qwen3_14b",
         "starcoder2_7b", "zamba2_7b", "llama4_maverick_400b_a17b",
         "qwen2_1_5b", "llama3_405b", "arctic_480b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def job_id(arch, shape, mode, multi):
    mesh = "2x16x16" if multi else "16x16"
    return f"{arch}__{shape}__{mode}__{mesh}"


def job_spec(arch, shape, mode, multi) -> dict:
    """Everything that determines the job's output, in canonical form."""
    spec = {"arch": arch, "shape": shape, "mode": mode,
            "mesh": "2x16x16" if multi else "16x16"}
    if mode == "probe":
        spec["q_chunk"] = 4096
        spec["kv_chunk"] = 4096
    return spec


def job_hash(arch, shape, mode, multi) -> str:
    return content_hash(job_spec(arch, shape, mode, multi))


def _is_cached(out_json: str, want_hash: str) -> bool:
    try:
        with open(out_json) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return False
    return (data.get("campaign") or {}).get("job_hash") == want_hash


def _stamp(out_json: str, arch, shape, mode, multi) -> None:
    """Write the content-address stamp into a fresh result file."""
    try:
        with open(out_json) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return   # job "succeeded" without a readable artifact: leave unstamped
    data["campaign"] = {"job_hash": job_hash(arch, shape, mode, multi),
                        "spec": job_spec(arch, shape, mode, multi)}
    with open(out_json, "w") as f:
        json.dump(data, f, indent=1, default=float)
        f.write("\n")


def run_job(arch, shape, mode, multi, timeout, force=False):
    jid = job_id(arch, shape, mode, multi)
    out_json = os.path.join(OUT_DIR, jid + ".json")
    if not force and _is_cached(out_json, job_hash(arch, shape, mode, multi)):
        return jid, "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch.replace("_", "-"), "--shape", shape,
           "--mode", mode, "--json", out_json]
    if multi:
        cmd.append("--multi-pod")
    if mode == "probe":
        cmd += ["--q-chunk", "4096", "--kv-chunk", "4096"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        status = "ok" if p.returncode == 0 else "fail"
        if status == "ok":
            _stamp(out_json, arch, shape, mode, multi)
        else:
            with open(out_json + ".err", "w") as f:
                f.write(p.stdout[-4000:] + "\n---\n" + p.stderr[-6000:])
    except subprocess.TimeoutExpired:
        status = "timeout"
        with open(out_json + ".err", "w") as f:
            f.write(f"timeout after {timeout}s")
    return jid, f"{status} ({time.time() - t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=5)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--modes", default="main,multi,probe")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--force", action="store_true",
                    help="re-run jobs even when their stamp is current")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    modes = args.modes.split(",")
    jobs = []
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            if "main" in modes:
                jobs.append((arch, shape, "main", False))
            if "multi" in modes:
                jobs.append((arch, shape, "main", True))
            if "probe" in modes:
                jobs.append((arch, shape, "probe", False))

    t0 = time.time()
    done = 0
    with ThreadPoolExecutor(max_workers=args.workers) as ex:
        futs = {ex.submit(run_job, *j, args.timeout, args.force): j
                for j in jobs}
        for fut in as_completed(futs):
            jid, status = fut.result()
            done += 1
            print(f"[{done}/{len(jobs)} {time.time()-t0:.0f}s] {jid}: "
                  f"{status}", flush=True)
    print(f"campaign done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
