"""Dry-run campaign driver: all (arch × shape) × {main 16x16, main 2x16x16,
probe 16x16} as parallel subprocesses; results land in
benchmarks/results/dryrun/<job>.json.

    PYTHONPATH=src python -m repro.launch.campaign [--workers 5] [--modes ...]

Each job is its own process so the 512-device XLA flag stays contained and
compiles run truly in parallel.
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT_DIR = os.path.join(ROOT, "benchmarks", "results", "dryrun")

ARCHS = ["internvl2_2b", "hubert_xlarge", "rwkv6_7b", "qwen3_14b",
         "starcoder2_7b", "zamba2_7b", "llama4_maverick_400b_a17b",
         "qwen2_1_5b", "llama3_405b", "arctic_480b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def job_id(arch, shape, mode, multi):
    mesh = "2x16x16" if multi else "16x16"
    return f"{arch}__{shape}__{mode}__{mesh}"


def run_job(arch, shape, mode, multi, timeout):
    jid = job_id(arch, shape, mode, multi)
    out_json = os.path.join(OUT_DIR, jid + ".json")
    if os.path.exists(out_json):
        return jid, "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch.replace("_", "-"), "--shape", shape,
           "--mode", mode, "--json", out_json]
    if multi:
        cmd.append("--multi-pod")
    if mode == "probe":
        cmd += ["--q-chunk", "4096", "--kv-chunk", "4096"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        status = "ok" if p.returncode == 0 else "fail"
        if status == "fail":
            with open(out_json + ".err", "w") as f:
                f.write(p.stdout[-4000:] + "\n---\n" + p.stderr[-6000:])
    except subprocess.TimeoutExpired:
        status = "timeout"
        with open(out_json + ".err", "w") as f:
            f.write(f"timeout after {timeout}s")
    return jid, f"{status} ({time.time() - t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=5)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--modes", default="main,multi,probe")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    modes = args.modes.split(",")
    jobs = []
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            if "main" in modes:
                jobs.append((arch, shape, "main", False))
            if "multi" in modes:
                jobs.append((arch, shape, "main", True))
            if "probe" in modes:
                jobs.append((arch, shape, "probe", False))

    t0 = time.time()
    done = 0
    with ThreadPoolExecutor(max_workers=args.workers) as ex:
        futs = {ex.submit(run_job, *j, args.timeout): j for j in jobs}
        for fut in as_completed(futs):
            jid, status = fut.result()
            done += 1
            print(f"[{done}/{len(jobs)} {time.time()-t0:.0f}s] {jid}: "
                  f"{status}", flush=True)
    print(f"campaign done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
