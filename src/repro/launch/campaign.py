"""DEPRECATED alias — renamed to ``repro.launch.dryrun_campaign`` to free
the ``campaign`` name for the experiments campaign layer (DESIGN.md §15):

    PYTHONPATH=src python -m repro.launch.dryrun_campaign

Importing from here keeps working; ``python -m repro.launch.campaign`` too.
"""

from __future__ import annotations

import sys

from repro.launch.dryrun_campaign import (ARCHS, OUT_DIR,  # noqa: F401
                                          ROOT, SHAPES, job_hash, job_id,
                                          job_spec, main, run_job)

if __name__ == "__main__":
    print("[launch.campaign] deprecated: use `python -m "
          "repro.launch.dryrun_campaign`", file=sys.stderr)
    main()
