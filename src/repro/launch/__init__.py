"""Launcher: mesh, sharding, dry-run, roofline, train CLI."""
