"""Three-term roofline from compiled dry-run artifacts (no hardware).

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory term     = HLO_bytes / (chips × 819 GB/s)
    collective term = collective_bytes / (chips × 50 GB/s per ICI link)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed.  Collective
bytes are parsed from the HLO text: we sum *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with a ring-algorithm wire factor (all-reduce moves ≈2× its operand bytes;
the others ≈1×).  cost_analysis numbers on a partitioned module are
per-device, so terms divide by per-chip peaks directly.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# result shapes like `bf16[16,128,1024]{2,1,0}` or tuples `(f32[8], f32[8])`
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# `%name = <shape(s)> <collective-kind>(...operands...)`
_OP_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _wire_bytes(kind: str, result_bytes: int, group: int) -> float:
    """Per-device wire traffic for a ring implementation.

    all-gather result = gathered tensor; each device sends its (1/g) shard
    (g−1) times ⇒ wire ≈ result·(g−1)/g.
    all-reduce (≡ reduce-scatter + all-gather) ⇒ ≈ 2·result·(g−1)/g.
    reduce-scatter result = the shard; input = result·g ⇒ ≈ result·(g−1).
    all-to-all: each device keeps 1/g, sends the rest ⇒ ≈ result·(g−1)/g.
    collective-permute: one send per device ⇒ result.
    """
    g = max(2, group)
    f = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "collective-permute":
        return float(result_bytes)
    return result_bytes * f


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes per collective kind, parsed from HLO text.

    NOTE: while-loop (lax.scan) bodies appear once in the text, so collectives
    inside scans are counted once — the dry-run probes therefore lower with
    RunConfig.unroll=True so every structural loop is unrolled.
    """
    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    # pass 1: group sizes for async starts (the -done line lacks the attr)
    start_groups: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if m and m.group(3) == "-start":
            name = line.split("=", 1)[0].strip().lstrip("%")
            gm = _GROUPS_RE.search(line)
            start_groups[name] = int(gm.group(2)) if gm else 2
    # pass 2: count sync ops and -done results
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        shape_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-start":
            continue   # counted at the matching -done (clean result shape)
        if startdone == "-done":
            om = re.search(r"\(%?([\w.\-]+)", line[m.end() - 1:])
            group = start_groups.get(om.group(1), 2) if om else 2
        else:
            gm = _GROUPS_RE.search(line)
            group = int(gm.group(2)) if gm else 2
        totals[kind] += _wire_bytes(kind, _shape_bytes(shape_str), group)
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device (wire)
    model_flops: float          # 6·N·D (or 6·N_active·D) total, fwd+bwd
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
        }


RING_DTYPE_BYTES = {"fp32": 4, "bf16": 2}

# optimizer state slots carried next to the ring (fp32 each): sgd none,
# momentum/adagrad one (velocity / accumulator), adamw two (m, v)
_OPT_STATE_SLOTS = {"sgd": 0, "momentum": 1, "adagrad": 1, "adamw": 2}


def ring_bytes(K: int, D: int, ring_dtype: str = "fp32",
               optimizer: str = "momentum", donated: bool = True) -> Dict:
    """Device-resident bytes of the replay engine's hot-loop carry
    (DESIGN.md §12): the (K, D) weight ring in ``ring_dtype``, the fp32
    optimizer state, and — with a compressed (bf16) ring — the fp32
    error-feedback residue of the latest row.  ``donated=False`` models
    the pre-megakernel scan, whose undonated carry is double-buffered
    across dispatches (2× every term).  This is the feasibility limit the
    what-if replay runs against: max feasible D ≈ HBM / bytes_per_param.
    """
    per = RING_DTYPE_BYTES.get(ring_dtype)
    if per is None:
        raise ValueError(f"unknown ring_dtype {ring_dtype!r}; expected one "
                         f"of {sorted(RING_DTYPE_BYTES)}")
    slots = _OPT_STATE_SLOTS.get(optimizer, 1)
    ring = K * D * per
    state = slots * D * 4
    residue = D * 4 if ring_dtype == "bf16" else 0
    mult = 1 if donated else 2
    total = (ring + state + residue) * mult
    return {
        "ring_bytes": ring * mult,
        "state_bytes": state * mult,
        "residue_bytes": residue * mult,
        "total_bytes": total,
        "bytes_per_param": total / D if D else 0.0,
    }


def normalize_cost_analysis(cost) -> Dict:
    """``compiled.cost_analysis()`` across jaxlib versions: older releases
    return a per-partition list of dicts (one entry on a single module),
    newer ones return the dict directly.  Normalize to one flat dict so
    every consumer can ``cost.get("flops")`` without version checks."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: Dict = {}
        for entry in cost:
            for k, v in dict(entry).items():
                merged[k] = merged.get(k, 0.0) + v
        return merged
    return dict(cost)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N·D for inference,
    with N = active params (MoE) and D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyse(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: Dict, hlo_text: str, mf: float) -> Roofline:
    coll = collective_bytes(hlo_text)
    cost = normalize_cost_analysis(cost)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=coll["total"],
        model_flops=mf,
        coll_breakdown=coll,
    )
