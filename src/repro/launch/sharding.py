"""Sharding policy: PartitionSpecs for parameters, inputs, caches.

Two tensor-parallel modes, chosen per architecture (DESIGN.md §9):

* **head-parallel** (``n_heads % model_axis == 0``, likewise for SSM/RWKV
  head counts): Megatron-style.  Attention Q/O sharded over heads (K/V
  replicated when the GQA kv count does not divide — they are small), MLP
  column→row parallel, Mamba/RWKV channel dims sharded on head boundaries.
  Used by: llama3-405b (128H), internvl2 (16H), hubert (16H), zamba2
  (32H attn / 112 ssm heads), rwkv6 (64 heads).

* **sequence-parallel** (indivisible head counts: qwen2 12H, qwen3 40H,
  starcoder2 36H, arctic 56H, llama4 40H): weights replicated over `model`,
  activations sharded over the *sequence* dim on `model`.  Attention induces
  a K/V all-gather (small under GQA); everything else is token-local.  This
  avoids both redundant compute and the giant partial-sum all-reduces a
  row-parallel fallback would cause.

MoE experts are always expert-parallel over `model` (E = 128 = 8 experts per
shard).  FSDP (≥50 B params, or ≥5 B in seq-parallel mode where weights are
otherwise replicated over `model`) additionally shards parameters over the
learner (`data`/`pod`) axes.  The layer-stack axis (dim 0 of every ``units``
leaf) is the scan axis — never sharded.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig, RunConfig
from repro.launch.mesh import data_axes


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _data_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in data_axes(mesh)]))


def _dspec(mesh: Mesh):
    dax = data_axes(mesh)
    return dax if len(dax) > 1 else dax[0]


def parallelism_mode(cfg: ModelConfig, model_size: int) -> str:
    """'head' or 'seq' — see module docstring."""
    from repro import config as C
    if cfg.has_attention and cfg.n_heads % model_size != 0:
        return "seq"
    if C.BLOCK_MAMBA in cfg.block_pattern and \
            cfg.ssm_n_heads % model_size != 0:
        return "seq"
    if C.BLOCK_RWKV in cfg.block_pattern and \
            cfg.rwkv_n_heads % model_size != 0:
        return "seq"
    return "head"


def needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    mode = parallelism_mode(cfg, _axis_size(mesh, "model"))
    threshold = 5e9 if mode == "seq" else 5e10
    return cfg.param_count() > threshold


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def _head_spec(name: str, shape, ms: int, F) -> Optional[tuple]:
    """Per-leaf spec (head-parallel mode).  `F` = fsdp axes or None.
    `name` is the final path segment with its parent (e.g. 'attn/w_q')."""
    def div(d):
        return shape[d] % ms == 0 and shape[d] >= ms

    if name.endswith("/w_q"):
        return (F, "model", None) if div(1) else (F, None, None)
    if name.endswith("/w_k") and len(shape) == 3 or \
            name.endswith("/w_v") and len(shape) == 3:
        return (F, "model", None) if div(1) else (F, None, None)
    if name.endswith("/w_o") and len(shape) == 3:
        return ("model", None, F) if div(0) else (None, None, F)
    if name.endswith(("/b_q", "/b_k", "/b_v")):
        return ("model", None) if div(0) else (None, None)
    if name.endswith(("/q_norm", "/k_norm")):
        return (None,)
    # dense MLP (SwiGLU)
    if name.endswith(("mlp/w_gate", "mlp/w_up")):
        return (F, "model") if div(1) else (F, None)
    if name.endswith("mlp/w_down"):
        return ("model", F) if div(0) else (None, F)
    # MoE experts: expert-parallel
    if "/moe/" in name and len(shape) == 3:
        return ("model", F, None) if div(0) else (F, None, None)
    if name.endswith("w_router"):
        return (None, None)
    # Mamba2
    if name.endswith(("/w_z", "/w_x", "/w_dt")):
        return (F, "model") if div(1) else (F, None)
    if name.endswith("/w_bc"):
        return (F, None)
    if name.endswith(("/conv_x",)):
        return (None, "model") if div(1) else (None, None)
    if name.endswith(("/conv_bc",)):
        return (None, None)
    if name.endswith(("/conv_bx", "/A_log", "/D", "/dt_bias",
                      "/norm_scale")):
        return ("model",) if div(0) else (None,)
    if name.endswith("/conv_bbc"):
        return (None,)
    if name.endswith("/w_out"):
        return ("model", F) if div(0) else (None, F)
    # RWKV6
    if name.endswith(("/w_r", "/w_g")) or \
            (name.endswith("/w_k") and len(shape) == 2 and
             "ffn" not in name) or \
            (name.endswith("/w_v") and len(shape) == 2 and "ffn" not in name):
        return (F, "model") if div(1) else (F, None)
    if name.endswith("rwkv/w_o"):
        return ("model", F) if div(0) else (None, F)
    if name.endswith("/decay_A"):
        return (F, None)
    if name.endswith("/decay_B"):
        return (None, "model") if div(1) else (None, None)
    if name.endswith("/bonus_u"):
        return ("model", None) if div(0) else (None, None)
    if name.endswith("ffn/w_k"):
        return (F, "model") if div(1) else (F, None)
    if name.endswith("ffn/w_v"):
        return ("model", F) if div(0) else (None, F)
    return None


def _spec_for_leaf(path: str, shape: Tuple[int, ...], mesh: Mesh,
                   mode: str, fsdp: bool, fsdp_wide: bool = False) -> P:
    ms = _axis_size(mesh, "model")
    ds = _data_size(mesh)
    dspec = _dspec(mesh)

    is_stacked = path.startswith("units/")
    inner = path.split("/", 1)[1] if is_stacked else path
    shp = shape[1:] if is_stacked else shape

    # top-level leaves
    if inner == "embed":                      # (V, M)
        spec = ("model" if shp[0] % ms == 0 else None, None)
    elif inner == "head":                     # (M, V)
        spec = (None, "model" if shp[1] % ms == 0 else None)
    elif inner.startswith("final_norm") or inner.startswith("frontend"):
        spec = (None,) * len(shp)
    elif mode == "head" or inner.split("/")[0] == "shared" or \
            "/moe/" in inner or inner.endswith("w_router"):
        s = _head_spec("/" + inner, shp, ms, None)
        spec = s if s is not None else (None,) * len(shp)
    else:
        # seq-parallel: replicate over model (experts handled above)
        spec = (None,) * len(shp)

    spec = list(spec)
    # FSDP: shard one replicated-so-far dim over the learner axes.  For
    # seq-parallel giants (ZeRO-3, §Perf B2) shard over data AND model when
    # the leaf does not already use `model`.
    if fsdp:
        wide = fsdp_wide and all(s != "model" for s in spec)
        fspec = ((tuple(data_axes(mesh)) + ("model",)) if wide else dspec)
        fsize = ds * (ms if wide else 1)
        cand = sorted(range(len(shp)), key=lambda d: -shp[d])
        for d in cand:
            if spec[d] is None and shp[d] % fsize == 0 and shp[d] >= fsize:
                spec[d] = fspec
                break
    if is_stacked:
        spec = [None] + spec
    return P(*spec)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_shardings(params_shape, mesh: Mesh, fsdp: bool,
                    mode: Optional[str] = None, fsdp_wide: bool = False):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    if mode is None:
        raise ValueError("pass mode explicitly (parallelism_mode(cfg, ...))")

    def leaf_sharding(path, leaf):
        spec = _spec_for_leaf(_path_str(path), leaf.shape, mesh, mode, fsdp,
                              fsdp_wide)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf_sharding, params_shape)


# ---------------------------------------------------------------------------
# inputs & caches
# ---------------------------------------------------------------------------
def batch_spec_for(cfg: ModelConfig, mesh: Mesh, mode: str,
                   batch: int, seq: int):
    """(batch_axis_spec, seq_axis_spec) for (B, S)-shaped inputs."""
    ds = _data_size(mesh)
    ms = _axis_size(mesh, "model")
    bspec = _dspec(mesh) if batch % ds == 0 and batch >= ds else None
    sspec = ("model" if mode == "seq" and seq % ms == 0 and seq > ms
             else None)
    return bspec, sspec


def cache_shardings(caches_shape, mesh: Mesh, batch: int):
    """Decode caches (units, B, ...): batch over learners; the context/state
    dim over `model` — context-parallel decode (every chip holds 1/16 of the
    KV history or the head-sharded recurrent state)."""
    ms = _axis_size(mesh, "model")
    ds = _data_size(mesh)
    dspec = _dspec(mesh)

    def leaf_sharding(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % ds == 0 and shape[1] >= ds:
            spec[1] = dspec
        # first dim after batch that divides the model axis: for KV caches
        # that is the context dim C; for SSM/RWKV states the head dim H.
        for d in range(2, len(shape)):
            if shape[d] % ms == 0 and shape[d] >= ms:
                spec[d] = "model"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(leaf_sharding, caches_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# SPMD replay placement specs (DESIGN.md §13)
# ---------------------------------------------------------------------------
# The replay scan's carry is (ring, state, residue) with a leading shard
# axis — (S, K, W) / (S, W) — placed one slice per "ps" device.  The xs
# pytree replicates the small per-event vectors (timestep indices, LRs,
# coefficients: O(steps·c) scalars) on every device and shards only the
# minibatch leaves — (steps, c, …) — over the "learner" axis on the slot
# dim, so each learner device stages and differentiates just its
# slot_block slots.  The per-shard pulled-timestamp matrix (steps, c, S)
# shards its trailing shard axis over "ps": each PS device reads only its
# own ring's timestamps.

def spmd_carry_specs() -> Tuple[P, P, P]:
    """(ring, state, residue) specs: every carry leaf shards dim 0 over
    "ps" (state/residue may be None in the carry — a P over an empty
    subtree pairs fine)."""
    return (P("ps"), P("ps"), P("ps"))


def spmd_xs_specs(keys) -> Dict[str, Any]:
    """PartitionSpec dict for a ``_trace_xs`` key set (+ 3-d ts).  The
    "batch" entry is a pytree *prefix*: one spec broadcast over the whole
    minibatch subtree."""
    specs: Dict[str, Any] = {}
    for key in keys:
        if key == "ts":
            specs[key] = P(None, None, "ps")
        elif key == "batch":
            specs[key] = P(None, "learner")
        else:
            specs[key] = P()
    return specs


def spmd_aux_specs() -> Tuple[P, P]:
    """(a, wstar) what-if auxiliaries, shard-packed to (S, W): per-"ps"."""
    return (P("ps"), P("ps"))


def default_microbatches(cfg: ModelConfig, shape: InputShape,
                         data_shards: int = 16, model_shards: int = 16,
                         budget_bytes: float = 10e9) -> int:
    """Gradient-accumulation factor so train activations fit HBM.

    Estimate: remat keeps one residual-stream copy per unit plus ~4x
    transients for the live unit's backward (fp32 intermediates), sharded
    over data (and over model for sequence-parallel archs)."""
    if shape.kind != "train":
        return 1
    mode = parallelism_mode(cfg, model_shards)
    tokens_per_dev = shape.global_batch * shape.seq_len / data_shards
    if mode == "seq":
        tokens_per_dev /= model_shards
    act = tokens_per_dev * cfg.d_model * cfg.n_units * 2 * 5.0
    mb = 1
    while act / mb > budget_bytes and mb < 64:
        mb *= 2
    # each micro-batch must still cover every data shard (and the softsync
    # group split); llama3-class models saturate this cap — the remaining
    # overrun is attacked in §Perf via sequence-parallel residuals.
    mb = min(mb, max(1, shape.global_batch // (4 * data_shards) * 4))
    while (shape.global_batch // 4) % mb != 0 and mb > 1:
        mb //= 2
    return mb
