"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).

TPU v5e constants used by the roofline (per chip):
  peak bf16: 197 TFLOP/s; HBM: 819 GB/s; ICI: ~50 GB/s/link.
"""

from __future__ import annotations

import os
from typing import Callable, Tuple

import jax

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")

# the emulated-cluster mesh for the SPMD replay (DESIGN.md §13): S parameter-
# server shards × L learner-group devices on XLA host devices
SIM_AXES = ("ps", "learner")

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _jax_initialized() -> bool:
    """Whether a jax backend has already been created (after which the
    host-platform device count is locked in).  Probes the private backend
    cache so the probe itself never initializes; unknown layouts (future
    jax) conservatively report True — the caller then validates against
    ``jax.device_count()`` instead of silently editing a dead env var."""
    xb = getattr(getattr(jax, "_src", None), "xla_bridge", None)
    for attr in ("_backends", "_backend_cache"):
        cache = getattr(xb, attr, None)
        if isinstance(cache, dict):
            return bool(cache)
    return True


def ensure_host_devices(n: int) -> int:
    """Ensure ≥ n (emulated) host devices, returning the live device count.

    The ``xla_force_host_platform_device_count`` XLA flag (SNIPPETS §3, the
    dry-run trick) only takes effect BEFORE the first jax backend is
    created.  Called early, this sets/extends ``XLA_FLAGS`` (keeping an
    existing larger request) and initializes jax; called after jax is
    already live with fewer than n devices it raises a RuntimeError that
    says exactly how to fix the launch — instead of the opaque
    "mesh shape is larger than the number of devices" failure
    ``make_debug_mesh`` used to die with."""
    if n < 1:
        raise ValueError(f"need at least 1 device, got n={n}")
    if not _jax_initialized():
        flags = os.environ.get("XLA_FLAGS", "").split()
        kept, have = [], 0
        for f in flags:
            if f.startswith(_HOST_COUNT_FLAG):
                try:
                    have = int(f.split("=", 1)[1])
                except (IndexError, ValueError):
                    have = 0
            else:
                kept.append(f)
        want = max(n, have)
        os.environ["XLA_FLAGS"] = " ".join(
            kept + [f"{_HOST_COUNT_FLAG}={want}"]).strip()
    count = jax.device_count()
    if count < n:
        raise RuntimeError(
            f"need {n} devices but jax initialized with {count}: the host "
            f"device count locks at first backend use, so set "
            f"XLA_FLAGS={_HOST_COUNT_FLAG}={n} in the environment (or call "
            f"launch.mesh.ensure_host_devices({n}) before any jax "
            f"computation / device query)")
    return count


def _require_devices(n: int, what: str) -> None:
    if jax.device_count() < n:
        raise RuntimeError(
            f"{what} needs {n} devices but only {jax.device_count()} are "
            f"visible; run under XLA_FLAGS={_HOST_COUNT_FLAG}={n} or call "
            f"launch.mesh.ensure_host_devices({n}) before jax initializes")


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax.make_mesh landed in 0.4.35; the oldest CI pin predates it
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return _make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The learner (batch) axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size


def n_learners(mesh: jax.sharding.Mesh) -> int:
    """λ for the distributed runtime = product of the learner axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    lam = 1
    for a in data_axes(mesh):
        lam *= sizes[a]
    return lam


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    to have been set before jax init — see :func:`ensure_host_devices`)."""
    _require_devices(data * model, f"debug mesh ({data}×{model})")
    return _make_mesh((data, model), ("data", "model"))


def make_sim_mesh(ps: int, learners: int) -> jax.sharding.Mesh:
    """The SPMD-replay cluster: ``ps × learner`` emulated host devices.

    Axis "ps" holds the S parameter-server shards (one (K, Dp) ring slice
    per device); axis "learner" splits the c gradient slots of an update
    across learner-group devices (DESIGN.md §13)."""
    _require_devices(ps * learners, f"sim mesh ({ps}×{learners})")
    return _make_mesh((ps, learners), SIM_AXES)


def shard_map(f: Callable, mesh: jax.sharding.Mesh, *, in_specs,
              out_specs) -> Callable:
    """Version-spanning ``shard_map``: prefers ``jax.shard_map`` (0.6+,
    ``check_vma`` kwarg), falls back to ``jax.experimental.shard_map``
    (0.4.x, ``check_rep`` kwarg).  Replication checking is disabled either
    way: the replay out-specs replicate the ring over the learner axis,
    which the checker cannot prove through a psum-inside-scan body."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
