"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).

TPU v5e constants used by the roofline (per chip):
  peak bf16: 197 TFLOP/s; HBM: 819 GB/s; ICI: ~50 GB/s/link.
"""

from __future__ import annotations

from typing import Tuple

import jax

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The learner (batch) axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size


def n_learners(mesh: jax.sharding.Mesh) -> int:
    """λ for the distributed runtime = product of the learner axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    lam = 1
    for a in data_axes(mesh):
        lam *= sizes[a]
    return lam


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    to have been set before jax init)."""
    return jax.make_mesh((data, model), ("data", "model"))
