"""Aggregate dry-run campaign JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--dir benchmarks/results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

ARCH_ORDER = ["internvl2_2b", "hubert_xlarge", "rwkv6_7b", "qwen3_14b",
              "starcoder2_7b", "zamba2_7b", "llama4_maverick_400b_a17b",
              "qwen2_1_5b", "llama3_405b", "arctic_480b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_):
    rows = {}
    for path in glob.glob(os.path.join(dir_, "*.json")):
        with open(path) as f:
            data = json.load(f)
        for r in data:
            key = (r["arch"].replace("-", "_"), r["shape"], r["mesh"],
                   "probe" if r.get("kind") == "probe" else "main")
            rows[key] = r
    return rows


def fmt_ms(x):
    return f"{x*1e3:8.1f}"


def dryrun_table(rows) -> str:
    out = ["| arch | shape | 16x16 | 2x16x16 | GiB/dev | mb | fsdp |",
           "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            m1 = rows.get((a, s, "16x16", "main"))
            m2 = rows.get((a, s, "2x16x16", "main"))
            if m1 is None:
                continue
            if m1["status"] == "skip":
                out.append(f"| {a} | {s} | SKIP | SKIP | — | — | — |"
                           f" <!-- {m1['reason']} -->")
                continue
            s1 = "OK" if m1["status"] == "ok" else m1["status"].upper()
            s2 = ("OK" if m2 and m2["status"] == "ok"
                  else (m2 or {}).get("status", "?").upper())
            gib = m1.get("bytes_per_device", 0) / 2**30
            out.append(
                f"| {a} | {s} | {s1} ({m1.get('compile_s', 0):.0f}s) "
                f"| {s2} ({(m2 or {}).get('compile_s', 0):.0f}s) "
                f"| {gib:.1f} | {m1.get('num_microbatches', 1)} "
                f"| {'Y' if m1.get('fsdp') else 'N'} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp | t_mem | t_coll | bound | useful "
           "| MODEL_FLOPS | coll GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, "16x16", "probe"))
            if r is None:
                continue
            if r["status"] == "skip":
                out.append(f"| {a} | {s} | — | — | — | skip | — | — | — |")
                continue
            out.append(
                f"| {a} | {s} | {fmt_ms(r['t_compute_s'])}ms "
                f"| {fmt_ms(r['t_memory_s'])}ms "
                f"| {fmt_ms(r['t_collective_s'])}ms "
                f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
                f"| {r['model_flops']:.2e} "
                f"| {r['coll_bytes_per_dev']/1e9:.1f} |")
    return "\n".join(out)


def summary_stats(rows):
    ok = skip = 0
    bounds = defaultdict(int)
    worst = []
    for (a, s, mesh, kind), r in rows.items():
        if kind == "main" and mesh == "16x16":
            ok += r["status"] == "ok"
            skip += r["status"] == "skip"
        if kind == "probe" and r["status"] == "ok":
            bounds[r["dominant"]] += 1
            worst.append((r["useful_ratio"], a, s, r["dominant"]))
    worst.sort()
    return ok, skip, dict(bounds), worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    ok, skip, bounds, worst = summary_stats(rows)
    print(f"single-pod main: {ok} ok / {skip} skip;  "
          f"probe bound split: {bounds}")
    print("\n== §Dry-run ==\n")
    print(dryrun_table(rows))
    print("\n== §Roofline (single-pod probes) ==\n")
    print(roofline_table(rows))
    print("\nworst useful-FLOPs ratios (hillclimb candidates):")
    for u, a, s, d in worst[:8]:
        print(f"  {u:.3f}  {a} × {s}  ({d}-bound)")


if __name__ == "__main__":
    main()
