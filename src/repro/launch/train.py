"""Training launcher.

Single-host CPU (examples/tests):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --protocol softsync --n 4 --engine fused --steps 100 --batch 8 \
        --seq 128 --ckpt /tmp/run1

Production (TPU pods): the same CLI with --mesh 16x16 / --mesh 2x16x16
builds the mesh from repro.launch.mesh and places the jit'd step with the
sharding policy in repro.launch.sharding.  On this CPU container the mesh
path is exercised by the dry-run (repro.launch.dryrun); real execution runs
on the default device.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.config import RunConfig
from repro.configs import get_config, get_smoke
from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--protocol", default="softsync",
                    choices=["hardsync", "softsync", "async"])
    ap.add_argument("--n", type=int, default=4, dest="n_softsync")
    ap.add_argument("--learners", type=int, default=8)
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "fused"])
    ap.add_argument("--lr-policy", default="staleness_inverse",
                    choices=["const", "staleness_inverse", "sqrt_scale",
                             "per_gradient"])
    ap.add_argument("--optimizer", default="momentum",
                    choices=["sgd", "momentum", "adagrad", "adamw"])
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only and args.protocol == "async":
        pass  # protocols are model-agnostic; nothing to special-case
    run = RunConfig(
        protocol=args.protocol, n_softsync=args.n_softsync,
        n_learners=args.learners,
        minibatch=max(1, args.batch // args.learners),
        base_lr=args.lr, lr_policy=args.lr_policy,
        optimizer=args.optimizer, num_microbatches=args.microbatches,
        seed=args.seed, attn_q_chunk=min(1024, args.seq),
        attn_kv_chunk=min(1024, args.seq))

    # report expected staleness for the chosen protocol (clock machinery)
    if run.protocol != "hardsync":
        from repro.experiments import ExperimentSpec
        from repro.experiments import run as run_experiment
        meas = run_experiment(ExperimentSpec(run=run, steps=200))
        print(f"protocol={run.protocol} n={run.n_softsync} "
              f"c={run.gradients_per_update} "
              f"expected<sigma>={meas.staleness['mean']:.2f} "
              f"lr={run.learning_rate():.5f}")

    t0 = time.time()
    res = train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
                engine=args.engine, eval_every=args.eval_every, log=print)
    print(f"done: {args.steps} rounds in {res.wallclock:.1f}s "
          f"({res.wallclock / args.steps * 1e3:.0f} ms/round)")
    if args.ckpt:
        path = os.path.join(args.ckpt, "checkpoint.npz")
        save_checkpoint(path, res.params, step=args.steps)
        with open(os.path.join(args.ckpt, "history.json"), "w") as f:
            json.dump(res.history, f, indent=1)
        print(f"checkpoint -> {path}")


if __name__ == "__main__":
    main()
