import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and emit roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--protocol softsync --n 4] \
        [--engine sequential|fused] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST precede any jax import (device count locks at
first init) — this module is the only place it is set; tests and benches see
the real single CPU device.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.config import INPUT_SHAPES, validate_pairing
from repro.configs import ARCH_IDS, get_config, long_context_variant
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh, n_chips, n_learners
from repro.launch.specs import (build_lowerable, make_run_config,
                                params_specs)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               protocol: str = "softsync", n_softsync: int = 4,
               engine: str = "sequential", num_microbatches: int = 0,
               attn_q_chunk: int = 1024, attn_kv_chunk: int = 1024,
               seq_par_residual: bool = False, mode_override: str = None,
               verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k":
        cfg = long_context_variant(cfg)
    skip = validate_pairing(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    run, engine = make_run_config(cfg, shape, mesh, protocol=protocol,
                                  n_softsync=n_softsync, engine=engine,
                                  num_microbatches=num_microbatches,
                                  attn_q_chunk=attn_q_chunk,
                                  attn_kv_chunk=attn_kv_chunk,
                                  seq_par_residual=seq_par_residual,
                                  mode_override=mode_override)
    t0 = time.time()
    with mesh:
        fn, arg_specs = build_lowerable(cfg, shape, mesh, run, engine=engine,
                                        mode_override=mode_override)
        lowered = fn.lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = rl.normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()

    roof = rl.analyse(arch, shape_name, mesh_name, n_chips(mesh),
                      cost, hlo, rl.model_flops(cfg, shape))
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "protocol": run.protocol, "n_softsync": run.n_softsync,
        "engine": engine, "num_microbatches": run.num_microbatches,
        "fsdp": run.fsdp,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        **{k: v for k, v in roof.row().items()
           if k not in ("arch", "shape", "mesh")},
        "coll_breakdown": {k: v for k, v in roof.coll_breakdown.items()
                           if v > 0},
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"t_comp {roof.t_compute*1e3:.1f}ms "
              f"t_mem {roof.t_memory*1e3:.1f}ms "
              f"t_coll {roof.t_collective*1e3:.1f}ms "
              f"-> {roof.dominant}-bound | useful {roof.useful_flops_ratio:.2f} "
              f"| {result['bytes_per_device']/2**30:.1f} GiB/dev")
        sys.stdout.flush()
    return result


def _probe_costs(cfg, shape, mesh, run, engine, mode_override=None):
    """Lower one fully-unrolled cost probe; return (flops, bytes, coll)."""
    with mesh:
        fn, arg_specs = build_lowerable(cfg, shape, mesh, run, engine=engine,
                                        mode_override=mode_override)
        lowered = fn.lower(*arg_specs)
        compiled = lowered.compile()
        cost = rl.normalize_cost_analysis(compiled.cost_analysis())
        coll = rl.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll["total"], coll)


def _grad_allreduce_bytes(cfg, mesh, fsdp: bool) -> float:
    """Analytic per-device wire bytes of ONE gradient all-reduce over the λ
    learner groups (ring, bf16 grads) — used to correct the sequential
    softsync engine's (G−1) extra reduces that the hardsync probe lacks."""
    pspecs = params_specs(cfg, mesh, fsdp)
    lam = n_learners(mesh)
    total_local = 0
    for leaf in jax.tree.leaves(pspecs):
        shard = leaf.sharding.shard_shape(leaf.shape)
        total_local += int(np.prod(shard)) * 2        # bf16
    return 2.0 * total_local * (lam - 1) / lam


def probe_roofline(arch: str, shape_name: str, *, multi_pod: bool = False,
                   protocol: str = "softsync", n_softsync: int = 4,
                   engine: str = "sequential",
                   attn_q_chunk: int = 1024, attn_kv_chunk: int = 1024,
                   seq_par_residual: bool = False, mode_override: str = None,
                   verbose: bool = True) -> dict:
    """Trip-count-correct roofline: lower unrolled probes at n_units ∈ {1, 2}
    (python loops; cost_analysis counts lax.scan bodies only ONCE — see
    EXPERIMENTS.md §Methodology), then
        total = probe1 + (U − 1) · (probe2 − probe1).
    Probes run hardsync / microbatch=1 (FLOP/byte-equivalent: both are linear
    batch splits); sequential softsync adds (G−1) gradient all-reduces which
    are corrected analytically.
    """
    cfg_full = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k":
        cfg_full = long_context_variant(cfg_full)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = validate_pairing(cfg_full, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    costs = {}
    for u in (1, 2):
        cfg_u = dataclasses.replace(cfg_full, n_units=u)
        run, eng = make_run_config(cfg_u, shape, mesh, protocol="hardsync",
                                   engine="sequential", num_microbatches=1,
                                   attn_q_chunk=attn_q_chunk,
                                   attn_kv_chunk=attn_kv_chunk,
                                   seq_par_residual=seq_par_residual,
                                   mode_override=mode_override)
        run = run.replace(unroll=True)
        costs[u] = _probe_costs(cfg_u, shape, mesh, run, eng,
                                mode_override=mode_override)
    U = cfg_full.n_units
    f1, b1, c1, bk1 = costs[1]
    f2, b2, c2, bk2 = costs[2]
    flops = f1 + (U - 1) * (f2 - f1)
    hbytes = b1 + (U - 1) * (b2 - b1)
    coll = c1 + (U - 1) * (c2 - c1)
    # per-kind extrapolation: fixed part (embed/head/loss) + U × per-unit
    breakdown = {k: bk1.get(k, 0.0) + (U - 1) * (bk2.get(k, 0.0)
                                                 - bk1.get(k, 0.0))
                 for k in (set(bk1) | set(bk2)) - {"total"}}
    breakdown = {k: v for k, v in breakdown.items() if v > 0}
    coll_per_unit = c2 - c1
    coll_fixed = c1 - coll_per_unit

    # sequential-softsync collective correction: (G−1) extra grad reduces
    G = n_softsync if (protocol in ("softsync", "async")
                       and shape.kind == "train") else 1
    from repro.launch import sharding as _shd
    ar_grad = _grad_allreduce_bytes(cfg_full, mesh,
                                    _shd.needs_fsdp(cfg_full, mesh))
    coll_corrected = coll + (G - 1) * ar_grad if G > 1 else coll

    roof = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=n_chips(mesh),
        hlo_flops=flops, hlo_bytes=hbytes, coll_bytes=coll_corrected,
        model_flops=rl.model_flops(cfg_full, shape),
        coll_breakdown=breakdown)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "ok", "kind": "probe",
              "protocol": protocol, "n_softsync": G,
              "probe_seconds": round(time.time() - t0, 1),
              "ar_grad_bytes": ar_grad,
              "coll_fixed_bytes": coll_fixed,
              "coll_per_unit_bytes": coll_per_unit,
              **{k: v for k, v in roof.row().items()
                 if k not in ("arch", "shape", "mesh")},
              "coll_breakdown": breakdown}
    if verbose:
        print(f"[probe {arch} × {shape_name} × {mesh_name}] "
              f"t_comp {roof.t_compute*1e3:.1f}ms "
              f"t_mem {roof.t_memory*1e3:.1f}ms "
              f"t_coll {roof.t_collective*1e3:.1f}ms "
              f"-> {roof.dominant}-bound | useful {roof.useful_flops_ratio:.3f}"
              f" | {result['probe_seconds']}s")
        sys.stdout.flush()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--protocol", default="softsync",
                    choices=["hardsync", "softsync", "async"])
    ap.add_argument("--n", type=int, default=4, dest="n_softsync")
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "fused"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--mode", default="main", choices=["main", "probe"])
    ap.add_argument("--seq-par-residual", action="store_true")
    ap.add_argument("--force-mode", default=None, choices=["head", "seq"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        pairs = [(args.arch, args.shape)]

    results = []
    for a, s in pairs:
        try:
            if args.mode == "probe":
                results.append(probe_roofline(
                    a, s, multi_pod=args.multi_pod, protocol=args.protocol,
                    n_softsync=args.n_softsync, engine=args.engine,
                    attn_q_chunk=args.q_chunk, attn_kv_chunk=args.kv_chunk,
                    seq_par_residual=args.seq_par_residual,
                    mode_override=args.force_mode))
                continue
            results.append(dryrun_one(
                a, s, multi_pod=args.multi_pod, protocol=args.protocol,
                n_softsync=args.n_softsync, engine=args.engine,
                num_microbatches=args.microbatches,
                attn_q_chunk=args.q_chunk, attn_kv_chunk=args.kv_chunk,
                seq_par_residual=args.seq_par_residual,
                mode_override=args.force_mode))
        except Exception as e:
            traceback.print_exc()
            results.append({"arch": a, "shape": s,
                            "mesh": "2x16x16" if args.multi_pod else "16x16",
                            "status": "error", "error": repr(e)})
            sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skip")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"\n== dry-run summary: {ok} ok / {sk} skip / {err} error ==")
    return 1 if err else 0


if __name__ == "__main__":
    sys.exit(main())
