"""Scalar/vector clocks and staleness accounting (paper §3.1).

The parameter server's weights carry a scalar timestamp ``ts_i`` that
increments on every weight update.  A gradient inherits the timestamp of the
weights it was computed from; its *staleness* when folded into update ``j``
is ``σ = j − i``.  The set of gradient timestamps contributing to one update
forms a vector clock; the paper's average staleness (Eq. 2) is

    ⟨σ⟩_i = (i − 1) − mean(i_1, …, i_n).

Two ingestion paths feed the log: the legacy per-arrival loop records one
:class:`StalenessRecord` per update (:meth:`VectorClockLog.record`), and the
trace/replay engine hands over the whole (steps, c) vector-clock matrix at
once (:meth:`VectorClockLog.from_matrix`) — the Fig.-4 statistics are then
computed vectorized on the matrix, with per-update ``records`` materialized
lazily only if a consumer asks for them.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


def staleness_matrix(pulled_ts: np.ndarray,
                     update_ts: Optional[np.ndarray] = None) -> np.ndarray:
    """(steps, c) σ matrix for a vector-clock matrix: slot (j, i) has
    σ = update_ts[j] − pulled_ts[j, i] (Eq. 2 per-slot form; update_ts
    defaults to the row index, i.e. the weights were at timestamp j when
    update j fired).  The ONE home of this accounting — shared by the log
    below and by ``ArrivalTrace.staleness``."""
    ts = np.asarray(pulled_ts, dtype=np.int64)
    if update_ts is None:
        update_ts = np.arange(ts.shape[0], dtype=np.int64)
    return np.asarray(update_ts, dtype=np.int64)[:, None] - ts


@dataclasses.dataclass
class StalenessRecord:
    """Bookkeeping for one weight update event at the parameter server."""
    update_index: int                 # i: timestamp after this update
    gradient_timestamps: List[int]    # vector clock ⟨ts_{i_1} … ts_{i_n}⟩

    @property
    def staleness_values(self) -> List[int]:
        """Per-gradient staleness σ = (i−1) − ts_g  (weights were at i−1
        when this update was applied)."""
        return [(self.update_index - 1) - t for t in self.gradient_timestamps]

    @property
    def average_staleness(self) -> float:
        """Eq. 2."""
        return float((self.update_index - 1)
                     - np.mean(self.gradient_timestamps))


class VectorClockLog:
    """Accumulates StalenessRecords over a run; provides Fig.-4 statistics."""

    def __init__(self):
        self._records: Optional[List[StalenessRecord]] = []
        self._matrix: Optional[np.ndarray] = None   # (steps, c) pulled ts
        self._valid: Optional[np.ndarray] = None    # (steps, c) slot mask

    @classmethod
    def from_matrix(cls, pulled_ts: np.ndarray,
                    valid: Optional[np.ndarray] = None) -> "VectorClockLog":
        """Build from a trace's (steps, c) vector-clock matrix: row j is the
        clock of update j+1 (statistics stay vectorized on the matrix).
        ``valid`` (same shape, bool) excludes cancelled slots — an elastic
        trace's unfilled/backup-cancelled pushes carry placeholder clocks
        that must not enter the Fig.-4 statistics."""
        log = cls()
        log._matrix = np.asarray(pulled_ts, dtype=np.int64)
        log._valid = None if valid is None else np.asarray(valid, bool)
        log._records = None
        return log

    @property
    def records(self) -> List[StalenessRecord]:
        if self._records is None:
            if self._valid is None:
                self._records = [StalenessRecord(j + 1, row.tolist())
                                 for j, row in enumerate(self._matrix)]
            else:
                self._records = [
                    StalenessRecord(j + 1, row[keep].tolist())
                    for j, (row, keep) in enumerate(zip(self._matrix,
                                                        self._valid))]
        return self._records

    def record(self, update_index: int,
               gradient_timestamps: Sequence[int]) -> StalenessRecord:
        rec = StalenessRecord(update_index, list(gradient_timestamps))
        self.records.append(rec)
        self._matrix = None          # matrix no longer authoritative
        return rec

    # ---- statistics --------------------------------------------------------
    def _staleness_matrix(self) -> Optional[np.ndarray]:
        """(steps, c) σ matrix when the log is matrix-backed, else None."""
        if self._matrix is None:
            return None
        return staleness_matrix(self._matrix)

    def average_staleness_series(self) -> np.ndarray:
        """⟨σ⟩ per update step (Fig. 4 main panels)."""
        sig = self._staleness_matrix()
        if sig is not None:
            if self._valid is not None:
                count = np.maximum(1, self._valid.sum(axis=1))
                return (np.where(self._valid, sig, 0).sum(axis=1)
                        / count).astype(np.float64)
            return sig.mean(axis=1).astype(np.float64)
        return np.array([r.average_staleness for r in self.records])

    def all_staleness_values(self) -> np.ndarray:
        """Per-gradient σ across the whole run (Fig. 4(b) inset)."""
        sig = self._staleness_matrix()
        if sig is not None:
            return (sig[self._valid] if self._valid is not None
                    else sig.reshape(-1))
        if not self.records:
            return np.zeros((0,))
        return np.concatenate([np.asarray(r.staleness_values)
                               for r in self.records])

    def staleness_histogram(self, max_sigma: Optional[int] = None
                            ) -> np.ndarray:
        """P(σ = k) for k = 0 … max_sigma, normalized by the total gradient
        count.  ``max_sigma=None`` uses the largest observed σ; an explicit
        ``max_sigma`` (including 0) truncates — mass above it is excluded,
        so the histogram sums to P(σ ≤ max_sigma).  An empty log yields a
        single zero bin (or max_sigma + 1 zero bins when given)."""
        vals = self.all_staleness_values()
        if max_sigma is None:
            max_sigma = int(vals.max()) if len(vals) else 0
        edges = np.arange(-0.5, max_sigma + 1.5)
        hist, _ = np.histogram(vals, bins=edges)
        return hist / max(1, len(vals))

    def fraction_exceeding(self, bound: float) -> float:
        vals = self.all_staleness_values()
        if len(vals) == 0:
            return 0.0
        return float(np.mean(vals > bound))

    def mean_staleness(self) -> float:
        vals = self.all_staleness_values()
        return float(vals.mean()) if len(vals) else 0.0
