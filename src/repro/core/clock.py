"""Scalar/vector clocks and staleness accounting (paper §3.1).

The parameter server's weights carry a scalar timestamp ``ts_i`` that
increments on every weight update.  A gradient inherits the timestamp of the
weights it was computed from; its *staleness* when folded into update ``j``
is ``σ = j − i``.  The set of gradient timestamps contributing to one update
forms a vector clock; the paper's average staleness (Eq. 2) is

    ⟨σ⟩_i = (i − 1) − mean(i_1, …, i_n).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class StalenessRecord:
    """Bookkeeping for one weight update event at the parameter server."""
    update_index: int                 # i: timestamp after this update
    gradient_timestamps: List[int]    # vector clock ⟨ts_{i_1} … ts_{i_n}⟩

    @property
    def staleness_values(self) -> List[int]:
        """Per-gradient staleness σ = (i−1) − ts_g  (weights were at i−1
        when this update was applied)."""
        return [(self.update_index - 1) - t for t in self.gradient_timestamps]

    @property
    def average_staleness(self) -> float:
        """Eq. 2."""
        return float((self.update_index - 1)
                     - np.mean(self.gradient_timestamps))


class VectorClockLog:
    """Accumulates StalenessRecords over a run; provides Fig.-4 statistics."""

    def __init__(self):
        self.records: List[StalenessRecord] = []

    def record(self, update_index: int,
               gradient_timestamps: Sequence[int]) -> StalenessRecord:
        rec = StalenessRecord(update_index, list(gradient_timestamps))
        self.records.append(rec)
        return rec

    # ---- statistics --------------------------------------------------------
    def average_staleness_series(self) -> np.ndarray:
        """⟨σ⟩ per update step (Fig. 4 main panels)."""
        return np.array([r.average_staleness for r in self.records])

    def all_staleness_values(self) -> np.ndarray:
        """Per-gradient σ across the whole run (Fig. 4(b) inset)."""
        if not self.records:
            return np.zeros((0,))
        return np.concatenate([np.asarray(r.staleness_values)
                               for r in self.records])

    def staleness_histogram(self, max_sigma: int = None):
        vals = self.all_staleness_values()
        hi = int(vals.max()) if max_sigma is None and len(vals) else max_sigma
        edges = np.arange(-0.5, (hi or 0) + 1.5)
        hist, _ = np.histogram(vals, bins=edges)
        return hist / max(1, len(vals))

    def fraction_exceeding(self, bound: float) -> float:
        vals = self.all_staleness_values()
        if len(vals) == 0:
            return 0.0
        return float(np.mean(vals > bound))

    def mean_staleness(self) -> float:
        vals = self.all_staleness_values()
        return float(vals.mean()) if len(vals) else 0.0
