"""(σ, μ, λ) tradeoff machinery: the paper's runtime model + curve driver.

The paper's runtime numbers come from a P775 cluster that does not exist in
this container, so wall-clock is *derived* from a calibrated analytical model
of the three Rudra system architectures (§3.2/3.3):

* compute:  t_mb(μ) = t_fix + μ·t_sample / gemm_eff(μ) — small mini-batches
  under-utilize the GEMM units (§5.2), captured by gemm_eff(μ) = μ/(μ+κ).
* communication: pushGradient + pullWeights move the full model W bytes each.
  - Rudra-base: flat PS ⇒ λ pushes serialize at the PS link; learners block.
  - Rudra-adv:  tree PS ⇒ serialization factor log₂(branch) per level; weight
    broadcast down the PS tree.
  - Rudra-adv*: comm threads + learner broadcast tree ⇒ comm fully
    overlapped except the first-gradient dependency.

The model is calibrated so the baseline (σ,μ,λ) = (0,128,1) CIFAR run matches
the paper's 22,392 s for 140 epochs, and reproduces the *qualitative* claims
(Fig. 8 speed-ups, Table 1 overlap, Table 2 time ordering).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """P775-like node + interconnect constants (relative units)."""
    t_fixed: float = 0.05          # per-minibatch fixed overhead (s)
    t_sample: float = 0.0011       # per-sample compute at perfect GEMM eff (s)
    gemm_kappa: float = 12.0       # μ/(μ+κ) GEMM efficiency knee
    link_bw: float = 24e9          # B/s per link (paper: 192 GB/s bidir node)
    ps_service_bw: float = 24e9    # PS ingest bandwidth
    tree_branch: int = 8           # Rudra-adv PS tree branching factor


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    model_bytes: float = 350e3     # CIFAR CNN ≈ 350 kB (§4.2)
    dataset_size: int = 50_000
    epochs: int = 140


def gemm_efficiency(mu: int, kappa: float) -> float:
    return mu / (mu + kappa)


def compute_time(mu: int, hw: HardwareModel) -> float:
    return hw.t_fixed + mu * hw.t_sample / gemm_efficiency(mu, hw.gemm_kappa)


def comm_time_per_push(arch: str, lam: int, hw: HardwareModel,
                       wl: WorkloadModel) -> float:
    """Exposed (non-overlapped) communication time per minibatch.
    Contention coefficients calibrated so the adversarial scenario
    (μ=4, 300 MB, ~60 learners) reproduces the paper's Table 1 overlaps."""
    wire = wl.model_bytes / hw.link_bw          # one model transfer
    if arch == "base":
        # flat PS: λ concurrent senders contend at the PS ingest link;
        # push + pull both exposed (effective concurrency ≈ 0.66·λ).
        return wire * 0.66 * lam + wire
    if arch == "adv":
        # tree PS: contention only among ≤branch siblings per level.
        levels = max(1, math.ceil(math.log(max(lam, 2), hw.tree_branch)))
        return wire * hw.tree_branch * levels * 0.33
    if arch == "adv*":
        # fully threaded: only the enqueue latency is exposed.
        return wire * 0.02
    raise ValueError(arch)


def minibatch_time(arch: str, mu: int, lam: int, hw: HardwareModel,
                   wl: WorkloadModel) -> float:
    comp = compute_time(mu, hw)
    comm = comm_time_per_push(arch, lam, hw, wl)
    if arch == "adv*":
        # overlap: comm hidden behind compute except residual
        return max(comp, comm) + 0.02 * comm
    return comp + comm


def communication_overlap(arch: str, mu: int, lam: int,
                          hw: HardwareModel = HardwareModel(),
                          wl: WorkloadModel = WorkloadModel()) -> float:
    """Table 1: computation / (computation + exposed communication)."""
    comp = compute_time(mu, hw)
    comm = comm_time_per_push(arch, lam, hw, wl)
    if arch == "adv*":
        exposed = max(0.0, comm - comp) + 0.02 * comm
    else:
        exposed = comm
    return comp / (comp + exposed)


def epoch_time(arch: str, protocol: str, mu: int, lam: int,
               hw: HardwareModel = HardwareModel(),
               wl: WorkloadModel = WorkloadModel(),
               jitter_sigma: float = 0.05) -> float:
    """Simulated seconds per epoch for a (protocol, μ, λ) configuration."""
    mb_per_learner = wl.dataset_size / (mu * lam)
    t_mb = minibatch_time(arch, mu, lam, hw, wl)
    if protocol == "hardsync":
        # barrier: expected max of λ lognormal draws ≈ mean·(1 + σ√(2 ln λ))
        straggle = 1.0 + jitter_sigma * math.sqrt(2 * math.log(max(lam, 2)))
        return mb_per_learner * t_mb * straggle
    # softsync: learners run free; PS throughput may bind for tiny μ.
    # The PS ingest scales with the architecture: the adv tree distributes
    # aggregation over `branch` children per level; adv* additionally
    # overlaps ingest with compute.
    ps_bw = hw.ps_service_bw
    if arch == "adv":
        ps_bw *= hw.tree_branch
    elif arch == "adv*":
        ps_bw *= hw.tree_branch * 4
    ps_updates_per_s = 1.0 / max(1e-9, wl.model_bytes / ps_bw * lam)
    learner_rate = lam / t_mb                    # minibatches/s aggregate
    effective = min(learner_rate, ps_updates_per_s * lam)
    return wl.dataset_size / mu / effective


def training_time(arch: str, protocol: str, mu: int, lam: int,
                  hw: HardwareModel = HardwareModel(),
                  wl: WorkloadModel = WorkloadModel()) -> float:
    return wl.epochs * epoch_time(arch, protocol, mu, lam, hw, wl)


def calibrate_to_baseline(target_seconds: float = 22_392.0,
                          wl: WorkloadModel = WorkloadModel()
                          ) -> HardwareModel:
    """Scale t_sample so (hardsync, μ=128, λ=1) matches the paper's baseline
    140-epoch wall-clock (§5.4)."""
    hw = HardwareModel()
    base = training_time("base", "hardsync", 128, 1, hw, wl)
    scale = target_seconds / base
    return dataclasses.replace(hw, t_fixed=hw.t_fixed * scale,
                               t_sample=hw.t_sample * scale)


def minibatch_duration_sampler(arch: str, lam: int,
                               hw: HardwareModel = None,
                               wl: WorkloadModel = None,
                               jitter_sigma: float = 0.05):
    """Duration sampler whose base is the calibrated per-minibatch cost
    (compute + exposed communication for ``arch``), pluggable into the
    schedule pass (``core/trace.py``): the trace's ``event_time`` then IS
    the paper's runtime axis, read directly off the simulation instead of a
    separate closed-form epoch model."""
    hw = hw or calibrate_to_baseline()
    wl = wl or WorkloadModel()

    def sampler(rng, mu, learner):
        return (minibatch_time(arch, mu, lam, hw, wl)
                * rng.lognormal(mean=0.0, sigma=jitter_sigma))
    return sampler


def runtime_axis(trace) -> np.ndarray:
    """Per-update wall-clock (simulated seconds) for error-vs-time curves:
    the trace's event clock, shaped (steps,)."""
    return np.asarray(trace.event_time, dtype=np.float64)


def speedup_table(arch: str, protocol: str, mu: int,
                  lams=(1, 2, 4, 10, 18, 30),
                  hw: HardwareModel = None) -> Dict[int, float]:
    """Fig. 8: speed-up vs the λ=1 configuration at the same μ."""
    hw = hw or calibrate_to_baseline()
    base = training_time(arch, "hardsync", mu, 1, hw)
    return {lam: base / training_time(arch, protocol, mu, lam, hw)
            for lam in lams}
