"""Replay pass of the compiled PS simulator (DESIGN.md §4).

Phase 2 of the trace/replay split: given the :class:`ArrivalTrace` a
schedule pass produced (``core/trace.py``), execute every update event in
ONE compiled ``jax.lax.scan`` instead of the legacy per-arrival Python loop
(one un-jitted ``grad_fn`` dispatch and one host→device optimizer
round-trip per gradient).

The staleness semantics — each gradient is computed against exactly the
weights its learner pulled — are preserved with a **device-resident weight
ring buffer**: a (K, D) fp32 buffer of the last K parameter snapshots in
the ``optim.flatten`` layout, where ``K = trace.max_staleness + 1`` (the
trace knows its own bound; n-softsync keeps it at ~2n, Fig. 4).  Snapshot
of timestamp ``ts`` lives in row ``ts % K``; event j gathers its c source
rows, unflattens them, computes the c gradients with a vmapped ``grad_fn``,
and applies ONE fused multi-gradient event through the unified subsystem —
``repro.optim.apply_event_flat`` on the flat buffers (the jnp twin of the
Pallas ``ps_update`` tile; pytree ``apply_update_tree`` for adamw), in
``combine`` or ``sequential`` mode per the trace's LR policy — before
writing the new snapshot to row ``(j+1) % K``.  The row being overwritten
belongs to timestamp j+1−K, which no later event can reference — σ would
exceed the trace's own max.  The ring keeps fp32 master weights; the final
parameters are cast back to their original dtypes on exit.

Oracle: the legacy loop in ``core/simulator.py``; equivalence on identical
traces is pinned by ``tests/test_trace_engine.py`` (EXPERIMENTS.md §Sim).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.config import RunConfig
from repro.core.lr_policies import resolve_trace_lrs
from repro.core.protocols import init_ps_state
from repro.core.simulator import SimResult
from repro.core.trace import ArrivalTrace, schedule
from repro.optim import flatten


@functools.lru_cache(maxsize=32)
def _unflatten_jit(layout: flatten.TreeLayout) -> Callable:
    """Jitted (D,) → pytree restore (eager slice-per-leaf costs ~ms/call)."""
    return jax.jit(lambda flat: flatten.flat_to_tree(flat, layout))


def _unstack_tree(tree, c: int):
    """Tree with a leading (c,) axis → list of c pytrees (c is static)."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(c)]


@functools.lru_cache(maxsize=32)
def _make_scan_fn(grad_fn, spec, mode: str, c: int, K: int,
                  layout: flatten.TreeLayout):
    """The jitted scan over update events — cached per static config so
    repeated replays (benchmark/sweep loops) reuse the compiled program;
    the LRU bound keeps long-lived processes from pinning every grad_fn
    closure + executable ever seen.

    Kernel-supported optimizers (sgd / momentum / adagrad) never leave the
    flat domain: the carry is just the (K, D) ring plus the (D,) state
    vector, gradients are flattened once per event, and the apply is ONE
    fused ``optim.apply_event_flat`` over the whole model — the scan body
    is the jnp twin of the Pallas ``ps_update`` tile.  adamw (scalar step
    counter, no kernel path) falls back to the pytree apply.
    """
    coef = jnp.full((c,), 1.0 / c, jnp.float32)

    def gradients(ring, x):
        rows = ring[x["ts"]]          # (c, D) gather; ts pre-wrapped mod K
        pulled = flatten.batched_flat_to_tree(rows, layout)
        return jax.vmap(grad_fn)(pulled, x["batch"])

    if spec.kernel_supported:
        def event(carry, x):
            ring, s = carry
            g = flatten.batched_tree_to_flat(gradients(ring, x))
            w, s = optim.apply_event_flat(spec, ring[x["prev"]], s, g,
                                          coef, x["lrs"], mode)
            return (ring.at[x["slot"]].set(w), s), None
    else:
        def event(carry, x):
            ring, (params, opt_state) = carry
            grads = _unstack_tree(gradients(ring, x), c)
            params, opt_state = optim.apply_update_tree(
                spec, params, opt_state, grads, coef, x["lrs"], mode)
            ring = ring.at[x["slot"]].set(flatten.tree_to_flat(params))
            return (ring, (params, opt_state)), None

    @jax.jit
    def run(carry, xs):
        # unroll a few events per while-loop iteration: the body is tiny
        # (one fused event), so loop bookkeeping is a measurable fraction
        return jax.lax.scan(event, carry, xs, unroll=8)[0]

    return run


def _materialize_batches(trace: ArrivalTrace, batch_fn: Callable):
    """Evaluate ``batch_fn(learner, minibatch_idx)`` for every trace slot
    and stack into a pytree with leading (steps, c) axes.  Stacking happens
    host-side so the whole trace's data moves to device in ONE transfer per
    leaf (batch_fns returning numpy avoid per-minibatch device_puts)."""
    rows = []
    for j in range(trace.steps):
        slots = [batch_fn(int(trace.learner[j, i]), int(trace.mb_index[j, i]))
                 for i in range(trace.c)]
        rows.append(jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *slots))
    return jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *rows)


def replay(trace: ArrivalTrace, run: RunConfig, *,
           grad_fn: Callable,
           init_params,
           batch_fn: Callable,
           eval_fn: Optional[Callable] = None,
           eval_every: int = 0) -> SimResult:
    """Execute a scheduled trace against real gradients, compiled.

    ``grad_fn(params, batch) -> grads`` must be vmappable (any jit-able JAX
    function is).  ``batch_fn(learner_idx, minibatch_idx) -> batch`` is
    evaluated host-side for every trace slot up front — the trace fixes the
    (learner, minibatch) schedule, so the data rides along as scan inputs.

    With ``eval_every`` set, the scan runs in eval_every-sized segments;
    a trailing remainder segment (steps % eval_every != 0) has a different
    scan length and compiles a second program — pick eval_every | steps in
    compile-sensitive sweeps.
    """
    if (trace.protocol != run.protocol
            or trace.n_learners != run.n_learners
            or trace.c != run.gradients_per_update):
        raise ValueError(
            f"trace ({trace.protocol}, λ={trace.n_learners}, c={trace.c}) "
            f"was not scheduled from this RunConfig ({run.protocol}, "
            f"λ={run.n_learners}, c={run.gradients_per_update})")
    # the trace bakes policy-resolved LRs in; re-resolving from this run's
    # policy must reproduce them, or the caller is silently sweeping
    # base_lr/lr_policy on a stale trace
    want_lrs, want_mode = resolve_trace_lrs(run, trace.pulled_ts)
    if trace.mode != want_mode or not np.allclose(trace.lrs, want_lrs):
        raise ValueError(
            f"trace LRs/mode ({trace.mode}) disagree with this RunConfig's "
            f"lr_policy={run.lr_policy!r}/base_lr={run.base_lr} — reschedule "
            f"the trace for this config")
    steps, c = trace.steps, trace.c
    K = trace.max_staleness + 1
    spec, opt_state = init_ps_state(run, init_params)
    layout = flatten.layout_of(init_params)

    scan_fn = _make_scan_fn(grad_fn, spec, trace.mode, c, K, layout)

    steps_idx = np.arange(steps)
    xs = {
        "ts": jnp.asarray(trace.pulled_ts % K, jnp.int32),
        "prev": jnp.asarray(steps_idx % K, jnp.int32),
        "slot": jnp.asarray((steps_idx + 1) % K, jnp.int32),
        "lrs": jnp.asarray(trace.lrs, jnp.float32),
        "batch": _materialize_batches(trace, batch_fn),
    }
    flat0 = flatten.tree_to_flat(init_params)
    ring = jnp.broadcast_to(flat0, (K, flat0.shape[0]))
    if spec.kernel_supported:
        # flat-domain carry: ring + the single (D,) state vector (or None)
        s0 = (flatten.tree_to_flat(opt_state[spec.state_keys[0]])
              if spec.state_keys else None)
        carry = (ring, s0)

        def params_of(carry, done):
            return _unflatten_jit(layout)(carry[0][done % K])
    else:
        carry = (ring, (init_params, opt_state))

        def params_of(carry, done):
            return carry[1][0]

    history = []
    if eval_fn and eval_every:
        done = 0
        while done < steps:
            take = min(eval_every, steps - done)
            seg = jax.tree.map(lambda a: a[done:done + take], xs)
            carry = scan_fn(carry, seg)
            done += take
            if done % eval_every == 0:
                history.append({"update": done,
                                "time": float(trace.event_time[done - 1]),
                                **eval_fn(params_of(carry, done))})
    else:
        carry = scan_fn(carry, xs)

    params = params_of(carry, steps)
    return SimResult(trace.clock_log(), steps, trace.simulated_time,
                     trace.minibatches, params, history)


def simulate_compiled(run: RunConfig, *,
                      steps: int,
                      grad_fn: Optional[Callable] = None,
                      init_params=None,
                      batch_fn: Optional[Callable] = None,
                      eval_fn: Optional[Callable] = None,
                      eval_every: int = 0,
                      duration_sampler: Optional[Callable] = None
                      ) -> SimResult:
    """Drop-in counterpart of ``core.simulator.simulate`` on the compiled
    trace/replay path: schedule once, then replay (or, with ``grad_fn``
    left None, return the measure-mode result straight off the trace)."""
    trace = schedule(run, steps, duration_sampler=duration_sampler)
    if grad_fn is None:
        return SimResult(trace.clock_log(), trace.steps,
                         trace.simulated_time, trace.minibatches)
    return replay(trace, run, grad_fn=grad_fn, init_params=init_params,
                  batch_fn=batch_fn, eval_fn=eval_fn, eval_every=eval_every)
