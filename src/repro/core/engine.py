"""Replay pass of the compiled PS simulator (DESIGN.md §4).

Phase 2 of the trace/replay split: given the :class:`ArrivalTrace` a
schedule pass produced (``core/trace.py``), execute every update event in
ONE compiled ``jax.lax.scan`` instead of the legacy per-arrival Python loop
(one un-jitted ``grad_fn`` dispatch and one host→device optimizer
round-trip per gradient).

The staleness semantics — each gradient is computed against exactly the
weights its learner pulled — are preserved with a **device-resident weight
ring buffer**: a (K, D) fp32 buffer of the last K parameter snapshots in
the ``optim.flatten`` layout, where ``K = trace.max_staleness + 1`` (the
trace knows its own bound; n-softsync keeps it at ~2n, Fig. 4).  Snapshot
of timestamp ``ts`` lives in row ``ts % K``; event j gathers its c source
rows, unflattens them, computes the c gradients with a vmapped ``grad_fn``,
and applies ONE fused multi-gradient event through the unified subsystem —
``repro.optim.apply_event_flat`` on the flat buffers (the jnp twin of the
Pallas ``ps_update`` tile; pytree ``apply_update_tree`` for adamw), in
``combine`` or ``sequential`` mode per the trace's LR policy — before
writing the new snapshot to row ``(j+1) % K``.  The row being overwritten
belongs to timestamp j+1−K, which no later event can reference — σ would
exceed the trace's own max.  The ring keeps fp32 master weights; the final
parameters are cast back to their original dtypes on exit.

Oracle: the legacy loop in ``core/simulator.py``; equivalence on identical
traces is pinned by ``tests/test_trace_engine.py`` (EXPERIMENTS.md §Sim).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.config import RunConfig
from repro.core.lr_policies import resolve_trace_lrs
from repro.core.protocols import init_ps_state
from repro.core.simulator import SimResult
from repro.core.topology import Topology
from repro.core.trace import ArrivalTrace, PlacementPlan, placement_plan
from repro.optim import flatten

# cross-shard pull assembly for the SPMD replay (DESIGN.md §13): one fused
# all_gather over the "ps" axis, or the equivalent S−1 neighbor-ppermute
# ring exchange (bitwise-equal data movement; slower on emulated devices)
SPMD_ASSEMBLIES = ("all_gather", "ppermute")


@functools.lru_cache(maxsize=32)
def _unflatten_jit(layout: flatten.TreeLayout) -> Callable:
    """Jitted (D,) → pytree restore (eager slice-per-leaf costs ~ms/call)."""
    return jax.jit(lambda flat: flatten.flat_to_tree(flat, layout))


def _unstack_tree(tree, c: int):
    """Tree with a leading (c,) axis → list of c pytrees (c is static)."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(c)]


@functools.lru_cache(maxsize=32)
def _make_scan_fn(grad_fn, spec, mode: str, c: int, K: int,
                  layout: flatten.TreeLayout, batched: bool = False,
                  shards: int = 1, group_size: int = 1,
                  masked: bool = False, member_masked: bool = False,
                  ring_impl: str = "stock", ring_dtype: str = "fp32",
                  whatif: bool = False, publish: bool = False):
    """The jitted scan over update events — cached per static config so
    repeated replays (benchmark/sweep loops) reuse the compiled program;
    the LRU bound keeps long-lived processes from pinning every grad_fn
    closure + executable ever seen.

    Kernel-supported optimizers (sgd / momentum / adagrad) never leave the
    flat domain: the carry is just the (K, D) ring plus the (D,) state
    vector, gradients are flattened once per event, and the apply is ONE
    fused ``optim.apply_event_flat`` over the whole model — the scan body
    is the jnp twin of the Pallas ``ps_update`` tile.  adamw (scalar step
    counter, no kernel path) falls back to the pytree apply.

    Topology (DESIGN.md §6) — the trivial (1, 1) case compiles the exact
    pre-topology body:

    * ``shards`` = S > 1: the carry ring becomes S per-shard (K, Dp) rings
      stacked as (S, K, Dp); each event gathers every slot's weight vector
      from per-shard rows (``x["ts"]`` is (c, S) — inconsistent reads) and
      applies the fused event per shard slice via the vmapped
      ``optim.apply_event_sharded``.
    * ``group_size`` = gs > 1: each slot aggregates gs member gradients
      computed against the slot's pulled weights (the group pulls once and
      broadcasts); minibatches carry a (c, gs, …) leading shape and the
      member gradients are averaged before the apply.

    Elastic membership (DESIGN.md §7) stays branch-free: ``masked=True``
    reads each event's combine coefficients from the trace
    (``x["coef"]``, zero on cancelled slots — the schedule pass resolved
    who committed) instead of the static 1/c; ``member_masked=True`` does
    the same for the group-member average (``x["mcoef"]``: a crashed
    member's gradient gets weight 0, survivors renormalize).  The scan
    body is otherwise identical — cancelled work is computed and then
    folded with coefficient 0, which XLA treats as data, not control flow.

    ``batched=True`` returns ``jit(vmap(scan))``: the identical per-event
    body mapped over a leading batch axis of B independent grid points —
    one device program executes a whole multi-seed/multi-config sweep cell
    (``replay_batch``, trivial topology only).  The ring-buffer *write*
    position (and the previous snapshot's row) depend only on the step
    index and the shared K, so ``prev``/``slot`` stay unbatched
    (``in_axes=None``): the per-event ring update remains a
    dynamic-update-slice at a common row instead of a per-lane scatter —
    the difference between the batched scan keeping the (B, K, D) ring in
    place and copying it every event.  Only ``ts`` (which snapshots each
    lane's c gradients read), ``lrs``, and the minibatches are per-lane.

    Ring scan bodies (DESIGN.md §12) — ``ring_impl`` selects how a
    kernel-supported event executes:

    * ``stock``  — the original gather → ``apply_event_flat`` →
      ``.at[slot].set`` chain (the bitwise baseline; adamw always lands
      here via its pytree body).
    * ``fused``  — ``optim.apply_event_ring``: the same math phrased as
      ONE fused read-update-write over a flat (K, Dp) ring (bitwise-equal
      to stock at fp32), plus the bf16 error-feedback residue when
      ``ring_dtype == "bf16"``.  Sharded traces unify onto the flat padded
      buffer: the per-shard structure only matters for the *gather* (each
      slot assembles per-shard rows at per-shard timestamps); the update
      itself is elementwise, so one fused event over the (K, S·Dp) buffer
      computes the same values as the stacked per-shard applies.  Bitwise
      it matches the flat ``apply_event_flat`` reference — the *stock
      sharded* body phrases the combine einsum on (S, c, Dp) operands,
      which XLA lowers with different rounding (~1 ulp/event), so sharded
      fused vs stock agree to fp32 accumulation tolerance only.
    * ``pallas`` — the ``kernels/replay_ring`` megakernel: one pallas_call
      per event with scalar-prefetched ring rows and in-place aliased
      writes (interpret mode off-TPU).

    For non-stock impls the carry is ``(ring, state, residue)`` and the
    jitted scan **donates** it (``donate_argnums=0``): the K·D ring stops
    being double-buffered across scan dispatches.  ``whatif=True`` swaps
    the gradient stage for the in-kernel/streamed closed-form gradients
    (``g = a ⊙ (w_pulled − w*)``; combine mode, trivial topology): the
    scan fn then takes ``(carry, xs, (a, w*))`` and no minibatches ride
    the trace at all.
    """
    coef = jnp.full((c,), 1.0 / c, jnp.float32)
    D = layout.total
    Dp = -(-D // shards)                  # Topology.padded_width(D)

    def coef_of(x):
        return x["coef"] if masked else coef

    def slot_weights(ring, x):
        """The (c, D) weight vectors the slots' gradients are computed
        against: one ring gather, or the per-shard assembly (each slot
        concatenates its S pulled slices — possibly different timestamps:
        weights that never existed as one consistent version, §3.1)."""
        if shards == 1:
            return ring[x["ts"]]      # (c, D) gather; ts pre-wrapped mod K
        # ring: (S, K, Dp); x["ts"]: (c, S) → per-shard (S, c, Dp) gather
        parts = jax.vmap(lambda r, t: r[t], in_axes=(0, 1))(ring, x["ts"])
        return flatten.shard_unpack(jnp.moveaxis(parts, 0, 1), D)

    def gradients_of(pulled_flat, x):
        """vmapped grad_fn at the (c, D) fp32 pulled weights, cast to fp32
        ONCE right after the backward pass — the member-mean/flatten
        stages downstream see fp32 and their casts are no-ops (one cast
        per event instead of one per reduction on the hot loop)."""
        pulled = flatten.batched_flat_to_tree(pulled_flat, layout)
        if group_size == 1:
            g = jax.vmap(grad_fn)(pulled, x["batch"])
            return jax.tree.map(lambda a: a.astype(jnp.float32), g)
        # member gradients share the slot's pulled weights; average the
        # (c, gs) gradient stack over the group axis (Eq. 3 locally) —
        # weighted by the survivor mask when membership is elastic (a
        # group with a crashed member aggregates over survivors)
        g = jax.vmap(lambda p, b: jax.vmap(lambda bb: grad_fn(p, bb))(b))(
            pulled, x["batch"])
        g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
        if member_masked:
            mc = x["mcoef"]                              # (c, gs)
            def wmean(a):
                w = mc.reshape(mc.shape + (1,) * (a.ndim - 2))
                return (a * w).sum(axis=1)
            return jax.tree.map(wmean, g)
        return jax.tree.map(lambda a: a.mean(axis=1), g)

    def gradients(ring, x):
        return gradients_of(slot_weights(ring, x), x)

    fused = ring_impl in ("fused", "pallas") and spec.kernel_supported
    if fused:
        from repro.kernels import replay_ring   # lazy: breaks import cycle

        def slot_weights_flat(ring, x):
            """Fused-impl gather off the flat (K, Dp) ring (padding and —
            with a bf16 ring — quantization stripped): the (c, D) fp32
            weights the slot gradients see.  Sharded traces view the
            buffer as (K, S, Dp) rows for the per-shard-timestamp
            assembly; the flat layout is the shard rows concatenated, so
            this is bitwise the stock per-shard gather."""
            if shards == 1:
                return ring[x["ts"]][..., :D].astype(jnp.float32)
            view = ring[:, :shards * Dp].reshape(K, shards, Dp)
            parts = jax.vmap(lambda r, t: r[t],
                             in_axes=(1, 1), out_axes=1)(view, x["ts"])
            return parts.reshape(c, shards * Dp)[:, :D].astype(jnp.float32)

        if whatif:
            def event(aux, carry, x):
                ring, s, res = carry
                a, wstar = aux
                if ring_impl == "pallas" and K >= 2:
                    idx = jnp.concatenate(
                        [jnp.stack([x["prev"], x["slot"]]), x["ts"]])
                    ring, s, res = replay_ring.ring_apply_whatif(
                        ring, s, res, a, wstar, coef_of(x), x["lrs"], idx,
                        spec=spec)
                else:
                    ring, s, res = optim.apply_event_ring_whatif(
                        spec, ring, s, res, a, wstar, x["ts"], coef_of(x),
                        x["lrs"], x["prev"], x["slot"])
                return (ring, s, res), None
        else:
            def event(carry, x):
                ring, s, res = carry
                g = flatten.batched_tree_to_flat(
                    gradients_of(slot_weights_flat(ring, x), x))
                gp = flatten.pad_flat(g, ring.shape[1])
                if ring_impl == "pallas":
                    idx = jnp.stack([x["prev"], x["slot"]])
                    ring, s, res = replay_ring.ring_apply(
                        ring, s, res, gp, coef_of(x), x["lrs"], idx,
                        spec=spec, mode=mode)
                else:
                    ring, s, res = optim.apply_event_ring(
                        spec, ring, s, res, gp, coef_of(x), x["lrs"],
                        x["prev"], x["slot"], mode)
                return (ring, s, res), None
    elif spec.kernel_supported and shards > 1:
        def event(carry, x):
            ring, s = carry
            g = flatten.batched_tree_to_flat(gradients(ring, x))
            gp = flatten.shard_pack_grads(g, shards, Dp)     # (S, c, Dp)
            w, s = optim.apply_event_sharded(
                spec, ring[:, x["prev"]], s, gp, coef_of(x), x["lrs"], mode)
            return (ring.at[:, x["slot"]].set(w), s), None
    elif spec.kernel_supported:
        def event(carry, x):
            ring, s = carry
            g = flatten.batched_tree_to_flat(gradients(ring, x))
            w, s = optim.apply_event_flat(spec, ring[x["prev"]], s, g,
                                          coef_of(x), x["lrs"], mode)
            return (ring.at[x["slot"]].set(w), s), None
    else:
        def event(carry, x):
            ring, (params, opt_state) = carry
            grads = _unstack_tree(gradients(ring, x), c)
            params, opt_state = optim.apply_update_tree(
                spec, params, opt_state, grads, coef_of(x), x["lrs"], mode)
            ring = ring.at[x["slot"]].set(flatten.tree_to_flat(params))
            return (ring, (params, opt_state)), None

    if publish:
        # serving lane (DESIGN.md §14): capture each *published* weight
        # version as the scan writes it — the ring row is read at its birth
        # instant, which is exactly what every publication policy resolves
        # to (a ring read always returns the newest row; the host-side
        # schedule_serving already mapped refreshes/requests to versions).
        # x["pub"] indexes the snapshot buffer riding the carry: the
        # published-version position for rows some replica serves, or the
        # inert dummy row (branch-free — unpublished rows write there).
        # Snapshots store the raw ring row in fp32: with a bf16 ring the
        # published weights are the quantized snapshots, residue excluded
        # (the serving tolerance policy — §14).
        if batched or whatif:
            raise ValueError(
                "publish capture supports the single-lane staged-gradient "
                "scan only (replay_batch and the what-if replay reject "
                "serving traces upstream)")
        base_event = event

        def event(carry, x):
            core, snaps = carry
            core, _ = base_event(core, x)
            row = core[0][x["slot"]].astype(jnp.float32)
            return (core, snaps.at[x["pub"]].set(row)), None

    # single lane: unroll a few events per while-loop iteration (the body
    # is tiny, loop bookkeeping is a measurable fraction).  The batched
    # body is B× wider — unrolling only bloats its code and measured ~25%
    # slower — and the what-if body streams O(D) temporaries whose
    # lifetimes unrolling would overlap, so both stay rolled.
    unroll = 1 if (batched or whatif) else 8

    if whatif:
        def run(carry, xs, aux):
            return jax.lax.scan(functools.partial(event, aux), carry, xs,
                                unroll=unroll)[0]
        return jax.jit(run, donate_argnums=0)

    def run(carry, xs):
        return jax.lax.scan(event, carry, xs, unroll=unroll)[0]

    if batched:
        axes = {"ts": 0, "prev": None, "slot": None, "lrs": 0, "batch": 0}
        if masked:
            axes["coef"] = 0
        vrun = jax.vmap(run, in_axes=(0, axes))
        return (jax.jit(vrun, donate_argnums=0) if fused else jax.jit(vrun))
    # non-stock carries are donated: the ring/state/residue buffers are
    # updated in place across scan dispatches instead of double-buffered
    return jax.jit(run, donate_argnums=0) if fused else jax.jit(run)


def _spmd_local_width(D: int, shards: int, ring_impl: str) -> int:
    """Per-"ps"-device ring row width: the shard slice Dp = ⌈D/S⌉, padded
    to the megakernel tile multiple when the local body is Pallas."""
    Dp = -(-D // shards)
    if ring_impl == "pallas":
        from repro.kernels import replay_ring   # lazy: import cycle
        return replay_ring.padded_width(Dp)
    return Dp


@functools.lru_cache(maxsize=32)
def _make_spmd_scan_fn(grad_fn, spec, mode: str, c: int, K: int,
                       layout: flatten.TreeLayout, plan: PlacementPlan,
                       xs_keys: tuple, group_size: int = 1,
                       masked: bool = False, member_masked: bool = False,
                       ring_impl: str = "fused", ring_dtype: str = "fp32",
                       whatif: bool = False, assembly: str = "all_gather"):
    """The replay scan shard_mapped over a ``(ps, learner)`` device mesh —
    the distributed twin of :func:`_make_scan_fn` (DESIGN.md §13).

    Placement: PS shard s's (K, Wl) ring slice (plus its optimizer-state /
    residue rows) lives on "ps"-device s; learner-group device l owns the
    contiguous slot block [l·cl, (l+1)·cl) of every update's c gradient
    slots.  The per-event body then runs the paper's PS protocol as real
    collectives:

    * **pull** — each PS device gathers its own ring rows at its own
      per-shard timestamps (the inconsistent-read column ``ts[:, s]``) and
      an ``all_gather`` over "ps" assembles the (c, D) pulled weights on
      every device (``assembly="ppermute"`` swaps in the bitwise-equal
      S−1-hop neighbor ring exchange, ``optim.ring_all_gather``);
    * **push** — combine mode reduces each learner device's local-slot
      partial of ĝ = Σ coef_j·g_j with ONE ``psum`` over "learner"
      (``optim.combine_spmd``); sequential mode ``all_gather``s the slot
      gradients over "learner" instead (every event needs every slot);
    * **update** — each PS device applies the fused/Pallas ring body
      (``optim.apply_event_ring`` / ``replay_ring.ring_apply``) to its own
      slice of ĝ — elementwise math, so per-shard applies are exactly the
      shard slices of the single-device apply.

    Equivalence to ``placement="single"`` (pinned by tests/test_spmd.py;
    tolerance policy in DESIGN.md §13): the **what-if** body is bitwise
    against single-device replay, any S — shard-local closed-form
    gradients, no reduction to reorder — and ``assembly="ppermute"`` is
    bitwise against ``"all_gather"``.  The **staged-gradient** bodies
    track single-device replay to ~1 ulp per event even at L = 1: the
    math is op-for-op identical, but XLA fuses the combine/update chain
    differently (fma contraction) inside the shard_map body, and L > 1
    additionally reorders the fp32 combine reduction through the psum's
    partial-sum tree.  Elastic masks stay branch-free: the
    trace coefficients ride in replicated and each device slices its
    block, so cancelled slots fold with weight 0 exactly as on one device.

    The gradient stage intentionally mirrors ``_make_scan_fn.gradients_of``
    op-for-op (vmapped grad_fn → ONE fp32 cast → member mean) — the
    duplication is what keeps both paths' pins independent.  What-if
    replay needs no learner axis at all (closed-form gradients are
    shard-local); callers plan it with L = 1 and the body never touches
    "learner".
    """
    S, L = plan.shards, plan.learners
    cl = c // L
    D = layout.total
    Dp = -(-D // S)
    Wl = _spmd_local_width(D, S, ring_impl)
    from repro.kernels import replay_ring       # lazy: import cycle
    from repro.launch import mesh as mesh_lib
    from repro.launch import sharding as sharding_lib

    if assembly not in SPMD_ASSEMBLIES:
        raise ValueError(f"unknown spmd_assembly {assembly!r}: expected "
                         f"one of {SPMD_ASSEMBLIES}")
    mesh = mesh_lib.make_sim_mesh(S, L)
    coef = jnp.full((c,), 1.0 / c, jnp.float32)

    def coef_of(x):
        return x["coef"] if masked else coef

    def assemble(mine):
        if assembly == "ppermute":
            return optim.ring_all_gather(mine, "ps", S)
        return jax.lax.all_gather(mine, "ps", axis=0)

    def pulled_weights(rl, x):
        """(c, D) fp32 pulled weights, assembled from every shard's local
        gather (pallas pad stripped per shard) — the same moveaxis/reshape
        assembly as the single-device fused ``slot_weights_flat``."""
        mine = rl[x["ts"][:, 0]][:, :Dp]              # (c, Dp) local rows
        parts = assemble(mine)                        # (S, c, Dp)
        full = jnp.moveaxis(parts, 0, 1).reshape(c, S * Dp)
        return full[:, :D].astype(jnp.float32)

    def local_gradients(w_full, x, lo):
        """(cl, D) fp32 gradients of this learner device's slot block —
        op-for-op the single-device ``gradients_of`` on the block."""
        wl = jax.lax.dynamic_slice_in_dim(w_full, lo, cl, 0)
        pulled = flatten.batched_flat_to_tree(wl, layout)
        if group_size == 1:
            g = jax.vmap(grad_fn)(pulled, x["batch"])
            g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
            return flatten.batched_tree_to_flat(g)
        g = jax.vmap(lambda p, b: jax.vmap(lambda bb: grad_fn(p, bb))(b))(
            pulled, x["batch"])
        g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
        if member_masked:
            mc = jax.lax.dynamic_slice_in_dim(x["mcoef"], lo, cl, 0)

            def wmean(a):
                w = mc.reshape(mc.shape + (1,) * (a.ndim - 2))
                return (a * w).sum(axis=1)
            g = jax.tree.map(wmean, g)
        else:
            g = jax.tree.map(lambda a: a.mean(axis=1), g)
        return flatten.batched_tree_to_flat(g)

    def shard_slice(vec, si):
        """(…, D) → this PS device's (…, Dp) slice (last shard zero-padded,
        matching the flat-ring layout exactly)."""
        vp = flatten.pad_flat(vec, S * Dp)
        return jax.lax.dynamic_slice_in_dim(vp, si * Dp, Dp, vp.ndim - 1)

    def unpack_carry(carry):
        ring, s, res = carry
        return (ring[0],
                None if s is None else s[0],
                None if res is None else res[0])

    def pack_carry(rl, sl, resl):
        return (rl[None],
                None if sl is None else sl[None],
                None if resl is None else resl[None])

    if whatif:
        def event(aux, carry, x):
            rl, sl, resl = unpack_carry(carry)
            a_l, ws_l = aux[0][0], aux[1][0]
            ts_col = x["ts"][:, 0]
            if ring_impl == "pallas" and K >= 2:
                idx = jnp.concatenate(
                    [jnp.stack([x["prev"], x["slot"]]), ts_col])
                rl, sl, resl = replay_ring.ring_apply_whatif(
                    rl, sl, resl, a_l, ws_l, coef_of(x), x["lrs"], idx,
                    spec=spec)
            else:
                rl, sl, resl = optim.apply_event_ring_whatif(
                    spec, rl, sl, resl, a_l, ws_l, ts_col, coef_of(x),
                    x["lrs"], x["prev"], x["slot"])
            return pack_carry(rl, sl, resl), None
    else:
        def event(carry, x):
            rl, sl, resl = unpack_carry(carry)
            w = pulled_weights(rl, x)
            lo = jax.lax.axis_index("learner") * cl
            g = local_gradients(w, x, lo)             # (cl, D)
            si = jax.lax.axis_index("ps")
            if mode == "combine":
                coef_l = jax.lax.dynamic_slice_in_dim(coef_of(x), lo, cl, 0)
                ghat = optim.combine_spmd(g, coef_l, "learner")   # (D,)
                gp = flatten.pad_flat(shard_slice(ghat, si), Wl)[None]
                cvec = jnp.ones((1,), jnp.float32)
                lvec = x["lrs"][:1]
            else:
                g_all = jax.lax.all_gather(g, "learner", axis=0, tiled=True)
                gp = flatten.pad_flat(shard_slice(g_all, si), Wl)  # (c, Wl)
                cvec = coef_of(x)
                lvec = x["lrs"]
            if ring_impl == "pallas":
                idx = jnp.stack([x["prev"], x["slot"]])
                rl, sl, resl = replay_ring.ring_apply(
                    rl, sl, resl, gp, cvec, lvec, idx, spec=spec, mode=mode)
            else:
                rl, sl, resl = optim.apply_event_ring(
                    spec, rl, sl, resl, gp, cvec, lvec, x["prev"],
                    x["slot"], mode)
            return pack_carry(rl, sl, resl), None

    carry_specs = sharding_lib.spmd_carry_specs()
    xs_specs = sharding_lib.spmd_xs_specs(xs_keys)
    if whatif:
        def run(carry, xs, aux):
            return jax.lax.scan(functools.partial(event, aux), carry, xs)[0]
        smapped = mesh_lib.shard_map(
            run, mesh,
            in_specs=(carry_specs, xs_specs, sharding_lib.spmd_aux_specs()),
            out_specs=carry_specs)
    else:
        def run(carry, xs):
            return jax.lax.scan(event, carry, xs)[0]
        smapped = mesh_lib.shard_map(run, mesh,
                                     in_specs=(carry_specs, xs_specs),
                                     out_specs=carry_specs)
    return jax.jit(smapped, donate_argnums=0)


def _materialize_batches(trace: ArrivalTrace, batch_fn: Callable):
    """Evaluate ``batch_fn(learner, minibatch_idx)`` for every trace slot
    and stack into a pytree with leading (steps, c) axes — (steps, c, gs)
    with learner groups: slot (j, i) aggregates the gs member minibatches
    ``batch_fn(member, push_counter)``.  Stacking happens host-side so the
    whole trace's data moves to device in ONE transfer per leaf (batch_fns
    returning numpy avoid per-minibatch device_puts)."""
    members = trace.member_learners()          # None when ungrouped
    rows = []
    for j in range(trace.steps):
        if members is None:
            slots = [batch_fn(int(trace.learner[j, i]),
                              int(trace.mb_index[j, i]))
                     for i in range(trace.c)]
        else:
            slots = [jax.tree.map(
                lambda *xs: np.stack([np.asarray(x) for x in xs]),
                *[batch_fn(int(m), int(trace.mb_index[j, i]))
                  for m in members[j, i]])
                for i in range(trace.c)]
        rows.append(jax.tree.map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *slots))
    return jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *rows)


def _check_trace(trace: ArrivalTrace, run: RunConfig) -> None:
    """A trace is only valid for the RunConfig that scheduled it."""
    if (trace.protocol != run.protocol
            or trace.n_learners != run.n_learners
            or trace.c != run.gradients_per_update):
        raise ValueError(
            f"trace ({trace.protocol}, λ={trace.n_learners}, c={trace.c}) "
            f"was not scheduled from this RunConfig ({run.protocol}, "
            f"λ={run.n_learners}, c={run.gradients_per_update})")
    topo = Topology.from_run(run)
    if trace.topology != topo:
        raise ValueError(
            f"trace topology ({trace.topology}) disagrees with this "
            f"RunConfig's ({topo}) — reschedule the trace for this config")
    # the trace bakes policy-resolved LRs in; re-resolving from this run's
    # policy must reproduce them, or the caller is silently sweeping
    # base_lr/lr_policy on a stale trace
    want_lrs, want_mode = resolve_trace_lrs(run, trace.pulled_ts)
    if trace.mode != want_mode or not np.allclose(trace.lrs, want_lrs):
        raise ValueError(
            f"trace LRs/mode ({trace.mode}) disagree with this RunConfig's "
            f"lr_policy={run.lr_policy!r}/base_lr={run.base_lr} — reschedule "
            f"the trace for this config")
    if (trace.serving is None) != (run.serving is None):
        raise ValueError(
            f"trace {'carries' if trace.serving is not None else 'has no'} "
            f"serving lane but run.serving is "
            f"{'unset' if run.serving is None else 'set'} — reschedule the "
            f"trace for this config")


def _trace_xs(trace: ArrivalTrace, K: int, batch_fn: Optional[Callable],
              batches=None) -> dict:
    """The scan inputs of one trace: ring indices (pre-wrapped mod K),
    per-event LRs, and the whole trace's minibatches — materialized per
    slot via ``batch_fn``, or taken pre-staged from ``batches`` (a pytree
    with leading (steps, c) axes — (steps, c, gs) with learner groups —
    e.g. a problem's vectorized ``stage_minibatches`` output), or omitted
    entirely when both are None (the what-if replay computes closed-form
    gradients in-kernel and never touches data).  With S > 1 PS shards
    ``ts`` carries the (steps, c, S) per-shard pulled rows."""
    steps_idx = np.arange(trace.steps)
    if batches is not None:
        batches = jax.tree.map(jnp.asarray, batches)
    elif batch_fn is not None:
        batches = _materialize_batches(trace, batch_fn)
    ts = (trace.pulled_ts if trace.shard_pulled_ts is None
          else trace.shard_pulled_ts)
    xs = {
        "ts": jnp.asarray(ts % K, jnp.int32),
        "prev": jnp.asarray(steps_idx % K, jnp.int32),
        "slot": jnp.asarray((steps_idx + 1) % K, jnp.int32),
        "lrs": jnp.asarray(trace.lrs, jnp.float32),
    }
    if batches is not None:
        xs["batch"] = batches
    if trace.valid is not None:
        xs["coef"] = jnp.asarray(trace.event_coef())
    if trace.member_valid is not None:
        xs["mcoef"] = jnp.asarray(trace.member_coef())
    return xs


def replay(trace: ArrivalTrace, run: RunConfig, *,
           grad_fn: Optional[Callable] = None,
           init_params,
           batch_fn: Optional[Callable] = None,
           batches=None,
           eval_fn: Optional[Callable] = None,
           eval_every: int = 0,
           flat_grad=None,
           placement: Optional[str] = None,
           spmd_assembly: str = "all_gather",
           serve_batches=None,
           serve_eval_fn: Optional[Callable] = None) -> SimResult:
    """Execute a scheduled trace against real gradients, compiled.

    ``grad_fn(params, batch) -> grads`` must be vmappable (any jit-able JAX
    function is).  Minibatches come from exactly one of ``batch_fn``
    (``(learner_idx, minibatch_idx) -> batch``, evaluated host-side per
    trace slot) or ``batches`` (a pre-staged pytree with leading (steps, c)
    axes — e.g. a problem's vectorized ``stage_minibatches`` output, which
    skips the per-slot Python staging loop entirely; this is where most of
    the single-replay wall clock went before PR 6).

    ``run.ring_impl``/``run.ring_dtype`` select the scan body and ring
    storage (DESIGN.md §12): the default ``auto`` runs the fused megakernel
    path (Pallas on TPU, its bitwise jnp twin elsewhere) with a donated
    carry; ``stock`` forces the pre-megakernel chain.

    ``flat_grad = ("quadratic", a, w*)`` (flat (D,) fp32 arrays in the
    ``optim.flatten`` layout) opts into the **what-if replay**: gradients
    are computed in-kernel as ``a ⊙ (w_pulled − w*)`` and no data is staged
    — peak memory O(K·D_ring + D), which is what makes trace-driven studies
    at ``configs/`` big-model D feasible.  Requires a kernel-supported
    optimizer, combine mode, the trivial topology and a non-stock impl
    (``placement="spmd"`` lifts the topology restriction: closed-form
    gradients are shard-local, so every PS device what-ifs its own slice);
    anything else falls back to the staged-gradient path (so ``batch_fn``/
    ``batches`` must still be provided when those conditions can miss).

    ``placement`` (default ``run.placement``) selects where the scan runs
    (DESIGN.md §13): ``"single"`` is the one-device program above;
    ``"spmd"`` shard_maps it over a ``make_sim_mesh(S, L)`` device mesh —
    per-shard rings on distinct "ps" devices, slot blocks on distinct
    "learner" devices, cross-shard pulls / combine pushes as real
    all_gather/psum (or ppermute, ``spmd_assembly="ppermute"``)
    collectives.  What-if spmd replay is bitwise-equal to single-device;
    staged-gradient paths track it to ~1 ulp/event (XLA fusion inside the
    shard_map body; psum reduction order at L > 1) — see DESIGN.md §13.

    With ``eval_every`` set, the scan runs in eval_every-sized segments;
    a trailing remainder segment (steps % eval_every != 0) has a different
    scan length and compiles a second program — pick eval_every | steps in
    compile-sensitive sweeps.

    **Serving lane** (DESIGN.md §14): a trace scheduled with
    ``run.serving`` set carries a resolved ``ServingTrace``; the scan then
    additionally captures every *published* weight version (a ring-row
    read at the version's birth — branch-free, one extra
    dynamic-update-slice per event) and, post-scan, evaluates each request
    batch against the version that served it.  ``serve_batches`` (a pytree
    with a leading (R,) request axis, e.g. a problem's ``stage_requests``)
    and ``serve_eval_fn(params, request_batch) -> scalar metric`` are then
    required.  A serving trace disables the what-if fast path (the
    staged-gradient scan carries the snapshot buffer); a run *without*
    serving compiles the exact pre-serving program — same scan-fn cache
    entry, bitwise-identical replay.
    """
    _check_trace(trace, run)
    serving = trace.serving
    if serving is not None and (serve_batches is None
                                or serve_eval_fn is None):
        raise ValueError(
            "this trace carries a serving lane: pass serve_batches (a "
            "pytree with a leading (R,) request axis, e.g. "
            "problem.stage_requests(trace.serving, run.serving)) and "
            "serve_eval_fn(params, request_batch) -> scalar metric")
    if serving is None and (serve_batches is not None
                            or serve_eval_fn is not None):
        raise ValueError(
            "serve_batches/serve_eval_fn passed but the trace has no "
            "serving lane — schedule it from a RunConfig with "
            "serving=FleetConfig(...)")
    steps, c = trace.steps, trace.c
    K = trace.max_staleness + 1
    topo = trace.topology
    S, gs = topo.shards, trace.group_size
    spec, opt_state = init_ps_state(run, init_params)
    layout = flatten.layout_of(init_params)
    if S > 1 and not spec.kernel_supported:
        raise ValueError(
            f"{spec.optimizer!r} has no flat event path, so no sharded "
            f"replay (shards={S}); use a kernel-supported optimizer")
    if trace.valid is not None and trace.mode != "combine":
        raise ValueError(
            f"elastic traces replay in 'combine' mode only (cancelled "
            f"slots fold with coefficient 0; sequential optimizer events "
            f"cannot be masked), got mode={trace.mode!r}")

    place = placement if placement is not None else run.placement
    if place == "spmd":
        return _replay_spmd(trace, run, spec=spec, opt_state=opt_state,
                            layout=layout, grad_fn=grad_fn,
                            init_params=init_params, batch_fn=batch_fn,
                            batches=batches, eval_fn=eval_fn,
                            eval_every=eval_every, flat_grad=flat_grad,
                            assembly=spmd_assembly)
    if place != "single":
        raise ValueError(f"unknown placement {place!r}: expected "
                         f"'single' or 'spmd'")

    impl = optim.resolve_ring_impl(run.ring_impl, spec)
    ef = run.ring_dtype == "bf16"
    whatif = (flat_grad is not None and impl != "stock"
              and trace.mode == "combine" and S == 1 and gs == 1
              and serving is None)
    if whatif:
        kind = flat_grad[0]
        if kind != "quadratic":
            raise ValueError(f"unknown flat_grad kind {kind!r}; expected "
                             f"('quadratic', a, wstar)")
    elif grad_fn is None:
        raise ValueError("grad_fn is required outside the what-if replay")
    elif (batch_fn is None) == (batches is None):
        raise ValueError("pass exactly one of batch_fn / batches")

    scan_fn = _make_scan_fn(None if whatif else grad_fn, spec, trace.mode,
                            c, K, layout, shards=S, group_size=gs,
                            masked=trace.valid is not None,
                            member_masked=trace.member_valid is not None,
                            ring_impl=impl, ring_dtype=run.ring_dtype,
                            whatif=whatif, publish=serving is not None)

    xs = _trace_xs(trace, K, None if whatif else batch_fn,
                   batches=None if whatif else batches)
    if serving is not None:
        xs["pub"] = jnp.asarray(_pub_index(serving, steps), jnp.int32)
    flat0 = flatten.tree_to_flat(init_params)
    D = flat0.shape[0]
    Dp = topo.padded_width(D)
    if impl != "stock":
        # flat (K, width) ring in the ring dtype — sharded traces use the
        # concatenated shard rows (width = S·Dp ≥ D), the Pallas megakernel
        # a row-block tile multiple on top; padding zeros are inert.  With
        # a bf16 ring the fp32 error-feedback residue of the latest row
        # completes the carry; the scan donates all three buffers.
        from repro.kernels import replay_ring   # lazy: import cycle
        width = D if S == 1 else S * Dp
        if impl == "pallas":
            width = replay_ring.padded_width(width)
        rdt = jnp.bfloat16 if ef else jnp.float32
        flat_pad = flatten.pad_flat(flat0, width)
        q0 = flat_pad.astype(rdt)
        ring = jnp.tile(q0[None], (K, 1))
        res0 = (flat_pad - q0.astype(jnp.float32)) if ef else None
        s0 = None
        if spec.state_keys:
            s0 = flatten.pad_flat(
                flatten.tree_to_flat(opt_state[spec.state_keys[0]]), width)
        carry = (ring, s0, res0)

        def params_of(carry, done):
            row = carry[0][done % K].astype(jnp.float32)
            if ef:
                row = row + carry[2]
            return _unflatten_jit(layout)(row[:D])

        aux = None
        if whatif:
            aux = (flatten.pad_flat(flat_grad[1].astype(jnp.float32), width),
                   flatten.pad_flat(flat_grad[2].astype(jnp.float32), width))
    elif S > 1:
        # per-shard rings: (S, K, Dp), row r of shard s = snapshot ts=r of
        # the shard's slice (the σ_s ≤ σ invariant keeps K a valid bound)
        ring = jnp.broadcast_to(
            flatten.shard_pack(flat0, S, Dp)[:, None, :], (S, K, Dp))
    else:
        ring = jnp.broadcast_to(flat0, (K, D))
    if impl == "stock" and spec.kernel_supported:
        # flat-domain carry: ring + the (D,)/(S, Dp) state vector (or None)
        s0 = None
        if spec.state_keys:
            s0 = flatten.tree_to_flat(opt_state[spec.state_keys[0]])
            if S > 1:
                s0 = flatten.shard_pack(s0, S, Dp)
        carry = (ring, s0)

        def params_of(carry, done):
            row = (carry[0][done % K] if S == 1
                   else flatten.shard_unpack(carry[0][:, done % K], D))
            return _unflatten_jit(layout)(row)
    elif impl == "stock":
        carry = (ring, (init_params, opt_state))

        def params_of(carry, done):
            return carry[1][0]

    if serving is not None:
        # snapshot buffer riding the carry: one row per published version
        # (+ the inert dummy row unpublished versions write).  Row 0 is
        # version 0 — the init weights every replica boots with, i.e. the
        # ring's initial row (already quantized under a bf16 ring: the
        # publication tolerance policy).
        P = int(serving.pub_versions.shape[0])
        row0 = carry[0][0].astype(jnp.float32)
        snaps0 = jnp.zeros((P + 1,) + row0.shape, jnp.float32).at[0].set(row0)
        core_params_of = params_of

        def params_of(carry, done):
            return core_params_of(carry[0], done)

        carry = (carry, snaps0)

    def advance(carry, seg):
        return (scan_fn(carry, seg, aux) if whatif
                else scan_fn(carry, seg))

    history = []
    if eval_fn and eval_every:
        done = 0
        while done < steps:
            take = min(eval_every, steps - done)
            seg = jax.tree.map(lambda a: a[done:done + take], xs)
            carry = advance(carry, seg)
            done += take
            if done % eval_every == 0:
                history.append({"update": done,
                                "time": float(trace.event_time[done - 1]),
                                **eval_fn(params_of(carry, done))})
    else:
        carry = advance(carry, xs)

    params = params_of(carry, steps)
    serve_result = None
    if serving is not None:
        serve_result = _serve_eval(carry[1], layout, D, serving,
                                   serve_batches, serve_eval_fn)
    return SimResult(trace.clock_log(), steps, trace.simulated_time,
                     trace.minibatches, params, history,
                     serving=serve_result)


def _pub_index(serving, steps: int) -> np.ndarray:
    """(steps,) snapshot-buffer index per scan step: version j + 1 is born
    when event j fires, so step j writes its new ring row to the version's
    position in ``pub_versions`` when some replica publishes it, else to
    the inert dummy row (index P — branch-free capture)."""
    pv = np.asarray(serving.pub_versions, np.int64)
    P = pv.shape[0]
    born = np.arange(1, steps + 1)
    idx = np.searchsorted(pv, born)
    hit = (idx < P) & (pv[np.minimum(idx, P - 1)] == born)
    return np.where(hit, idx, P)


def _serve_eval(snaps, layout, D: int, serving, serve_batches,
                serve_eval_fn, chunk: int = 512):
    """The serving lane's evaluation stage: map each request batch onto the
    captured snapshot of the version that served it, in chunked vmap lanes
    (at most two compiled programs: full chunks + one remainder).  Dropped
    requests (no live replica) score 0."""
    from repro.serve.fleet import ServingResult   # lazy: layering
    rows = snaps[:, :D]                           # (P + 1, D) fp32
    req_pub = jnp.asarray(serving.req_pub, jnp.int32)

    @jax.jit
    def lane(idx, batch):
        def one(i, b):
            return serve_eval_fn(flatten.flat_to_tree(rows[i], layout), b)
        return jax.vmap(one)(idx, batch)

    R = serving.n_requests
    parts = []
    for lo in range(0, R, chunk):
        hi = min(lo + chunk, R)
        part = lane(req_pub[lo:hi],
                    jax.tree.map(lambda a: jnp.asarray(a)[lo:hi],
                                 serve_batches))
        parts.append(np.asarray(part))
    metric = (np.concatenate(parts) if parts
              else np.zeros(0, np.float32))
    metric = np.where(serving.served, metric, 0.0).astype(np.float32)
    return ServingResult(trace=serving, request_metric=metric)


def _replay_spmd(trace: ArrivalTrace, run: RunConfig, *, spec, opt_state,
                 layout, grad_fn, init_params, batch_fn, batches, eval_fn,
                 eval_every, flat_grad, assembly) -> SimResult:
    """The ``placement="spmd"`` arm of :func:`replay`: resolve the trace's
    :func:`placement_plan` against the visible devices, build the sharded
    ``(S, K, Wl)`` carry, and drive the shard_mapped scan
    (:func:`_make_spmd_scan_fn`).  Validations shared with the single
    placement already ran in ``replay``."""
    steps, c = trace.steps, trace.c
    K = trace.max_staleness + 1
    topo = trace.topology
    S, gs = topo.shards, trace.group_size
    if trace.serving is not None:
        raise ValueError(
            "serving traces cannot replay with placement='spmd': the "
            "serving lane captures published ring rows inside the "
            "single-device scan, which shard_map splits into per-shard "
            "(K, Dp) rings; replay with placement='single' (the default)")
    if not spec.kernel_supported:
        raise ValueError(
            f"placement='spmd' needs a kernel-supported optimizer (flat "
            f"per-shard ring carries); {spec.optimizer!r} has none")
    # "stock" has no per-device flat ring body; its fused twin is bitwise
    # at fp32 (RunConfig validation already keeps bf16 off stock)
    impl = optim.resolve_ring_impl(run.ring_impl, spec)
    if impl == "stock":
        impl = "fused"
    ef = run.ring_dtype == "bf16"
    whatif = (flat_grad is not None and trace.mode == "combine" and gs == 1)
    if whatif:
        kind = flat_grad[0]
        if kind != "quadratic":
            raise ValueError(f"unknown flat_grad kind {kind!r}; expected "
                             f"('quadratic', a, wstar)")
    elif grad_fn is None:
        raise ValueError("grad_fn is required outside the what-if replay")
    elif (batch_fn is None) == (batches is None):
        raise ValueError("pass exactly one of batch_fn / batches")

    plan = placement_plan(trace, run, jax.device_count())
    if whatif:
        # closed-form gradients are shard-local: no learner axis needed
        plan = PlacementPlan(shards=plan.shards, learners=1, c=c)

    xs = _trace_xs(trace, K, None if whatif else batch_fn,
                   batches=None if whatif else batches)
    if xs["ts"].ndim == 2:
        xs["ts"] = xs["ts"][..., None]      # (steps, c, 1): one shard column
    scan_fn = _make_spmd_scan_fn(None if whatif else grad_fn, spec,
                                 trace.mode, c, K, layout, plan,
                                 tuple(sorted(xs)), group_size=gs,
                                 masked=trace.valid is not None,
                                 member_masked=trace.member_valid is not None,
                                 ring_impl=impl, ring_dtype=run.ring_dtype,
                                 whatif=whatif, assembly=assembly)

    flat0 = flatten.tree_to_flat(init_params)
    D = flat0.shape[0]
    Dp = topo.padded_width(D)
    Wl = _spmd_local_width(D, S, impl)
    rdt = jnp.bfloat16 if ef else jnp.float32
    packed = flatten.pad_flat(flatten.shard_pack(flat0, S, Dp), Wl)  # (S, Wl)
    q0 = packed.astype(rdt)
    ring = jnp.tile(q0[:, None, :], (1, K, 1))                   # (S, K, Wl)
    res0 = (packed - q0.astype(jnp.float32)) if ef else None
    s0 = None
    if spec.state_keys:
        s0 = flatten.pad_flat(
            flatten.shard_pack(
                flatten.tree_to_flat(opt_state[spec.state_keys[0]]), S, Dp),
            Wl)
    carry = (ring, s0, res0)

    def params_of(carry, done):
        row = carry[0][:, done % K, :].astype(jnp.float32)       # (S, Wl)
        if ef:
            row = row + carry[2]
        return _unflatten_jit(layout)(flatten.shard_unpack(row[:, :Dp], D))

    aux = None
    if whatif:
        aux = (flatten.pad_flat(
                   flatten.shard_pack(flat_grad[1].astype(jnp.float32),
                                      S, Dp), Wl),
               flatten.pad_flat(
                   flatten.shard_pack(flat_grad[2].astype(jnp.float32),
                                      S, Dp), Wl))

    def advance(carry, seg):
        return (scan_fn(carry, seg, aux) if whatif
                else scan_fn(carry, seg))

    history = []
    if eval_fn and eval_every:
        done = 0
        while done < steps:
            take = min(eval_every, steps - done)
            seg = jax.tree.map(lambda a: a[done:done + take], xs)
            carry = advance(carry, seg)
            done += take
            if done % eval_every == 0:
                history.append({"update": done,
                                "time": float(trace.event_time[done - 1]),
                                **eval_fn(params_of(carry, done))})
    else:
        carry = advance(carry, xs)

    params = params_of(carry, steps)
    return SimResult(trace.clock_log(), steps, trace.simulated_time,
                     trace.minibatches, params, history)


def replay_batch(traces: Sequence[ArrivalTrace],
                 runs: Sequence[RunConfig], *,
                 grad_fn: Callable,
                 init_params,
                 batch_fns: Optional[Sequence[Callable]] = None,
                 batches: Optional[Sequence] = None,
                 eval_fn: Optional[Callable] = None,
                 eval_every: int = 0) -> list:
    """Replay B shape-compatible traces as ONE vmapped device program.

    The sweep fast path (DESIGN.md §5): grid points that share trace shape
    — same ``steps`` and ``c`` (and therefore the same scan length and
    event arity) — plus the same optimizer spec, update mode, ``grad_fn``
    and parameter layout differ only in *data*: ring indices, LRs, and
    minibatches.  Stacking those along a leading (B,) axis and vmapping the
    identical per-event scan body executes a 5-seed × 4-config cell as one
    ``lax.scan`` instead of 20 sequential replays.  The ring is sized to
    the **group maximum** staleness (ring size never changes the math —
    only which row a snapshot lands in), so traces with different measured
    σ_max still batch.

    Per-lane results match :func:`replay` of the same trace to fp32
    accumulation tolerance (the vmapped body computes the same per-lane
    math, but XLA fuses the batched ops differently — observed drift
    ~1e-7 after tens of updates, same order as the legacy-vs-compiled
    drift in EXPERIMENTS.md §Sim).
    Restrictions (the driver falls back to sequential replays otherwise):
    kernel-supported optimizers only (sgd / momentum / adagrad — adamw's
    pytree carry has no flat lane layout), trivial (Rudra-base) topology
    only (sharded/grouped traces replay per-spec), all lanes agreeing on
    elasticity (masked combine-mode traces batch with other masked lanes —
    the per-event coefficients are just more lane data), one shared ``grad_fn`` and
    ``init_params`` (same problem), per-lane ``batch_fns`` — or per-lane
    pre-staged ``batches`` (leading (steps, c) axes; a problem's vectorized
    ``stage_minibatches``), which skips the per-slot staging loop entirely.
    """
    traces, runs = list(traces), list(runs)
    B = len(traces)
    if (batch_fns is None) == (batches is None):
        raise ValueError("pass exactly one of batch_fns / batches")
    lanes = list(batch_fns) if batches is None else list(batches)
    if not (B and len(runs) == B and len(lanes) == B):
        raise ValueError("traces / runs / batch data must align, non-empty")
    for trace, run in zip(traces, runs):
        _check_trace(trace, run)
        if trace.serving is not None:
            raise ValueError(
                "batched replay does not support serving traces: the "
                "serving lane adds a per-lane snapshot carry plus a "
                "post-scan request evaluation; replay serving specs "
                "individually (the experiment driver excludes them from "
                "batch cells automatically)")
    steps, c, mode = traces[0].steps, traces[0].c, traces[0].mode
    masked = traces[0].valid is not None
    for trace in traces[1:]:
        if (trace.steps, trace.c, trace.mode) != (steps, c, mode):
            raise ValueError(
                f"batch members must share trace shape: "
                f"(steps={steps}, c={c}, mode={mode!r}) vs "
                f"(steps={trace.steps}, c={trace.c}, mode={trace.mode!r})")
        if (trace.valid is not None) != masked:
            raise ValueError(
                "batch members must agree on elasticity: masked (elastic) "
                "and dense traces compile different scan bodies — group "
                "them separately")
    if masked and mode != "combine":
        raise ValueError("elastic traces replay in 'combine' mode only")
    spec = optim.spec_from_run(runs[0])
    for run in runs[1:]:
        other = optim.spec_from_run(run)
        if other != spec:
            raise ValueError(f"batch members must share the optimizer "
                             f"spec: {spec} vs {other}")
    ring_cfg = (runs[0].ring_impl, runs[0].ring_dtype)
    for run in runs[1:]:
        if (run.ring_impl, run.ring_dtype) != ring_cfg:
            raise ValueError(
                f"batch members must share (ring_impl, ring_dtype): "
                f"{ring_cfg} vs {(run.ring_impl, run.ring_dtype)} — a bf16 "
                f"lane's carry has a different dtype/residue layout")
    for run in runs:
        if run.placement != "single":
            raise ValueError(
                f"batched replay is single-placement only (a lane axis and "
                f"a device mesh cannot share the carry); replay "
                f"placement={run.placement!r} specs individually")
    opt_state = optim.init_state(spec, init_params)
    if not spec.kernel_supported:
        raise ValueError(f"{spec.optimizer!r} has no flat lane layout; "
                         f"replay each trace sequentially")
    for trace, run in zip(traces, runs):
        if not trace.topology.is_trivial(run.n_learners):
            raise ValueError(
                f"batched replay supports the trivial (Rudra-base) "
                f"topology only; got {trace.topology} — replay "
                f"sharded/grouped traces sequentially")
    K = max(trace.max_staleness for trace in traces) + 1
    layout = flatten.layout_of(init_params)
    impl = optim.resolve_ring_impl(runs[0].ring_impl, spec)
    ef = runs[0].ring_dtype == "bf16"
    scan_fn = _make_scan_fn(grad_fn, spec, mode, c, K, layout, batched=True,
                            masked=masked, ring_impl=impl,
                            ring_dtype=runs[0].ring_dtype)

    if batches is None:
        xs_lanes = [_trace_xs(trace, K, fn)
                    for trace, fn in zip(traces, lanes)]
    else:
        xs_lanes = [_trace_xs(trace, K, None, batches=b)
                    for trace, b in zip(traces, lanes)]
    # prev/slot are step-indexed mod the shared K — identical in every lane;
    # keep them unbatched so the scan's ring write stays a common-row
    # dynamic-update-slice (see _make_scan_fn)
    xs = jax.tree.map(
        lambda *a: jnp.stack(a),
        *[{k: v for k, v in lane.items() if k not in ("prev", "slot")}
          for lane in xs_lanes])
    xs["prev"] = xs_lanes[0]["prev"]
    xs["slot"] = xs_lanes[0]["slot"]
    flat0 = flatten.tree_to_flat(init_params)
    D = flat0.shape[0]
    if impl != "stock":
        from repro.kernels import replay_ring   # lazy: import cycle
        width = replay_ring.padded_width(D) if impl == "pallas" else D
        rdt = jnp.bfloat16 if ef else jnp.float32
        flat_pad = flatten.pad_flat(flat0, width)
        q0 = flat_pad.astype(rdt)
        ring = jnp.tile(q0[None, None], (B, K, 1))
        res0 = (jnp.tile((flat_pad - q0.astype(jnp.float32))[None], (B, 1))
                if ef else None)
        s0 = None
        if spec.state_keys:
            s_flat = flatten.pad_flat(
                flatten.tree_to_flat(opt_state[spec.state_keys[0]]), width)
            s0 = jnp.tile(s_flat[None], (B, 1))
        carry = (ring, s0, res0)

        def params_of(carry, lane, done):
            row = carry[0][lane, done % K].astype(jnp.float32)
            if ef:
                row = row + carry[2][lane]
            return _unflatten_jit(layout)(row[:D])
    else:
        ring = jnp.broadcast_to(flat0, (B, K) + flat0.shape)
        s0 = None
        if spec.state_keys:
            s_flat = flatten.tree_to_flat(opt_state[spec.state_keys[0]])
            s0 = jnp.broadcast_to(s_flat, (B,) + s_flat.shape)
        carry = (ring, s0)

        def params_of(carry, lane, done):
            return _unflatten_jit(layout)(carry[0][lane, done % K])

    def segment(lo, hi):
        # prev/slot are unbatched (steps,); everything else is (B, steps, …)
        return {k: (v[lo:hi] if k in ("prev", "slot")
                    else jax.tree.map(lambda a: a[:, lo:hi], v))
                for k, v in xs.items()}

    histories = [[] for _ in range(B)]
    if eval_fn and eval_every:
        done = 0
        while done < steps:
            take = min(eval_every, steps - done)
            seg = segment(done, done + take)
            carry = scan_fn(carry, seg)
            done += take
            if done % eval_every == 0:
                for b in range(B):
                    histories[b].append(
                        {"update": done,
                         "time": float(traces[b].event_time[done - 1]),
                         **eval_fn(params_of(carry, b, done))})
    else:
        carry = scan_fn(carry, xs)

    return [SimResult(trace.clock_log(), steps, trace.simulated_time,
                      trace.minibatches, params_of(carry, b, steps),
                      histories[b])
            for b, trace in enumerate(traces)]
