"""Learning-rate policies (paper §3.2 / §5.1 and footnote 3).

* ``const``             — α = α₀ (the paper's divergent control at n = 30).
* ``sqrt_scale``        — hardsync: α = α₀·√(λμ/B)  (§3.2).
* ``staleness_inverse`` — n-softsync: α = α₀/⟨σ⟩ = α₀/n  (Eq. 6).
* ``per_gradient``      — footnote 3: each gradient g with staleness σ_g gets
                          α_g = α₀ / max(1, σ_g).  The paper suggests but does
                          not evaluate this; we implement it as a beyond-paper
                          feature and benchmark it against Eq. 6.

Policies are callables ``(update_timestamp, gradient_timestamps) -> α`` (or a
list of per-gradient α for ``per_gradient``), matching what
``ParameterServerState.push_gradient`` expects.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.config import RunConfig

LR = Union[float, List[float]]


def resolve_trace_lrs(run: RunConfig, pulled_ts: np.ndarray,
                      update_ts: Optional[np.ndarray] = None
                      ) -> Tuple[np.ndarray, str]:
    """Vectorized trace-time policy resolution (schedule pass, DESIGN.md §4).

    ``pulled_ts`` is the trace's (steps, c) vector-clock matrix: row j holds
    the timestamps of the gradients folded into update j (fired at PS
    timestamp ``update_ts[j]``, default ``j``).  Returns the (steps, c)
    float64 LR matrix plus the ``repro.optim`` update mode the policy
    implies — scalar policies broadcast one α per event (``combine``,
    Eqs. 3/5); ``per_gradient`` resolves footnote 3's α₀/max(1, σ_g) per
    slot (``sequential``).  This is the ONE implementation of the policy
    formulas: :func:`make_lr_policy` evaluates it per event.
    """
    pulled_ts = np.asarray(pulled_ts)
    steps, c = pulled_ts.shape
    if run.lr_policy == "const":
        return np.full((steps, c), run.base_lr), "combine"
    if run.lr_policy == "sqrt_scale":
        scale = math.sqrt(run.n_learners * run.minibatch / run.ref_batch)
        return np.full((steps, c), run.base_lr * scale), "combine"
    if run.lr_policy == "staleness_inverse":
        sigma = max(1.0, run.expected_staleness)
        return np.full((steps, c), run.base_lr / sigma), "combine"
    if run.lr_policy == "per_gradient":
        if update_ts is None:
            update_ts = np.arange(steps)
        sigma = (np.asarray(update_ts, dtype=np.float64)[:, None]
                 - pulled_ts.astype(np.float64))
        return run.base_lr / np.maximum(1.0, sigma), "sequential"
    raise ValueError(run.lr_policy)


def make_lr_policy(run: RunConfig):
    """Per-event ``(update_timestamp, gradient_timestamps) -> α`` view of
    :func:`resolve_trace_lrs` (single source of the formulas) — what the
    legacy per-arrival PS loop calls at each fire."""
    scalar_mode = run.lr_policy != "per_gradient"

    def policy(ts: int, clocks: Sequence[int]) -> LR:
        row, _ = resolve_trace_lrs(run, np.asarray([list(clocks)]),
                                   update_ts=np.asarray([ts]))
        return float(row[0, 0]) if scalar_mode else row[0].tolist()
    return policy


def hardsync_lr(run: RunConfig) -> float:
    """α₀·√(λμ/B) — the paper's hardsync scaling (§3.2)."""
    return run.base_lr * math.sqrt(
        run.n_learners * run.minibatch / run.ref_batch)


def softsync_lr(run: RunConfig,
                measured_staleness: Optional[float] = None) -> float:
    """α₀/⟨σ⟩ (Eq. 6).  Pass the measured ⟨σ⟩ when available (the distributed
    round-based engine has ⟨σ⟩ = (n−1)/2 rather than the pipelined n)."""
    sigma = (measured_staleness if measured_staleness is not None
             else run.expected_staleness)
    return run.base_lr / max(1.0, sigma)
