"""Learning-rate policies (paper §3.2 / §5.1 and footnote 3).

* ``const``             — α = α₀ (the paper's divergent control at n = 30).
* ``sqrt_scale``        — hardsync: α = α₀·√(λμ/B)  (§3.2).
* ``staleness_inverse`` — n-softsync: α = α₀/⟨σ⟩ = α₀/n  (Eq. 6).
* ``per_gradient``      — footnote 3: each gradient g with staleness σ_g gets
                          α_g = α₀ / max(1, σ_g).  The paper suggests but does
                          not evaluate this; we implement it as a beyond-paper
                          feature and benchmark it against Eq. 6.

Policies are callables ``(update_timestamp, gradient_timestamps) -> α`` (or a
list of per-gradient α for ``per_gradient``), matching what
``ParameterServerState.push_gradient`` expects.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Union

from repro.config import RunConfig

LR = Union[float, List[float]]


def make_lr_policy(run: RunConfig):
    base = run.base_lr

    if run.lr_policy == "const":
        def policy(ts: int, clocks: Sequence[int]) -> LR:
            return base
        return policy

    if run.lr_policy == "sqrt_scale":
        scale = math.sqrt(run.n_learners * run.minibatch / run.ref_batch)

        def policy(ts: int, clocks: Sequence[int]) -> LR:
            return base * scale
        return policy

    if run.lr_policy == "staleness_inverse":
        sigma = max(1.0, run.expected_staleness)

        def policy(ts: int, clocks: Sequence[int]) -> LR:
            return base / sigma
        return policy

    if run.lr_policy == "per_gradient":
        def policy(ts: int, clocks: Sequence[int]) -> LR:
            # staleness of gradient g when applied now: ts − ts_g
            return [base / max(1.0, float(ts - t)) for t in clocks]
        return policy

    raise ValueError(run.lr_policy)


def hardsync_lr(run: RunConfig) -> float:
    """α₀·√(λμ/B) — the paper's hardsync scaling (§3.2)."""
    return run.base_lr * math.sqrt(
        run.n_learners * run.minibatch / run.ref_batch)


def softsync_lr(run: RunConfig, measured_staleness: float = None) -> float:
    """α₀/⟨σ⟩ (Eq. 6).  Pass the measured ⟨σ⟩ when available (the distributed
    round-based engine has ⟨σ⟩ = (n−1)/2 rather than the pipelined n)."""
    sigma = (measured_staleness if measured_staleness is not None
             else run.expected_staleness)
    return run.base_lr / max(1.0, sigma)
