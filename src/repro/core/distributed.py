"""TPU-native Rudra protocols as SPMD programs (DESIGN.md §2).

Inside one SPMD program there is no true asynchrony, so the n-softsync
protocol is realised as **round-based softsync**: one training round = n
sequential PS update events.  All λ learners (data-axis shard groups)
compute gradients against the round-start weights θ(i); event j folds the
mean gradient of group j with staleness σ_j = j, so σ ∈ {0..n−1} and
⟨σ⟩ = (n−1)/2.  The LR policy sees the *measured* ⟨σ⟩.

Two engines:

* ``sequential`` — faithful semantics.  ``lax.scan`` over the n groups: each
  iteration computes that group's gradient (backward over B/n samples) and
  applies the update immediately.  Total FLOPs equal one pass over the global
  batch, but the collective pattern is n gradient all-reduces per round —
  exactly the PS-traffic penalty the paper measures for λ-softsync (§5.2).

* ``fused`` — beyond-paper optimization.  Because the optimizer update is
  linear in the gradients (SGD exactly; momentum after folding the geometric
  velocity coefficients), the n sequential events collapse into ONE
  staleness-weighted gradient combination, computable as a single backward
  pass over a per-sample-weighted loss ⇒ one all-reduce per round, the same
  collective cost as hardsync.  For momentum the round applies the exact
  affine fold (repro.optim.sequential_fold): θ carries the folded
  velocity-decay term v0_coef and v advances by (m^n, Σ m^{n−1−i}) — exact
  whenever the n group-mean gradients coincide, a documented round-level
  approximation otherwise (see EXPERIMENTS.md §Perf for the convergence
  check).

Every applyUpdate routes through ``repro.optim`` (DESIGN.md §3) — this
module owns only the round structure and per-event LR schedule.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.config import ModelConfig, RunConfig
from repro.core.lr_policies import hardsync_lr, softsync_lr


# ---------------------------------------------------------------------------
# per-event learning rates for one round
# ---------------------------------------------------------------------------
def round_event_lrs(run: RunConfig, n: int) -> np.ndarray:
    """LR for each of the n update events in a round.

    staleness_inverse: uniform α₀/⟨σ⟩ with the engine's measured ⟨σ⟩=(n−1)/2.
    per_gradient (footnote 3): event j gets α₀/max(1, σ_j) with σ_j = j.
    """
    if run.lr_policy == "per_gradient":
        return np.array([run.base_lr / max(1.0, float(j)) for j in range(n)])
    if run.lr_policy == "staleness_inverse":
        sigma = max(1.0, (n - 1) / 2.0)
        return np.full((n,), run.base_lr / sigma)
    if run.lr_policy == "sqrt_scale":
        return np.full((n,), hardsync_lr(run))
    return np.full((n,), run.base_lr)


def fused_coefficients(run: RunConfig, n: int) -> Tuple[np.ndarray, float]:
    """Fold n sequential momentum updates into one combination.

    Sequential: v_j = m·v_{j-1} + g_j ;  θ ← θ − lr_j·v_j   (j = 0..n−1)
    ⇒ θ_n = θ_0 − Σ_i (Σ_{j≥i} lr_j m^{j−i}) g_i − (Σ_j lr_j m^{j+1}) v_0
    Returns (per-group gradient coefficients c_i for the θ update,
    velocity-carry coefficient Σ_j lr_j m^{j+1}) — the fold algebra lives in
    ``repro.optim.sequential_fold``.  For plain SGD (m = 0) the coefficients
    are exactly the per-event LRs.
    """
    fold = _round_fold(run, n)
    return np.asarray(fold.theta_coef), fold.v0_coef


def _round_fold(run: RunConfig, n: int) -> optim.RoundFold:
    lrs = round_event_lrs(run, n)
    m = run.momentum if run.optimizer == "momentum" else 0.0
    return optim.sequential_fold(lrs, m)


# ---------------------------------------------------------------------------
# optimizer state (all applyUpdate math lives in repro.optim)
# ---------------------------------------------------------------------------
def init_opt_state(run: RunConfig, params) -> dict:
    return optim.init_state(optim.spec_from_run(run), params)


# ---------------------------------------------------------------------------
# gradient computation with optional micro-batch accumulation
# ---------------------------------------------------------------------------
def grad_with_accum(loss_fn: Callable, params, batch, num_microbatches: int,
                    sample_weights=None):
    """value_and_grad with gradient accumulation over micro-batches.
    Returns (loss, metrics, grads).  Gradients accumulate in fp32."""
    def total_loss(p, b, w):
        if w is None:
            return loss_fn(p, b)
        return loss_fn(p, b, sample_weights=w)

    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            total_loss, has_aux=True)(params, batch, sample_weights)
        return loss, metrics, grads

    mb = jax.tree.map(
        lambda x: x.reshape((num_microbatches,
                             x.shape[0] // num_microbatches) + x.shape[1:]),
        batch)
    wb = (None if sample_weights is None else
          sample_weights.reshape(num_microbatches, -1))

    def acc_body(carry, inp):
        g_acc, l_acc = carry
        if sample_weights is None:
            b, w = inp, None
        else:
            b, w = inp
        (loss, metrics), g = jax.value_and_grad(
            total_loss, has_aux=True)(params, b, w)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), g_acc, g)
        return (g_acc, l_acc + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    xs = mb if sample_weights is None else (mb, wb)
    (g_sum, loss_sum), metrics = jax.lax.scan(
        acc_body, (zeros, jnp.float32(0.0)), xs)
    grads = jax.tree.map(lambda g: g / num_microbatches, g_sum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / num_microbatches, metrics, grads


# ---------------------------------------------------------------------------
# train steps
# ---------------------------------------------------------------------------
def make_hardsync_step(run: RunConfig, loss_fn: Callable):
    """Standard data-parallel step: Δθ = mean over the global batch ≡ Eq. 3.
    LR follows the paper's hardsync scaling when lr_policy = sqrt_scale."""
    lr = hardsync_lr(run) if run.lr_policy == "sqrt_scale" else run.base_lr
    spec = optim.spec_from_run(run)

    def step(params, opt, batch):
        loss, metrics, grads = grad_with_accum(
            loss_fn, params, batch, run.num_microbatches)
        params_new, opt_new = optim.apply_single(spec, params, opt, grads, lr)
        return params_new, opt_new, metrics

    return step


def make_softsync_step(run: RunConfig, loss_fn: Callable,
                       engine: str = "sequential"):
    """Round-based n-softsync (DESIGN.md §2).  One call = one round = n
    update events.  The global batch is split into n logical learner groups
    along the batch axis.
    """
    n = max(1, run.n_softsync)
    if run.protocol == "async":
        n = run.n_learners

    if engine == "fused":
        return _make_fused_softsync_step(run, loss_fn, n)

    lrs = jnp.asarray(round_event_lrs(run, n), jnp.float32)
    spec = optim.spec_from_run(run)

    def step(params, opt, batch):
        grouped = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
        theta0 = params      # round-start weights: all groups' grads use θ(i)

        def event(carry, inp):
            params, opt, loss_acc = carry
            group_batch, lr = inp
            loss, metrics, grads = grad_with_accum(
                loss_fn, theta0, group_batch, run.num_microbatches)
            params, opt = optim.apply_single(spec, params, opt, grads, lr)
            return (params, opt, loss_acc + loss), metrics

        (params, opt, loss_sum), metrics = jax.lax.scan(
            event, (params, opt, jnp.float32(0.0)), (grouped, lrs))
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        metrics["loss_round_mean"] = loss_sum / n
        return params, opt, metrics

    return step


def _make_fused_softsync_step(run: RunConfig, loss_fn: Callable, n: int):
    """Fused engine: one backward pass over a per-sample-weighted loss.

    The per-group θ-update coefficients c_i (fused_coefficients) become
    per-sample loss weights w_s = n·c_{g(s)} / Σc  scaled so that the single
    mean gradient equals Σ_i c_i · mean_{s∈i}(g_s) / (Σ_i c_i).  SGD /
    adagrad / adamw then do one apply with lr = Σ_i c_i; momentum applies
    the exact affine round fold — θ gets the v0_coef velocity carry and v
    advances by (m^n, Σ m^{n−1−i}) — so round-to-round momentum matches the
    sequential engine whenever the group-mean gradients coincide.
    """
    fold = _round_fold(run, n)
    coef = np.asarray(fold.theta_coef)
    total = float(coef.sum())
    group_w = jnp.asarray(coef / coef.mean(), jnp.float32)   # mean-1 weights
    spec = optim.spec_from_run(run)

    def step(params, opt, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        per_sample_w = jnp.repeat(group_w, B // n)           # (B,)
        loss, metrics, grads = grad_with_accum(
            loss_fn, params, batch, run.num_microbatches,
            sample_weights=per_sample_w)
        # grads is the weighted MEAN (1/n)Σ_i (c_i/c̄)·mean_i = Σ_i c_i·mean_i/Σc,
        # so applying with total weight Σ_i c_i reproduces θ₀ − Σ_i c_i·mean_i.
        if run.optimizer == "momentum":
            params, opt = optim.apply_round_folded(spec, params, opt, grads,
                                                   fold)
        else:
            params, opt = optim.apply_single(spec, params, opt, grads, total)
        return params, opt, metrics

    return step


def make_train_step(run: RunConfig, loss_fn: Callable,
                    engine: str = "sequential"):
    if run.protocol == "hardsync":
        return make_hardsync_step(run, loss_fn)
    return make_softsync_step(run, loss_fn, engine=engine)
