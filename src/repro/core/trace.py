"""Schedule pass of the compiled PS simulator (DESIGN.md §4).

The event-driven simulation splits into two phases.  This module is phase 1:
a host-side numpy **schedule** pass that runs the gradient-free event queue
(the same priority-queue arrival semantics as the legacy per-arrival loop in
``core/simulator.py``) and emits an :class:`ArrivalTrace` — for every update
event, which learner filled each of its c gradient slots, the PS timestamp
of the weights that learner had pulled, the learner's minibatch counter, the
simulated clock, and the LRs resolved from the run's policy.  Phase 2
(``core/engine.py``) replays the trace as one compiled ``lax.scan``.

The schedule draws from ``np.random.default_rng(run.seed)`` in exactly the
order the legacy loop does, so a trace scheduled with the same seed
reproduces the legacy arrival order bit-for-bit (the oracle-equivalence
contract, ``tests/test_trace_engine.py``).

Duration samplers are pluggable ``(rng, mu, learner) -> seconds`` callables;
:func:`make_duration_sampler` builds the one selected by
``RunConfig.duration_model``:

* ``homogeneous`` — fixed overhead + per-sample cost with the GEMM-
  efficiency penalty for small μ (§5.2) and lognormal jitter.
* ``two_speed``   — a two-tier heterogeneous cluster: the first
  ``slow_fraction·λ`` learners run ``slow_factor×`` slower.
* ``pareto``      — heavy straggler tail (Dutta et al., *Slow and Stale
  Gradients Can Win the Race*): duration × (1 + scale·Pareto(α)).
"""

from __future__ import annotations

import dataclasses
import heapq
import inspect
from typing import Callable, Optional

import numpy as np

from repro.config import DURATION_MODELS, RunConfig
from repro.core.clock import VectorClockLog, staleness_matrix
from repro.core.lr_policies import resolve_trace_lrs


# ---------------------------------------------------------------------------
# duration samplers
# ---------------------------------------------------------------------------
def base_duration(rng: np.random.Generator, mu: int) -> float:
    """Per-minibatch compute time: fixed overhead + per-sample cost, with the
    GEMM-efficiency penalty for small μ the paper describes (§5.2), plus
    lognormal jitter (homogeneous-cluster noise)."""
    gemm_eff = mu / (mu + 8.0)             # small μ ⇒ poor GEMM throughput
    base = 0.5 + mu * 0.01 / gemm_eff
    return base * rng.lognormal(mean=0.0, sigma=0.05)


def make_duration_sampler(run: RunConfig) -> Callable:
    """The ``(rng, mu, learner) -> seconds`` sampler selected by
    ``run.duration_model``."""
    if run.duration_model == "homogeneous":
        def sampler(rng, mu, learner):
            return base_duration(rng, mu)
        return sampler
    if run.duration_model == "two_speed":
        # slow_fraction small enough to round to zero learners is a valid
        # homogeneous control — don't force a slow learner into it
        n_slow = int(round(run.slow_fraction * run.n_learners))
        factor = float(run.slow_factor)

        def sampler(rng, mu, learner):
            d = base_duration(rng, mu)
            return d * factor if learner < n_slow else d
        return sampler
    if run.duration_model == "pareto":
        alpha, scale = float(run.pareto_alpha), float(run.pareto_scale)

        def sampler(rng, mu, learner):
            return base_duration(rng, mu) * (1.0 + scale * rng.pareto(alpha))
        return sampler
    raise ValueError(f"duration_model must be one of {DURATION_MODELS}, "
                     f"got {run.duration_model!r}")


def as_learner_sampler(sampler: Callable) -> Callable:
    """Adapt a legacy ``(rng, mu)`` sampler to the ``(rng, mu, learner)``
    signature (learner-independent)."""
    try:
        n_args = len(inspect.signature(sampler).parameters)
    except (TypeError, ValueError):
        n_args = 3
    if n_args >= 3:
        return sampler
    return lambda rng, mu, learner: sampler(rng, mu)


# ---------------------------------------------------------------------------
# the trace
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """Everything the replay engine needs, as dense host arrays.

    Row j describes update event j (PS timestamp j → j+1): slot i of the row
    is the i-th gradient folded into that update, in arrival order.
    """

    protocol: str
    n_learners: int
    learner: np.ndarray       # (steps, c) int32 — learner that pushed slot i
    pulled_ts: np.ndarray     # (steps, c) int32 — timestamp of pulled weights
    mb_index: np.ndarray      # (steps, c) int32 — learner's minibatch counter
    event_time: np.ndarray    # (steps,) float64 — simulated clock at fire
    lrs: np.ndarray           # (steps, c) — policy-resolved LRs
    mode: str                 # "combine" | "sequential" (repro.optim modes)

    @property
    def steps(self) -> int:
        return int(self.pulled_ts.shape[0])

    @property
    def c(self) -> int:
        """Gradients per update (Eq. 5's c; λ for hardsync)."""
        return int(self.pulled_ts.shape[1])

    @property
    def minibatches(self) -> int:
        """Arrivals consumed by the trace (the PS fires every c-th one)."""
        return self.steps * self.c

    @property
    def staleness(self) -> np.ndarray:
        """(steps, c) σ matrix: gradient in slot (j, i) has σ = j − ts
        (Eq.-2 accounting, one home: ``clock.staleness_matrix``)."""
        return staleness_matrix(self.pulled_ts)

    @property
    def max_staleness(self) -> int:
        """Ring-buffer bound: the replay engine keeps max σ + 1 snapshots
        (n-softsync bounds this at ~2n w.h.p., Fig. 4)."""
        return int(self.staleness.max()) if self.steps else 0

    @property
    def simulated_time(self) -> float:
        """The paper's runtime axis: simulated clock of the last update."""
        return float(self.event_time[-1]) if self.steps else 0.0

    def clock_log(self) -> VectorClockLog:
        """Fig.-4 statistics, trace-native (vectorized over the σ matrix)."""
        return VectorClockLog.from_matrix(self.pulled_ts)


# ---------------------------------------------------------------------------
# the schedule pass
# ---------------------------------------------------------------------------
def schedule(run: RunConfig, steps: int,
             duration_sampler: Optional[Callable] = None) -> ArrivalTrace:
    """Run the gradient-free event queue for ``steps`` updates.

    Identical arrival semantics (and rng draw order) to the legacy
    per-arrival loop; the only output is the trace.
    """
    lam = run.n_learners
    rng = np.random.default_rng(run.seed)
    sampler = as_learner_sampler(duration_sampler or
                                 make_duration_sampler(run))
    mu = run.minibatch

    if run.protocol == "hardsync":
        # barrier rounds: every learner contributes its step-th minibatch
        # computed on the round-start weights (timestamp = step).
        times = np.zeros((steps,))
        t = 0.0
        for step in range(steps):
            t += max(sampler(rng, mu, l) for l in range(lam))
            times[step] = t
        rows = np.arange(steps, dtype=np.int32)[:, None]
        learner = np.broadcast_to(np.arange(lam, dtype=np.int32),
                                  (steps, lam)).copy()
        pulled = np.broadcast_to(rows, (steps, lam)).copy()
        mb_idx = pulled.copy()
        lrs, mode = resolve_trace_lrs(run, pulled)
        return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                            times, lrs, mode)

    # ------------- softsync / async: the priority queue ---------------------
    c = run.gradients_per_update
    heap = []
    for i in range(lam):
        heapq.heappush(heap, (sampler(rng, mu, i), i, i))
    pulled_ts = [0] * lam
    mb_done = [0] * lam
    learner = np.zeros((steps, c), np.int32)
    pulled = np.zeros((steps, c), np.int32)
    mb_idx = np.zeros((steps, c), np.int32)
    times = np.zeros((steps,))
    timestamp = 0
    slot = 0
    mb = 0
    while timestamp < steps:
        t, _, li = heapq.heappop(heap)
        mb += 1
        learner[timestamp, slot] = li
        pulled[timestamp, slot] = pulled_ts[li]
        mb_idx[timestamp, slot] = mb_done[li]
        mb_done[li] += 1
        slot += 1
        if slot == c:                          # the PS fires
            times[timestamp] = t
            timestamp += 1
            slot = 0
        # pullWeights: pick up the current timestamp
        pulled_ts[li] = timestamp
        heapq.heappush(heap, (t + sampler(rng, mu, li), mb + lam, li))
    lrs, mode = resolve_trace_lrs(run, pulled)
    return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                        times, lrs, mode)
