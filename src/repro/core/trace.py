"""Schedule pass of the compiled PS simulator (DESIGN.md §4).

The event-driven simulation splits into two phases.  This module is phase 1:
a host-side numpy **schedule** pass that runs the gradient-free event queue
(the same priority-queue arrival semantics as the legacy per-arrival loop in
``core/simulator.py``) and emits an :class:`ArrivalTrace` — for every update
event, which learner filled each of its c gradient slots, the PS timestamp
of the weights that learner had pulled, the learner's minibatch counter, the
simulated clock, and the LRs resolved from the run's policy.  Phase 2
(``core/engine.py``) replays the trace as one compiled ``lax.scan``.

The schedule draws from ``np.random.default_rng(run.seed)`` in exactly the
order the legacy loop does, so a trace scheduled with the same seed
reproduces the legacy arrival order bit-for-bit (the oracle-equivalence
contract, ``tests/test_trace_engine.py``).  Elastic membership
(``run.membership`` joins/leaves/crash-restarts, ``run.backup`` hardsync
backup learners — DESIGN.md §7) also resolves here, into validity masks on
the trace; a static timeline keeps the rng draw order untouched
(``tests/test_elastic.py``).

Duration samplers are pluggable ``(rng, mu, learner) -> seconds`` callables;
:func:`make_duration_sampler` builds the one selected by
``RunConfig.duration_model``:

* ``homogeneous`` — fixed overhead + per-sample cost with the GEMM-
  efficiency penalty for small μ (§5.2) and lognormal jitter.
* ``two_speed``   — a two-tier heterogeneous cluster: the first
  ``slow_fraction·λ`` learners run ``slow_factor×`` slower.
* ``pareto``      — heavy straggler tail (Dutta et al., *Slow and Stale
  Gradients Can Win the Race*): duration × (1 + scale·Pareto(α)).
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
import inspect
import math
from typing import Callable, Optional

import numpy as np

from repro.config import (CALIBRATED_PREFIX, DURATION_MODELS, RunConfig,
                          parse_calibrated)
from repro.core.clock import VectorClockLog, staleness_matrix
from repro.core.lr_policies import resolve_trace_lrs
from repro.core.topology import Topology
from repro.membership import MembershipTimeline


# ---------------------------------------------------------------------------
# duration samplers
# ---------------------------------------------------------------------------
def base_duration(rng: np.random.Generator, mu: int) -> float:
    """Per-minibatch compute time: fixed overhead + per-sample cost, with the
    GEMM-efficiency penalty for small μ the paper describes (§5.2), plus
    lognormal jitter (homogeneous-cluster noise)."""
    gemm_eff = mu / (mu + 8.0)             # small μ ⇒ poor GEMM throughput
    base = 0.5 + mu * 0.01 / gemm_eff
    return base * rng.lognormal(mean=0.0, sigma=0.05)


def make_duration_sampler(run: RunConfig) -> Callable:
    """The ``(rng, mu, learner) -> seconds`` sampler selected by
    ``run.duration_model`` — one of the stochastic models below, or a
    ``calibrated:<arch>[:<int>mb]`` string resolving to the calibrated
    per-minibatch cost model of ``core/tradeoff.py`` (the same grammar
    ``ExperimentSpec.duration`` accepts; ``repro.config.parse_calibrated``
    is the shared parser)."""
    if run.duration_model.startswith(CALIBRATED_PREFIX):
        from repro.core import tradeoff as to     # lazy: keep layering flat
        arch, model_bytes = parse_calibrated(run.duration_model)
        wl = to.WorkloadModel()
        if model_bytes is not None:
            wl = dataclasses.replace(wl, model_bytes=model_bytes)
        return to.minibatch_duration_sampler(
            arch, run.n_learners, to.calibrate_to_baseline(), wl)
    if run.duration_model == "homogeneous":
        def sampler(rng, mu, learner):
            return base_duration(rng, mu)
        return sampler
    if run.duration_model == "two_speed":
        # slow_fraction small enough to round to zero learners is a valid
        # homogeneous control — don't force a slow learner into it
        n_slow = int(round(run.slow_fraction * run.n_learners))
        factor = float(run.slow_factor)

        def sampler(rng, mu, learner):
            d = base_duration(rng, mu)
            return d * factor if learner < n_slow else d
        return sampler
    if run.duration_model == "pareto":
        alpha, scale = float(run.pareto_alpha), float(run.pareto_scale)

        def sampler(rng, mu, learner):
            return base_duration(rng, mu) * (1.0 + scale * rng.pareto(alpha))
        return sampler
    raise ValueError(f"duration_model must be one of {DURATION_MODELS}, "
                     f"got {run.duration_model!r}")


def as_learner_sampler(sampler: Callable) -> Callable:
    """Adapt a legacy ``(rng, mu)`` sampler to the ``(rng, mu, learner)``
    signature (learner-independent)."""
    try:
        n_args = len(inspect.signature(sampler).parameters)
    except (TypeError, ValueError):
        n_args = 3
    if n_args >= 3:
        return sampler
    return lambda rng, mu, learner: sampler(rng, mu)


# ---------------------------------------------------------------------------
# the trace
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """Everything the replay engine needs, as dense host arrays.

    Row j describes update event j (PS timestamp j → j+1): slot i of the row
    is the i-th gradient folded into that update, in arrival order.

    With a non-trivial :class:`~repro.core.topology.Topology` the slot
    granularity is the *pusher* (a learner group): ``learner`` holds pusher
    ids, ``mb_index`` the pusher's push counter, and each slot stands for
    ``group_size`` member gradients aggregated locally (member learner ids
    come from ``member_learners``).  With S > 1 PS shards,
    ``shard_pulled_ts`` records the per-shard timestamps of the slices the
    pusher assembled its weights from (inconsistent reads; see topology.py).

    **Elastic membership** (DESIGN.md §7) resolves into two optional masks —
    the replay engine never branches per event, it only reweights:

    * ``valid`` (steps, c) bool: which slots of each row actually committed.
      Rows fired while λ(t) < λ (leaves/crashes shrank the n-softsync
      threshold, or backup-hardsync cancelled the slowest arrivals) have
      trailing unfilled slots: their ``learner``/``mb_index`` point at
      benign real data (learner 0, counter 0), ``pulled_ts`` is the row
      index (σ = 0), and the replay folds them with coefficient 0
      (:meth:`event_coef`).  None ⇔ every row is full (the static world).
    * ``member_valid`` (steps, c, gs) bool: per-slot member survival for
      grouped topologies — a group with crashed/left members aggregates
      over the survivors (:meth:`member_coef`).  None ⇔ ungrouped or no
      member ever missed a push.
    """

    protocol: str
    n_learners: int
    learner: np.ndarray       # (steps, c) int32 — pusher that filled slot i
    pulled_ts: np.ndarray     # (steps, c) int32 — timestamp of pulled weights
    mb_index: np.ndarray      # (steps, c) int32 — pusher's push counter
    event_time: np.ndarray    # (steps,) float64 — simulated clock at fire
    lrs: np.ndarray           # (steps, c) — policy-resolved LRs
    mode: str                 # "combine" | "sequential" (repro.optim modes)
    topology: Topology = Topology()
    # (steps, c, S) int32 per-shard pulled timestamps, None when S == 1.
    # Invariant: pulled_ts[j, i] <= shard_pulled_ts[j, i, s] <= j (a shard
    # slice is never staler than the logical pull, never from the future).
    shard_pulled_ts: Optional[np.ndarray] = None
    # elastic-membership masks (None = dense / full membership; see class
    # docstring)
    valid: Optional[np.ndarray] = None          # (steps, c) bool
    member_valid: Optional[np.ndarray] = None   # (steps, c, gs) bool
    # train-while-serve lane (DESIGN.md §14): the resolved ServingTrace when
    # run.serving attached a fleet — publication refreshes, request →
    # published-version assignments, staleness and latency, all resolved
    # host-side against this trace's event clock.  None = no serving lane;
    # the replay engine then compiles the exact pre-serving program.
    serving: Optional["ServingTrace"] = None

    @property
    def steps(self) -> int:
        return int(self.pulled_ts.shape[0])

    @property
    def c(self) -> int:
        """Gradients per update (Eq. 5's c; P for hardsync)."""
        return int(self.pulled_ts.shape[1])

    @property
    def group_size(self) -> int:
        """Learner gradients aggregated into one slot (1 = ungrouped)."""
        return self.topology.group_size(self.n_learners)

    @property
    def elastic(self) -> bool:
        """True when a membership timeline (or backup cancellation) masked
        any slot or group member of this trace."""
        return self.valid is not None or self.member_valid is not None

    @property
    def minibatches(self) -> int:
        """Minibatch gradients the trace actually commits: cancelled slots
        and crashed-out group members don't count (dense traces: steps·c·gs
        exactly as before)."""
        if self.member_valid is not None:
            slot_on = (self.valid if self.valid is not None
                       else np.ones(self.pulled_ts.shape, bool))
            return int((self.member_valid & slot_on[:, :, None]).sum())
        if self.valid is not None:
            return int(self.valid.sum()) * self.group_size
        return self.steps * self.c * self.group_size

    def event_coef(self) -> np.ndarray:
        """(steps, c) float32 combine coefficients: uniform over each row's
        committed slots, 0 on cancelled/unfilled ones (dense: 1/c)."""
        if self.valid is None:
            return np.full((self.steps, self.c), 1.0 / self.c, np.float32)
        count = np.maximum(1, self.valid.sum(axis=1, keepdims=True))
        return (self.valid / count).astype(np.float32)

    def member_coef(self) -> Optional[np.ndarray]:
        """(steps, c, gs) float32 member-averaging weights — uniform over a
        slot's surviving members — or None when every push was full (the
        replay then keeps its plain mean)."""
        if self.member_valid is None:
            return None
        count = np.maximum(1, self.member_valid.sum(axis=2, keepdims=True))
        return (self.member_valid / count).astype(np.float32)

    def member_learners(self) -> Optional[np.ndarray]:
        """(steps, c, gs) int32 member learner ids behind each slot, or
        None when ungrouped (the slot's ``learner`` IS the member)."""
        if self.group_size == 1:
            return None
        return self.topology.members(self.n_learners)[self.learner]

    @property
    def shard_staleness(self) -> np.ndarray:
        """(steps, c, S) per-shard σ matrix (σ_s ≤ σ: later-completing
        shard pulls see fresher slices).  S = 1 ⇒ the slot σ matrix with a
        trailing singleton axis."""
        if self.shard_pulled_ts is None:
            return self.staleness[:, :, None]
        steps = self.shard_pulled_ts.shape[0]
        return (np.arange(steps, dtype=np.int64)[:, None, None]
                - self.shard_pulled_ts.astype(np.int64))

    @property
    def staleness(self) -> np.ndarray:
        """(steps, c) σ matrix: gradient in slot (j, i) has σ = j − ts
        (Eq.-2 accounting, one home: ``clock.staleness_matrix``)."""
        return staleness_matrix(self.pulled_ts)

    @property
    def max_staleness(self) -> int:
        """Ring-buffer bound: the replay engine keeps max σ + 1 snapshots
        (n-softsync bounds this at ~2n w.h.p., Fig. 4)."""
        return int(self.staleness.max()) if self.steps else 0

    @property
    def simulated_time(self) -> float:
        """The paper's runtime axis: simulated clock of the last update."""
        return float(self.event_time[-1]) if self.steps else 0.0

    def clock_log(self) -> VectorClockLog:
        """Fig.-4 statistics, trace-native (vectorized over the σ matrix;
        cancelled slots are excluded from every statistic)."""
        return VectorClockLog.from_matrix(self.pulled_ts, valid=self.valid)

    def version_at(self, t) -> np.ndarray:
        """Weight version live at time t: the count of update events fired
        at or before t (version v ≥ 1 is born when event v − 1 fires; the
        same-instant tie rule — events apply before reads — is
        ``side="right"``).  Vectorizes over array t."""
        return np.searchsorted(self.event_time, t, side="right")


# ---------------------------------------------------------------------------
# the schedule pass
# ---------------------------------------------------------------------------
# rng stream tag for shard-pull skew draws: shard jitter must never perturb
# the main arrival stream (S = 1 and S > 1 schedule identical arrivals)
_SHARD_RNG_TAG = 0x7073


def _shard_pulled_ts(topo: Topology, run: RunConfig, pull_time: np.ndarray,
                     pulled: np.ndarray, times: np.ndarray) -> np.ndarray:
    """Resolve the (steps, c, S) per-shard pulled timestamps.

    A pull initiated at ``pull_time[j, i]`` completes at shard ``s`` a skew
    δ ~ Exp(pull_jitter) seconds later (independent rng stream — the main
    arrival schedule is untouched); every update fired by then is visible
    in that shard's slice.  Clipped to [pulled_ts, j]: reads are monotone
    w.r.t. the logical pull and never see the future relative to the update
    the gradient folds into.  pull_jitter = 0 ⇒ exactly the broadcast slot
    timestamps (consistent snapshot reads) — returned directly, without the
    clock comparison: with deterministic duration samplers an update can
    fire at the *same instant* as a pull, and counting updates with
    time ≤ pull would spuriously show it to the shard.
    """
    steps, c = pulled.shape
    if topo.pull_jitter <= 0:
        return np.broadcast_to(pulled[:, :, None],
                               (steps, c, topo.shards)).astype(np.int32)
    jrng = np.random.default_rng([run.seed, _SHARD_RNG_TAG])
    view = (pull_time[:, :, None].astype(np.float64)
            + topo.pull_jitter * jrng.exponential(
                size=(steps, c, topo.shards)))
    seen = np.searchsorted(times, view.reshape(-1),
                           side="right").reshape(view.shape)
    lo = pulled[:, :, None].astype(np.int64)
    hi = np.arange(steps, dtype=np.int64)[:, None, None]
    return np.clip(seen, lo, hi).astype(np.int32)


class _MembershipCursor:
    """Orders a timeline's events against the schedule clock: ``peek_t``
    is the next unprocessed event's time (inf when exhausted), ``pop``
    consumes it and folds it into the per-learner activity vector."""

    def __init__(self, timeline: MembershipTimeline, n_learners: int):
        self.events = timeline.events
        self.i = 0
        self.active = timeline.initial_active(n_learners)

    def peek_t(self) -> float:
        return (self.events[self.i].t if self.i < len(self.events)
                else math.inf)

    def pop(self):
        ev = self.events[self.i]
        self.i += 1
        self.active[ev.learner] = ev.kind == "join"
        return ev


def _finish_masks(slot_on: np.ndarray, mmask: np.ndarray, gs: int):
    """(valid, member_valid) in their canonical None-when-dense forms."""
    valid = None if slot_on.all() else slot_on
    member_valid = None
    if gs > 1 and (~mmask & slot_on[:, :, None]).any():
        member_valid = mmask
    return valid, member_valid


def schedule(run: RunConfig, steps: int,
             duration_sampler: Optional[Callable] = None) -> ArrivalTrace:
    """Run the gradient-free event queue for ``steps`` updates.

    Identical arrival semantics (and rng draw order) to the legacy
    per-arrival loop; the only output is the trace.  With learner groups
    the pushing entities are the P groups — a group push draws its gs
    member durations in member order and completes at their max (the local
    aggregation barrier) — which for group_size = 1 reduces draw-for-draw
    to the ungrouped loop.  PS shards never change the arrival schedule;
    they only add the per-shard pulled-timestamp resolution
    (:func:`_shard_pulled_ts`).

    **Elastic membership** (``run.membership``) resolves here, entirely at
    schedule time: membership events interleave with arrivals in time
    order (an event at the same instant as an arrival applies first), a
    crashed pusher's in-flight push is dropped, a restarted learner
    re-pulls with fresh timestamps, and the n-softsync firing threshold
    follows the live pusher count c(t) = max(1, ⌊P(t)/n⌋).  A static
    timeline draws from the rng in exactly the pre-elastic order and
    returns a mask-free trace (pinned bitwise by ``tests/test_elastic.py``).
    ``run.backup`` = b (hardsync) commits the first P − b arrivals per
    round and cancels the rest (Chen et al. backup learners).
    """
    lam = run.n_learners
    topo = Topology.from_run(run)
    members = topo.members(lam)            # (P, gs) learner ids
    pushers, gs = members.shape
    rng = np.random.default_rng(run.seed)
    sampler = as_learner_sampler(duration_sampler or
                                 make_duration_sampler(run))
    mu = run.minibatch
    cur = _MembershipCursor(run.membership, lam)

    def draw_duration(p: int, mask: np.ndarray) -> float:
        # group-local barrier over the members present at dispatch: gs
        # member draws in member order, max of their durations (full
        # membership + gs = 1 ⇒ one draw, the legacy per-learner schedule)
        return max(sampler(rng, mu, int(m))
                   for m, on in zip(members[p], mask) if on)

    if run.protocol == "hardsync":
        trace = _schedule_hardsync(run, steps, topo, members, cur,
                                   draw_duration)
    else:
        trace = _schedule_queue(run, steps, topo, members, cur,
                                draw_duration)
    if run.serving is not None:
        # serving lane (DESIGN.md §14): resolved AFTER the arrival schedule
        # from its own rng stream, so attaching a fleet never perturbs the
        # trace — arrivals with/without serving are bitwise identical
        from repro.serve.publication import schedule_serving
        trace = dataclasses.replace(
            trace, serving=schedule_serving(trace, run.serving, run.seed))
    return trace


# RunConfig fields the schedule pass NEVER reads — replay/runtime knobs
# only.  The lru key canonicalizes them to their defaults so e.g. a
# ring_impl × ring_dtype sweep over one protocol shape shares ONE cached
# trace instead of fragmenting the cache.  Every field NOT listed here is
# part of the cache key (the frozen-dataclass hash covers it), which is the
# audited guarantee that schedule-relevant fields — protocol, topology,
# membership, backup, durations, seed, the LR policy inputs — always key
# distinct traces.  ``tests/test_spmd.py::test_schedule_cached_field_audit``
# flips every RunConfig field and asserts its classification, so adding a
# field without triaging it here fails loudly.
_REPLAY_ONLY_FIELDS = (
    "momentum", "optimizer", "weight_decay",
    "ring_dtype", "ring_impl", "placement", "spmd_learners",
    "num_microbatches", "remat", "fsdp", "use_pallas",
    "attn_impl", "attn_q_chunk", "attn_kv_chunk", "unroll", "residual_spec",
)


def _schedule_key(run: RunConfig) -> RunConfig:
    """``run`` with replay-only fields reset to their defaults — the
    canonical cache key for :func:`schedule_cached`."""
    fields = {f.name: f for f in dataclasses.fields(RunConfig)}
    defaults = {name: fields[name].default for name in _REPLAY_ONLY_FIELDS}
    if all(getattr(run, k) == v for k, v in defaults.items()):
        return run
    return run.replace(**defaults)


@functools.lru_cache(maxsize=64)
def _schedule_cached(key: RunConfig, steps: int) -> ArrivalTrace:
    return schedule(key, steps)


def schedule_cached(run: RunConfig, steps: int) -> ArrivalTrace:
    """Memoized :func:`schedule` for the built-in duration models.

    ``schedule`` is a pure function of ``(run, steps)`` when no custom
    ``duration_sampler`` is supplied (the rng is seeded from ``run.seed``),
    yet the driver re-runs the full Python event queue every time the same
    grid point is replayed — in benchmark/sweep loops that schedule pass
    was a measurable slice of wall clock (~0.15 s per 96-step trace, paid
    per repeat).  The key is the full RunConfig with replay-only fields
    canonicalized away (``_REPLAY_ONLY_FIELDS``): membership/backup/
    topology/duration fields all hash into the key, while replay knobs
    (ring impl/dtype, placement, …) share a single entry.  Callers share
    ONE trace object per (canonical run, steps), so treat it as immutable —
    which every consumer already does; the arrays are replay *inputs*.
    Custom samplers (closures; unhashable, possibly stateful) must keep
    calling :func:`schedule` directly, as must benchmarks that time the
    schedule pass itself.
    """
    return _schedule_cached(_schedule_key(run), steps)


schedule_cached.cache_info = _schedule_cached.cache_info
schedule_cached.cache_clear = _schedule_cached.cache_clear


# ---------------------------------------------------------------------------
# SPMD placement (DESIGN.md §13)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Where a trace's replay runs on the emulated cluster: the schedule's
    topology mapped onto a ``(ps, learner)`` device mesh.  ``shards`` PS
    devices each own one (K, Dp) ring slice; ``learners`` devices each own
    a contiguous block of ``slot_block = c // learners`` gradient slots per
    update.  Host-side and jax-free — the engine turns it into a mesh +
    PartitionSpecs (launch/mesh.py, launch/sharding.py)."""

    shards: int
    learners: int
    c: int

    @property
    def devices(self) -> int:
        return self.shards * self.learners

    @property
    def slot_block(self) -> int:
        return self.c // self.learners

    def describe(self) -> str:
        return (f"spmd[{self.shards}ps×{self.learners}learner] "
                f"slot_block={self.slot_block}")


def placement_plan(trace: "ArrivalTrace", run: RunConfig,
                   device_count: int) -> PlacementPlan:
    """Resolve the trace's device placement: S from the schedule's topology,
    L from ``run.spmd_learners`` (0 = auto — the largest divisor of c such
    that S·L fits ``device_count``)."""
    topo = trace.topology or Topology()
    S, c = topo.shards, trace.c
    if S > device_count:
        raise RuntimeError(
            f"placement='spmd' with shards={S} needs {S} devices but only "
            f"{device_count} are visible; run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={S} or call "
            f"launch.mesh.ensure_host_devices({S}) before jax initializes")
    L = run.spmd_learners
    if L == 0:
        L = max(d for d in range(1, c + 1)
                if c % d == 0 and S * d <= device_count)
    if c % L != 0:
        raise ValueError(f"spmd_learners={L} must divide c={c}")
    if S * L > device_count:
        raise RuntimeError(
            f"placement plan {S}ps×{L}learner needs {S * L} devices but "
            f"only {device_count} are visible; lower spmd_learners or raise "
            f"the host device count (launch.mesh.ensure_host_devices)")
    return PlacementPlan(shards=S, learners=L, c=c)


def _schedule_hardsync(run: RunConfig, steps: int, topo: Topology,
                       members: np.ndarray, cur: _MembershipCursor,
                       draw_duration: Callable) -> ArrivalTrace:
    """Barrier rounds: every live pusher computes its round aggregate on
    the round-start weights (timestamp = step); the round commits the
    first ``P_active − backup`` arrivals (in pusher order on the trace
    row) and cancels the rest.  Membership at the barrier: the active set
    is read at round start; a crash mid-round drops that member's
    contribution (the whole push if nobody survives); joins/leaves take
    effect at the next barrier."""
    lam = run.n_learners
    pushers, gs = members.shape
    b = run.backup
    W = run.gradients_per_update           # row-width bound: P − b
    learner = np.zeros((steps, W), np.int32)
    slot_on = np.zeros((steps, W), bool)
    mmask = np.ones((steps, W, gs), bool)
    times = np.zeros((steps,))
    t = 0.0
    for step in range(steps):
        # active set at the barrier; an all-dead cluster stalls until the
        # next join (the barrier cannot proceed with zero learners)
        while True:
            while cur.peek_t() <= t:
                cur.pop()
            act = cur.active[members]      # (P, gs)
            if act.any():
                break
            if cur.peek_t() == math.inf:
                raise ValueError(
                    f"cluster died: no active learners and no future joins "
                    f"at t={t:.3f} after {step}/{steps} hardsync rounds — "
                    f"extend the membership timeline")
            t = cur.peek_t()
        arrivals = []                      # [completion, pusher, mask]
        for p in range(pushers):
            if act[p].any():
                mask = act[p].copy()
                arrivals.append([t + draw_duration(p, mask), p, mask])
        commit_n = max(1, len(arrivals) - b)
        committed = []
        for comp, p, mask in sorted(arrivals, key=lambda a: (a[0], a[1])):
            # crashes up to this completion kill mid-round contributions
            # of every not-yet-finished push (same-instant events first)
            while cur.peek_t() <= comp:
                ev = cur.pop()
                if ev.kind == "crash":
                    cp, pos = divmod(ev.learner, gs)
                    for a in arrivals:
                        if a[1] == cp and a[0] >= ev.t:
                            a[2][pos] = False
            if mask.any():
                committed.append((comp, p, mask))
                if len(committed) == commit_n:
                    break
        if not committed:
            raise ValueError(
                f"hardsync round {step}: every in-flight push crashed "
                f"before completing (t={t:.3f}) — nothing to commit")
        t = committed[-1][0]               # the round barrier
        times[step] = t
        committed.sort(key=lambda a: a[1])  # trace rows in pusher order
        for i, (_, p, mask) in enumerate(committed):
            learner[step, i] = p
            slot_on[step, i] = True
            mmask[step, i] = mask
    rows = np.arange(steps, dtype=np.int32)[:, None]
    pulled = np.broadcast_to(rows, (steps, W)).copy()
    mb_idx = pulled.copy()
    lrs, mode = resolve_trace_lrs(run, pulled)
    shard_ts = None
    if topo.shards > 1:
        # the barrier implies consistent pulls: every shard slice is
        # the round-start snapshot
        shard_ts = np.broadcast_to(
            pulled[:, :, None], pulled.shape + (topo.shards,)).copy()
    valid, member_valid = _finish_masks(slot_on, mmask, gs)
    return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                        times, lrs, mode, topo, shard_ts,
                        valid=valid, member_valid=member_valid)


def _schedule_queue(run: RunConfig, steps: int, topo: Topology,
                    members: np.ndarray, cur: _MembershipCursor,
                    draw_duration: Callable) -> ArrivalTrace:
    """softsync / async: the priority queue, with membership events
    interleaved in time order."""
    lam = run.n_learners
    pushers, gs = members.shape
    n = run.n_softsync
    W = run.gradients_per_update           # row-width bound (full cluster)
    heap = []                              # (completion, tiebreak, p, eid)
    recs = {}                              # eid -> member mask (mutable)
    in_flight = [None] * pushers           # live eid per pusher
    eid_next = 0

    learner = np.zeros((steps, W), np.int32)
    pulled = np.zeros((steps, W), np.int32)
    mb_idx = np.zeros((steps, W), np.int32)
    pull_time = np.zeros((steps, W))
    slot_on = np.zeros((steps, W), bool)
    mmask = np.ones((steps, W, gs), bool)
    times = np.zeros((steps,))
    pulled_ts = [0] * pushers
    pull_t = [0.0] * pushers               # when the pusher last pulled
    mb_done = [0] * pushers
    timestamp = 0
    slot = 0
    mb = 0

    def dispatch(p: int, t0: float, tiebreak) -> None:
        nonlocal eid_next
        mask = cur.active[members[p]].copy()
        eid = eid_next
        eid_next += 1
        recs[eid] = mask
        in_flight[p] = eid
        heapq.heappush(heap, (t0 + draw_duration(p, mask), tiebreak, p, eid))

    c_now = W

    def refresh_c() -> None:
        # n-softsync's splitting threshold follows the LIVE pusher count:
        # c(t) = max(1, ⌊P(t)/n⌋) (async: always 1)
        nonlocal c_now
        if run.protocol == "async":
            return
        p_act = int(topo.active_pushers(cur.active).sum())
        c_now = max(1, p_act // n)

    def apply_event(ev) -> None:
        p = ev.learner // gs
        if ev.kind == "join":
            if in_flight[p] is None:
                # the (re)joined learner pulls NOW: fresh timestamps, then
                # starts computing (an idle pusher comes back to life; a
                # pusher with survivors still computing picks the member
                # up at its next dispatch)
                pulled_ts[p] = timestamp
                pull_t[p] = ev.t
                dispatch(p, ev.t, mb + pushers)
        elif ev.kind == "crash":
            eid = in_flight[p]
            if eid is not None:
                mask = recs[eid]
                mask[ev.learner - p * gs] = False
                if not mask.any():         # the whole in-flight push is lost
                    in_flight[p] = None    # (its heap entry pops as a no-op)
        # graceful leave: the in-flight push still arrives; the learner
        # simply stops re-pulling (the redispatch check below)
        refresh_c()

    refresh_c()
    for p in range(pushers):
        if cur.active[members[p]].any():
            dispatch(p, 0.0, p)
    while timestamp < steps:
        # membership events interleave with arrivals in time order; an
        # event at the same instant as an arrival applies first (a join
        # may dispatch a push that lands before the current heap top)
        while cur.peek_t() <= (heap[0][0] if heap else math.inf):
            if cur.peek_t() == math.inf:
                break
            apply_event(cur.pop())
        if not heap:
            raise ValueError(
                f"cluster died: no active learners and no future joins "
                f"after {timestamp}/{steps} updates — extend the "
                f"membership timeline")
        t, _, p, eid = heapq.heappop(heap)
        mask = recs.pop(eid)
        if in_flight[p] == eid:
            in_flight[p] = None
        if not mask.any():
            continue                       # crashed-out push: dropped
        mb += 1
        learner[timestamp, slot] = p
        pulled[timestamp, slot] = pulled_ts[p]
        pull_time[timestamp, slot] = pull_t[p]
        mb_idx[timestamp, slot] = mb_done[p]
        slot_on[timestamp, slot] = True
        mmask[timestamp, slot] = mask
        mb_done[p] += 1
        slot += 1
        if slot >= c_now:                  # the PS fires
            times[timestamp] = t
            timestamp += 1
            slot = 0
        # pullWeights: pick up the current timestamp
        pulled_ts[p] = timestamp
        pull_t[p] = t
        if cur.active[members[p]].any():
            dispatch(p, t, mb + pushers)
        else:
            in_flight[p] = None            # left/crashed: stops pushing

    # unfilled slots carry benign placeholders: σ = 0 weights (the row's
    # own timestamp), learner 0's minibatch 0, and — through event_coef —
    # coefficient 0 in the replay, so their gradient never contributes
    rows = np.broadcast_to(np.arange(steps, dtype=np.int32)[:, None],
                           (steps, W))
    pulled = np.where(slot_on, pulled, rows)
    pull_time = np.where(slot_on, pull_time, times[:, None])
    lrs, mode = resolve_trace_lrs(run, pulled)
    shard_ts = None
    if topo.shards > 1:
        shard_ts = _shard_pulled_ts(topo, run, pull_time, pulled, times)
    valid, member_valid = _finish_masks(slot_on, mmask, gs)
    return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                        times, lrs, mode, topo, shard_ts,
                        valid=valid, member_valid=member_valid)
