"""Schedule pass of the compiled PS simulator (DESIGN.md §4).

The event-driven simulation splits into two phases.  This module is phase 1:
a host-side numpy **schedule** pass that runs the gradient-free event queue
(the same priority-queue arrival semantics as the legacy per-arrival loop in
``core/simulator.py``) and emits an :class:`ArrivalTrace` — for every update
event, which learner filled each of its c gradient slots, the PS timestamp
of the weights that learner had pulled, the learner's minibatch counter, the
simulated clock, and the LRs resolved from the run's policy.  Phase 2
(``core/engine.py``) replays the trace as one compiled ``lax.scan``.

The schedule draws from ``np.random.default_rng(run.seed)`` in exactly the
order the legacy loop does, so a trace scheduled with the same seed
reproduces the legacy arrival order bit-for-bit (the oracle-equivalence
contract, ``tests/test_trace_engine.py``).

Duration samplers are pluggable ``(rng, mu, learner) -> seconds`` callables;
:func:`make_duration_sampler` builds the one selected by
``RunConfig.duration_model``:

* ``homogeneous`` — fixed overhead + per-sample cost with the GEMM-
  efficiency penalty for small μ (§5.2) and lognormal jitter.
* ``two_speed``   — a two-tier heterogeneous cluster: the first
  ``slow_fraction·λ`` learners run ``slow_factor×`` slower.
* ``pareto``      — heavy straggler tail (Dutta et al., *Slow and Stale
  Gradients Can Win the Race*): duration × (1 + scale·Pareto(α)).
"""

from __future__ import annotations

import dataclasses
import heapq
import inspect
from typing import Callable, Optional

import numpy as np

from repro.config import DURATION_MODELS, RunConfig
from repro.core.clock import VectorClockLog, staleness_matrix
from repro.core.lr_policies import resolve_trace_lrs
from repro.core.topology import Topology


# ---------------------------------------------------------------------------
# duration samplers
# ---------------------------------------------------------------------------
def base_duration(rng: np.random.Generator, mu: int) -> float:
    """Per-minibatch compute time: fixed overhead + per-sample cost, with the
    GEMM-efficiency penalty for small μ the paper describes (§5.2), plus
    lognormal jitter (homogeneous-cluster noise)."""
    gemm_eff = mu / (mu + 8.0)             # small μ ⇒ poor GEMM throughput
    base = 0.5 + mu * 0.01 / gemm_eff
    return base * rng.lognormal(mean=0.0, sigma=0.05)


def make_duration_sampler(run: RunConfig) -> Callable:
    """The ``(rng, mu, learner) -> seconds`` sampler selected by
    ``run.duration_model``."""
    if run.duration_model == "homogeneous":
        def sampler(rng, mu, learner):
            return base_duration(rng, mu)
        return sampler
    if run.duration_model == "two_speed":
        # slow_fraction small enough to round to zero learners is a valid
        # homogeneous control — don't force a slow learner into it
        n_slow = int(round(run.slow_fraction * run.n_learners))
        factor = float(run.slow_factor)

        def sampler(rng, mu, learner):
            d = base_duration(rng, mu)
            return d * factor if learner < n_slow else d
        return sampler
    if run.duration_model == "pareto":
        alpha, scale = float(run.pareto_alpha), float(run.pareto_scale)

        def sampler(rng, mu, learner):
            return base_duration(rng, mu) * (1.0 + scale * rng.pareto(alpha))
        return sampler
    raise ValueError(f"duration_model must be one of {DURATION_MODELS}, "
                     f"got {run.duration_model!r}")


def as_learner_sampler(sampler: Callable) -> Callable:
    """Adapt a legacy ``(rng, mu)`` sampler to the ``(rng, mu, learner)``
    signature (learner-independent)."""
    try:
        n_args = len(inspect.signature(sampler).parameters)
    except (TypeError, ValueError):
        n_args = 3
    if n_args >= 3:
        return sampler
    return lambda rng, mu, learner: sampler(rng, mu)


# ---------------------------------------------------------------------------
# the trace
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """Everything the replay engine needs, as dense host arrays.

    Row j describes update event j (PS timestamp j → j+1): slot i of the row
    is the i-th gradient folded into that update, in arrival order.

    With a non-trivial :class:`~repro.core.topology.Topology` the slot
    granularity is the *pusher* (a learner group): ``learner`` holds pusher
    ids, ``mb_index`` the pusher's push counter, and each slot stands for
    ``group_size`` member gradients aggregated locally (member learner ids
    come from ``member_learners``).  With S > 1 PS shards,
    ``shard_pulled_ts`` records the per-shard timestamps of the slices the
    pusher assembled its weights from (inconsistent reads; see topology.py).
    """

    protocol: str
    n_learners: int
    learner: np.ndarray       # (steps, c) int32 — pusher that filled slot i
    pulled_ts: np.ndarray     # (steps, c) int32 — timestamp of pulled weights
    mb_index: np.ndarray      # (steps, c) int32 — pusher's push counter
    event_time: np.ndarray    # (steps,) float64 — simulated clock at fire
    lrs: np.ndarray           # (steps, c) — policy-resolved LRs
    mode: str                 # "combine" | "sequential" (repro.optim modes)
    topology: Topology = Topology()
    # (steps, c, S) int32 per-shard pulled timestamps, None when S == 1.
    # Invariant: pulled_ts[j, i] <= shard_pulled_ts[j, i, s] <= j (a shard
    # slice is never staler than the logical pull, never from the future).
    shard_pulled_ts: Optional[np.ndarray] = None

    @property
    def steps(self) -> int:
        return int(self.pulled_ts.shape[0])

    @property
    def c(self) -> int:
        """Gradients per update (Eq. 5's c; P for hardsync)."""
        return int(self.pulled_ts.shape[1])

    @property
    def group_size(self) -> int:
        """Learner gradients aggregated into one slot (1 = ungrouped)."""
        return self.topology.group_size(self.n_learners)

    @property
    def minibatches(self) -> int:
        """Minibatch gradients consumed by the trace (each of the steps·c
        slots aggregates group_size member gradients)."""
        return self.steps * self.c * self.group_size

    def member_learners(self) -> Optional[np.ndarray]:
        """(steps, c, gs) int32 member learner ids behind each slot, or
        None when ungrouped (the slot's ``learner`` IS the member)."""
        if self.group_size == 1:
            return None
        return self.topology.members(self.n_learners)[self.learner]

    @property
    def shard_staleness(self) -> np.ndarray:
        """(steps, c, S) per-shard σ matrix (σ_s ≤ σ: later-completing
        shard pulls see fresher slices).  S = 1 ⇒ the slot σ matrix with a
        trailing singleton axis."""
        if self.shard_pulled_ts is None:
            return self.staleness[:, :, None]
        steps = self.shard_pulled_ts.shape[0]
        return (np.arange(steps, dtype=np.int64)[:, None, None]
                - self.shard_pulled_ts.astype(np.int64))

    @property
    def staleness(self) -> np.ndarray:
        """(steps, c) σ matrix: gradient in slot (j, i) has σ = j − ts
        (Eq.-2 accounting, one home: ``clock.staleness_matrix``)."""
        return staleness_matrix(self.pulled_ts)

    @property
    def max_staleness(self) -> int:
        """Ring-buffer bound: the replay engine keeps max σ + 1 snapshots
        (n-softsync bounds this at ~2n w.h.p., Fig. 4)."""
        return int(self.staleness.max()) if self.steps else 0

    @property
    def simulated_time(self) -> float:
        """The paper's runtime axis: simulated clock of the last update."""
        return float(self.event_time[-1]) if self.steps else 0.0

    def clock_log(self) -> VectorClockLog:
        """Fig.-4 statistics, trace-native (vectorized over the σ matrix)."""
        return VectorClockLog.from_matrix(self.pulled_ts)


# ---------------------------------------------------------------------------
# the schedule pass
# ---------------------------------------------------------------------------
# rng stream tag for shard-pull skew draws: shard jitter must never perturb
# the main arrival stream (S = 1 and S > 1 schedule identical arrivals)
_SHARD_RNG_TAG = 0x7073


def _shard_pulled_ts(topo: Topology, run: RunConfig, pull_time: np.ndarray,
                     pulled: np.ndarray, times: np.ndarray) -> np.ndarray:
    """Resolve the (steps, c, S) per-shard pulled timestamps.

    A pull initiated at ``pull_time[j, i]`` completes at shard ``s`` a skew
    δ ~ Exp(pull_jitter) seconds later (independent rng stream — the main
    arrival schedule is untouched); every update fired by then is visible
    in that shard's slice.  Clipped to [pulled_ts, j]: reads are monotone
    w.r.t. the logical pull and never see the future relative to the update
    the gradient folds into.  pull_jitter = 0 ⇒ exactly the broadcast slot
    timestamps (consistent snapshot reads) — returned directly, without the
    clock comparison: with deterministic duration samplers an update can
    fire at the *same instant* as a pull, and counting updates with
    time ≤ pull would spuriously show it to the shard.
    """
    steps, c = pulled.shape
    if topo.pull_jitter <= 0:
        return np.broadcast_to(pulled[:, :, None],
                               (steps, c, topo.shards)).astype(np.int32)
    jrng = np.random.default_rng([run.seed, _SHARD_RNG_TAG])
    view = (pull_time[:, :, None].astype(np.float64)
            + topo.pull_jitter * jrng.exponential(
                size=(steps, c, topo.shards)))
    seen = np.searchsorted(times, view.reshape(-1),
                           side="right").reshape(view.shape)
    lo = pulled[:, :, None].astype(np.int64)
    hi = np.arange(steps, dtype=np.int64)[:, None, None]
    return np.clip(seen, lo, hi).astype(np.int32)


def schedule(run: RunConfig, steps: int,
             duration_sampler: Optional[Callable] = None) -> ArrivalTrace:
    """Run the gradient-free event queue for ``steps`` updates.

    Identical arrival semantics (and rng draw order) to the legacy
    per-arrival loop; the only output is the trace.  With learner groups
    the pushing entities are the P groups — a group push draws its gs
    member durations in member order and completes at their max (the local
    aggregation barrier) — which for group_size = 1 reduces draw-for-draw
    to the ungrouped loop.  PS shards never change the arrival schedule;
    they only add the per-shard pulled-timestamp resolution
    (:func:`_shard_pulled_ts`).
    """
    lam = run.n_learners
    topo = Topology.from_run(run)
    members = topo.members(lam)            # (P, gs) learner ids
    pushers, gs = members.shape
    rng = np.random.default_rng(run.seed)
    sampler = as_learner_sampler(duration_sampler or
                                 make_duration_sampler(run))
    mu = run.minibatch

    def push_duration(p: int) -> float:
        # group-local barrier: gs member gradients, max of their durations
        # (gs = 1 ⇒ one draw, the legacy per-learner schedule)
        return max(sampler(rng, mu, int(m)) for m in members[p])

    if run.protocol == "hardsync":
        # barrier rounds: every pusher contributes its step-th aggregate
        # computed on the round-start weights (timestamp = step).
        times = np.zeros((steps,))
        t = 0.0
        for step in range(steps):
            t += max(push_duration(p) for p in range(pushers))
            times[step] = t
        rows = np.arange(steps, dtype=np.int32)[:, None]
        learner = np.broadcast_to(np.arange(pushers, dtype=np.int32),
                                  (steps, pushers)).copy()
        pulled = np.broadcast_to(rows, (steps, pushers)).copy()
        mb_idx = pulled.copy()
        lrs, mode = resolve_trace_lrs(run, pulled)
        shard_ts = None
        if topo.shards > 1:
            # the barrier implies consistent pulls: every shard slice is
            # the round-start snapshot
            shard_ts = np.broadcast_to(
                pulled[:, :, None], pulled.shape + (topo.shards,)).copy()
        return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                            times, lrs, mode, topo, shard_ts)

    # ------------- softsync / async: the priority queue ---------------------
    c = run.gradients_per_update
    heap = []
    for i in range(pushers):
        heapq.heappush(heap, (push_duration(i), i, i))
    pulled_ts = [0] * pushers
    pull_t = [0.0] * pushers               # when the pusher last pulled
    mb_done = [0] * pushers
    learner = np.zeros((steps, c), np.int32)
    pulled = np.zeros((steps, c), np.int32)
    mb_idx = np.zeros((steps, c), np.int32)
    pull_time = np.zeros((steps, c))
    times = np.zeros((steps,))
    timestamp = 0
    slot = 0
    mb = 0
    while timestamp < steps:
        t, _, li = heapq.heappop(heap)
        mb += 1
        learner[timestamp, slot] = li
        pulled[timestamp, slot] = pulled_ts[li]
        pull_time[timestamp, slot] = pull_t[li]
        mb_idx[timestamp, slot] = mb_done[li]
        mb_done[li] += 1
        slot += 1
        if slot == c:                          # the PS fires
            times[timestamp] = t
            timestamp += 1
            slot = 0
        # pullWeights: pick up the current timestamp
        pulled_ts[li] = timestamp
        pull_t[li] = t
        heapq.heappush(heap, (t + push_duration(li), mb + pushers, li))
    lrs, mode = resolve_trace_lrs(run, pulled)
    shard_ts = None
    if topo.shards > 1:
        shard_ts = _shard_pulled_ts(topo, run, pull_time, pulled, times)
    return ArrivalTrace(run.protocol, lam, learner, pulled, mb_idx,
                        times, lrs, mode, topo, shard_ts)
