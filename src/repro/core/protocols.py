"""Synchronization-protocol update rules (paper §3.1, Eqs. 3–5).

These are pure pytree functions shared by the event-driven simulator and the
distributed (pjit/shard_map) runtime:

* hardsync  — Δθ = (1/λ) Σ_{l=1..λ} Δθ_l          (Eq. 3)
* n-softsync — Δθ = (1/c) Σ_{l=1..c} Δθ_l, c=⌊λ/n⌋ (Eq. 5)
* async     — Δθ = Δθ_l                            (Eq. 4; c = 1)

All three reduce to "average c gradients, scale by α, subtract" — so one
``apply_update`` with the protocol deciding c and the LR policy deciding α.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp


def tree_mean(grads: Sequence) -> object:
    """Average a list of gradient pytrees (the PS's sumGradients ÷ c)."""
    n = float(len(grads))
    return jax.tree.map(lambda *g: sum(g) / n, *grads)


def tree_weighted_sum(grads: Sequence, weights: Sequence[float]) -> object:
    """Σ w_g · grad_g — used by the fused staleness-weighted reduction."""
    return jax.tree.map(
        lambda *g: sum(w * x for w, x in zip(weights, g)), *grads)


def sgd_apply(params, grad, lr: float):
    """applyUpdate: θ ← θ − α·Δθ  (Eq. 1c)."""
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grad)


def momentum_apply(params, velocity, grad, lr: float, momentum: float):
    """Momentum-SGD applyUpdate (the paper's optimizer, §4.2)."""
    new_v = jax.tree.map(lambda v, g: momentum * v + g.astype(v.dtype),
                         velocity, grad)
    new_p = jax.tree.map(lambda p, v: p - lr * v.astype(p.dtype),
                         params, new_v)
    return new_p, new_v


def adagrad_apply(params, accum, grad, lr: float, eps: float = 1e-8):
    """AdaGrad applyUpdate (used by the paper for ImageNet 1-softsync)."""
    new_a = jax.tree.map(lambda a, g: a + jnp.square(g.astype(a.dtype)),
                         accum, grad)
    new_p = jax.tree.map(
        lambda p, g, a: p - lr * g.astype(p.dtype)
        / (jnp.sqrt(a.astype(p.dtype)) + eps),
        params, grad, new_a)
    return new_p, new_a


class ParameterServerState:
    """Host-side PS used by the event-driven simulator (Rudra-base logic).

    Holds the master weights + scalar timestamp, accumulates pushed gradients
    and fires an update every ``c`` arrivals, exactly like the paper's PS.
    """

    def __init__(self, params, c: int, optimizer: str = "sgd",
                 momentum: float = 0.9):
        self.params = params
        self.timestamp = 0
        self.c = c
        self.optimizer = optimizer
        self.momentum = momentum
        self._pending: List = []            # (grad, grad_timestamp)
        if optimizer == "momentum":
            self.velocity = jax.tree.map(jnp.zeros_like, params)
        elif optimizer == "adagrad":
            self.accum = jax.tree.map(jnp.zeros_like, params)

    def push_gradient(self, grad, grad_timestamp: int, lr_for_update):
        """Receive one gradient.  Returns the StalenessRecord-compatible
        vector clock if an update fired, else None.

        ``lr_for_update`` is a callable (gradient_timestamps -> α) so the LR
        policy can see the vector clock (per-gradient modulation)."""
        self._pending.append((grad, grad_timestamp))
        if len(self._pending) < self.c:
            return None
        grads = [g for g, _ in self._pending]
        clocks = [t for _, t in self._pending]
        self._pending = []
        lr = lr_for_update(self.timestamp, clocks)
        if callable(getattr(lr, "__iter__", None)) or isinstance(lr, (list,)):
            # per-gradient LRs: weighted sum instead of uniform mean
            delta = tree_weighted_sum(grads, [w / len(grads) for w in lr])
            self.params = sgd_apply(self.params, delta, 1.0)
        else:
            delta = tree_mean(grads)
            if self.optimizer == "momentum":
                self.params, self.velocity = momentum_apply(
                    self.params, self.velocity, delta, lr, self.momentum)
            elif self.optimizer == "adagrad":
                self.params, self.accum = adagrad_apply(
                    self.params, self.accum, delta, lr)
            else:
                self.params = sgd_apply(self.params, delta, lr)
        self.timestamp += 1
        return clocks
