"""Synchronization-protocol update rules (paper §3.1, Eqs. 3–5).

These are shared by the event-driven simulator and the distributed
(pjit/shard_map) runtime:

* hardsync  — Δθ = (1/λ) Σ_{l=1..λ} Δθ_l          (Eq. 3)
* n-softsync — Δθ = (1/c) Σ_{l=1..c} Δθ_l, c=⌊λ/n⌋ (Eq. 5)
* async     — Δθ = Δθ_l                            (Eq. 4; c = 1)

All three reduce to "combine c gradients, apply one optimizer step" — the
unified staleness-aware update in ``repro.optim`` (DESIGN.md §3).  This
module keeps the protocol bookkeeping (arrival batching, timestamps, the
scalar-vs-per-gradient LR contract) and routes every applyUpdate through
that subsystem; by default the PS fires the fused Pallas ``ps_update``
kernel (interpret mode off-TPU), so the simulator's measured hot path IS
the optimized one.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def tree_mean(grads: Sequence) -> object:
    """Average a list of gradient pytrees (the PS's sumGradients ÷ c)."""
    n = float(len(grads))
    return jax.tree.map(lambda *g: sum(g) / n, *grads)


def init_ps_state(run, params):
    """PS-side optimizer init shared by the host PS and the replay engine:
    the run's UpdateSpec plus fresh fp32 optimizer state for ``params``."""
    spec = optim.spec_from_run(run)
    return spec, optim.init_state(spec, params)


class ParameterServerState:
    """Host-side PS used by the event-driven simulator (Rudra-base logic).

    Holds the master weights + scalar timestamp, accumulates pushed gradients
    and fires an update every ``c`` arrivals, exactly like the paper's PS.
    The update itself is one call into ``repro.optim.apply_update``:

    * scalar LR from the policy  → ``combine`` mode (Eq. 3/5: average the c
      gradients, one optimizer event);
    * per-gradient LR list (footnote 3) → ``sequential`` mode: c optimizer
      events, event i applying G_i/c with its own α_i, so momentum/adagrad
      state advances per gradient instead of being silently bypassed.

    ``backend`` picks the optim backend; the default "pallas" runs the fused
    kernel over the whole concatenated model per update.
    """

    def __init__(self, params, c: int, optimizer: str = "sgd",
                 momentum: float = 0.9, weight_decay: float = 0.0,
                 backend: str = "pallas",
                 spec: "optim.UpdateSpec" = None):
        self.params = params
        self.timestamp = 0
        self.c = c
        self.backend = backend
        self.spec = spec if spec is not None else optim.UpdateSpec(
            optimizer=optimizer, momentum=momentum,
            weight_decay=weight_decay)
        self.optimizer = self.spec.optimizer
        self.momentum = self.spec.momentum
        self.opt_state = optim.init_state(self.spec, params)
        self._pending: List = []            # (grad, grad_timestamp)

    @classmethod
    def from_run(cls, params, run, backend: str = "pallas"
                 ) -> "ParameterServerState":
        """Build the host PS for a RunConfig — the spec comes from the same
        ``spec_from_run`` mapping the compiled replay engine uses
        (:func:`init_ps_state`), so the two stay field-for-field aligned.

        The host PS models the *flat, static* Rudra-base server only;
        sharded/grouped topologies (DESIGN.md §6) and elastic membership /
        backup learners (DESIGN.md §7) have no per-arrival oracle and
        replay exclusively on ``core.engine``."""
        from repro.core.topology import Topology   # lazy: keeps layering flat
        topo = Topology.from_run(run)
        if not topo.is_trivial(run.n_learners):
            raise ValueError(
                f"the host PS (legacy per-arrival loop) models the flat "
                f"Rudra-base server; topology {topo} replays on "
                f"core.engine only")
        if run.elastic or run.backup:
            raise ValueError(
                f"the host PS (legacy per-arrival loop) models a static "
                f"cluster; elastic membership ({run.membership}) / "
                f"backup={run.backup} resolve at schedule time and replay "
                f"on core.engine only")
        return cls(params, run.gradients_per_update, backend=backend,
                   spec=optim.spec_from_run(run))

    @property
    def velocity(self):
        return self.opt_state.get("velocity")

    @property
    def accum(self):
        return self.opt_state.get("accum")

    def push_gradient(self, grad, grad_timestamp: int, lr_for_update):
        """Receive one gradient.  Returns the StalenessRecord-compatible
        vector clock if an update fired, else None.

        ``lr_for_update`` is a callable (gradient_timestamps -> α) so the LR
        policy can see the vector clock (per-gradient modulation)."""
        self._pending.append((grad, grad_timestamp))
        if len(self._pending) < self.c:
            return None
        grads = [g for g, _ in self._pending]
        clocks = [t for _, t in self._pending]
        self._pending = []
        c = len(grads)
        lr = lr_for_update(self.timestamp, clocks)
        if np.ndim(lr) > 0:
            # footnote 3: per-gradient α_i ⇒ c sequential optimizer events
            # (any length-c sequence/array counts, incl. jax arrays)
            mode = "sequential"
            lrs = jnp.asarray(lr, jnp.float32)
        else:
            mode = "combine"
            lrs = jnp.full((c,), float(lr), jnp.float32)
        coef = jnp.full((c,), 1.0 / c, jnp.float32)
        self.params, self.opt_state = optim.apply_update(
            self.spec, self.params, self.opt_state, grads, coef, lrs,
            mode=mode, backend=self.backend)
        self.timestamp += 1
        return clocks
