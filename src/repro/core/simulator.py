"""Event-driven parameter-server simulator — the per-arrival oracle.

The paper's asynchronous protocols are races between MPI processes; their
*measurable* behaviour (staleness distributions, convergence, runtime) is a
function of arrival order at the PS.  This module reproduces arrival order
with a deterministic discrete-event simulation: λ learners with stochastic
compute durations push gradients into a priority queue; the PS fires an
update every ``c = ⌊λ/n⌋`` arrivals (n-softsync), on every arrival (async),
or at a barrier (hardsync).  Timestamps/vector clocks follow §3.1 exactly.

Two modes:

* **measure** — gradients are tokens; only clocks are tracked.  Reproduces
  Fig. 4 (⟨σ⟩ ≈ n, σ ≤ 2n w.h.p.) for any (λ, n) in milliseconds.  This is
  exactly the schedule pass of the compiled engine (``core/trace.py``).
* **sgd** — each learner holds the weight copy it pulled and computes a real
  JAX gradient on its own mini-batch against *those* weights; the PS applies
  Eqs. 3–5 with the configured LR policy.  Reproduces Fig. 5 / Tables 2–3
  dynamics on synthetic tasks.

The sgd mode here is the **legacy per-arrival loop**: one ``grad_fn`` call
and one optimizer dispatch per gradient, on the host.  It is kept as the
oracle the compiled trace/replay engine (``core/engine.py``, DESIGN.md §4)
is equivalence-tested against; production experiments run through
``repro.experiments`` (``run(ExperimentSpec(...))``).  The oracle models
the flat, static Rudra-base server only: sharded/grouped topologies and
elastic membership (crash/restart, backup learners) replay exclusively on
the compiled engine and are rejected here.

The simulated clock also yields the paper's runtime axis: total train time =
simulated time of the last update, with per-minibatch durations from the
pluggable samplers in ``core/trace.py`` (``RunConfig.duration_model``) or
the calibrated cost model in ``core/tradeoff.py``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.config import RunConfig
from repro.core.clock import VectorClockLog
from repro.core.lr_policies import make_lr_policy
from repro.core.protocols import ParameterServerState
from repro.core import trace as trace_mod


@dataclasses.dataclass
class LearnerState:
    index: int
    pulled_timestamp: int = 0
    params: Optional[object] = None      # the weight copy it pulled (sgd mode)
    minibatches_done: int = 0


@dataclasses.dataclass
class SimResult:
    clock_log: VectorClockLog
    updates: int
    simulated_time: float
    minibatches: int
    params: Optional[object] = None
    history: Optional[List[Dict]] = None   # eval trace (sgd mode)
    # train-while-serve lane (repro.serve, DESIGN.md §14): the ServingResult
    # of a replay whose trace carried a serving fleet; None otherwise (the
    # legacy oracle never serves — simulate() rejects serving configs)
    serving: Optional[object] = None


def _default_duration_sampler(rng: np.random.Generator, mu: int):
    """Legacy (rng, mu) alias of the homogeneous sampler in ``core/trace``."""
    return trace_mod.base_duration(rng, mu)


def simulate(run: RunConfig,
             *,
             steps: int,
             grad_fn: Optional[Callable] = None,
             init_params: Optional[object] = None,
             batch_fn: Optional[Callable] = None,
             eval_fn: Optional[Callable] = None,
             eval_every: int = 0,
             duration_sampler: Optional[Callable] = None,
             ps_backend: str = "pallas",
             ) -> SimResult:
    """Run the PS simulation for ``steps`` weight updates.

    measure mode: leave ``grad_fn`` None.
    sgd mode: provide ``grad_fn(params, batch) -> grads``,
    ``init_params``, and ``batch_fn(learner_idx, minibatch_idx) -> batch``.
    ``duration_sampler`` defaults to the model selected by
    ``run.duration_model``; 2-arg ``(rng, mu)`` callables are accepted.
    ``ps_backend`` picks the ``repro.optim`` backend of the host PS.
    """
    if run.serving is not None and grad_fn is not None:
        raise ValueError(
            "the legacy per-arrival oracle has no serving lane; replay a "
            "serving trace on the compiled engine (engine='compiled' / "
            "core.engine.replay)")
    if grad_fn is None:                       # measure mode == the schedule
        tr = trace_mod.schedule(run, steps, duration_sampler=duration_sampler)
        return SimResult(tr.clock_log(), tr.steps, tr.simulated_time,
                         tr.minibatches)

    lam = run.n_learners
    rng = np.random.default_rng(run.seed)
    sampler = trace_mod.as_learner_sampler(
        duration_sampler or trace_mod.make_duration_sampler(run))
    lr_policy = make_lr_policy(run)
    log = VectorClockLog()
    # everything below is sgd mode: real gradients through the unified PS
    ps = ParameterServerState.from_run(init_params, run, backend=ps_backend)

    # ---------------- hardsync: barrier rounds -----------------------------
    if run.protocol == "hardsync":
        # A barrier round is just "the PS fires after all λ arrivals" — the
        # same unified applyUpdate (repro.optim) as softsync, with c = λ.
        t = 0.0
        history = []
        mb = 0
        for step in range(steps):
            durations = [sampler(rng, run.minibatch, l) for l in range(lam)]
            t += max(durations)                       # barrier
            params0 = ps.params
            for l in range(lam):
                ps.push_gradient(grad_fn(params0, batch_fn(l, step)),
                                 step, lr_policy)
            mb += lam
            log.record(step + 1, [step] * lam)        # σ = 0 by construction
            if eval_fn and eval_every and (step + 1) % eval_every == 0:
                history.append({"update": step + 1, "time": t,
                                **eval_fn(ps.params)})
        return SimResult(log, steps, t, mb, ps.params, history)

    # ---------------- softsync / async: event queue -------------------------
    learners = [LearnerState(i) for i in range(lam)]
    for l in learners:
        l.params = ps.params
    # event heap: (push_completion_time, tiebreak, learner_idx)
    heap = []
    for l in learners:
        heapq.heappush(heap, (sampler(rng, run.minibatch, l.index),
                              l.index, l.index))
    updates = 0
    mb = 0
    t = 0.0
    history = []

    while updates < steps:
        t, _, li = heapq.heappop(heap)
        learner = learners[li]
        mb += 1
        grad_ts = learner.pulled_timestamp
        batch = batch_fn(li, learner.minibatches_done)
        grad = grad_fn(learner.params, batch)
        clocks = ps.push_gradient(grad, grad_ts, lr_policy)
        learner.minibatches_done += 1
        if clocks is not None:
            updates += 1
            log.record(ps.timestamp, clocks)
            if eval_fn and eval_every and updates % eval_every == 0:
                history.append({"update": updates, "time": t,
                                **eval_fn(ps.params)})
        # pullWeights: learner picks up current weights + timestamp.
        # (Rudra-base learners first compare timestamps and skip the pull if
        # unchanged — observationally identical here since we share the ref.)
        learner.params = ps.params
        learner.pulled_timestamp = ps.timestamp
        heapq.heappush(
            heap, (t + sampler(rng, run.minibatch, li), mb + lam, li))

    return SimResult(log, updates, t, mb, ps.params, history)
