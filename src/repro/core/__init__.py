"""The paper's primary contribution: staleness-bounded parameter-server
training (clocks, protocols, LR modulation, event simulator, and the
TPU-native distributed engines)."""
from repro.core.clock import StalenessRecord, VectorClockLog
from repro.core.protocols import (ParameterServerState, init_ps_state,
                                  tree_mean)
from repro.core.lr_policies import (make_lr_policy, hardsync_lr, softsync_lr,
                                    resolve_trace_lrs)
from repro.core.topology import RUDRA_ARCHS, Topology
from repro.core.trace import (ArrivalTrace, make_duration_sampler, schedule)
from repro.core.simulator import simulate, SimResult
from repro.core.engine import replay, replay_batch
from repro.membership import MembershipEvent, MembershipTimeline
from repro.core.distributed import (make_train_step, make_hardsync_step,
                                    make_softsync_step, init_opt_state,
                                    round_event_lrs, fused_coefficients)

__all__ = [
    "StalenessRecord", "VectorClockLog", "ParameterServerState",
    "init_ps_state", "tree_mean",
    "make_lr_policy", "hardsync_lr", "softsync_lr", "resolve_trace_lrs",
    "RUDRA_ARCHS", "Topology",
    "ArrivalTrace", "make_duration_sampler", "schedule",
    "MembershipEvent", "MembershipTimeline",
    "simulate", "SimResult",
    "replay", "replay_batch",
    "make_train_step", "make_hardsync_step", "make_softsync_step",
    "init_opt_state", "round_event_lrs", "fused_coefficients",
]
