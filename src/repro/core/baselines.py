"""Competing staleness-control baselines from the paper's related work (§6).

The paper positions n-softsync against two orthogonal solutions and one
design rejected in §3.3; implementing them makes the comparison concrete:

* **SSP** — Stale Synchronous Parallel (Ho et al. 2013 / Cui et al. 2014):
  asynchronous PS, but a learner whose clock is more than ``slack`` ahead of
  the slowest learner BLOCKS until the laggard catches up.  Hard staleness
  bound by construction, at the cost of stalls.

* **EASGD** — Elastic Averaging SGD (Zhang et al. 2014): learners keep local
  weights x_l and interact with a center x̃ through an elastic penalty:
      x_l ← x_l − η∇f(x_l) − α(x_l − x̃)
      x̃  ← x̃ + α Σ_l (x_l − x̃)
  Staleness is not bounded; divergence between replicas is *damped* instead.

* **Accrual (Downpour npush)** — learners sum ``npush`` local gradients
  before pushing (DistBelief's npush knob).  The paper rejects this for
  Rudra-adv* arguing it "effectively increases μ"; ``benchmarks/accrual``
  tests that equivalence claim empirically.

All three reuse the event-queue machinery of ``core/simulator.py`` so the
comparison against n-softsync is apples-to-apples (same durations, same
data order, same clocks).
"""

from __future__ import annotations

import heapq
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.clock import VectorClockLog
from repro.core.lr_policies import make_lr_policy
from repro.optim import sgd_step
from repro.core.simulator import SimResult, _default_duration_sampler


# ---------------------------------------------------------------------------
# SSP
# ---------------------------------------------------------------------------
def simulate_ssp(run: RunConfig, *, steps: int, slack: int,
                 grad_fn: Optional[Callable] = None,
                 init_params=None, batch_fn: Optional[Callable] = None,
                 duration_sampler: Callable = _default_duration_sampler
                 ) -> SimResult:
    """SSP: async PS (c = 1) where a learner with local clock > min_clock +
    slack blocks until the slowest learner advances.  Blocking is modelled
    by re-queueing the fast learner at the laggard's next completion time."""
    lam = run.n_learners
    rng = np.random.default_rng(run.seed)
    lr_policy = make_lr_policy(run)
    log = VectorClockLog()
    sgd = grad_fn is not None

    params = init_params
    pulled_ts = [0] * lam
    pulled_params: List = [params] * lam
    local_clock = [0] * lam
    done_mb = [0] * lam
    next_time = [0.0] * lam
    heap = []
    for i in range(lam):
        next_time[i] = duration_sampler(rng, run.minibatch)
        heapq.heappush(heap, (next_time[i], i, i))
    timestamp = 0
    updates = mb = 0
    t = 0.0
    stalls = 0
    while updates < steps:
        t, tb, li = heapq.heappop(heap)
        if local_clock[li] > min(local_clock) + slack:
            # blocked: sleep until the LAGGARD finishes its in-flight
            # mini-batch (re-queueing any earlier would livelock)
            stalls += 1
            lag = min(range(lam), key=lambda j: local_clock[j])
            wake = max(next_time[lag], t) + 1e-9
            next_time[li] = wake
            heapq.heappush(heap, (wake, tb + lam * 1000, li))
            continue
        mb += 1
        if sgd:
            grad = grad_fn(pulled_params[li], batch_fn(li, done_mb[li]))
            lr = lr_policy(timestamp, [pulled_ts[li]])
            if isinstance(lr, list):
                lr = lr[0]
            params = sgd_step(params, grad, lr)
        timestamp += 1
        updates += 1
        log.record(timestamp, [pulled_ts[li]])
        done_mb[li] += 1
        local_clock[li] += 1
        pulled_ts[li] = timestamp
        pulled_params[li] = params
        next_time[li] = t + duration_sampler(rng, run.minibatch)
        heapq.heappush(heap, (next_time[li], mb + lam, li))
    res = SimResult(log, updates, t, mb, params if sgd else None)
    res.stalls = stalls      # type: ignore[attr-defined]
    return res


# ---------------------------------------------------------------------------
# EASGD
# ---------------------------------------------------------------------------
def simulate_easgd(run: RunConfig, *, steps: int, rho: float = 0.1,
                   comm_every: int = 1,
                   grad_fn: Callable = None, init_params=None,
                   batch_fn: Callable = None,
                   duration_sampler: Callable = _default_duration_sampler
                   ) -> SimResult:
    """Asynchronous EASGD: each learner does local SGD on its own replica and
    every ``comm_every`` mini-batches performs the elastic exchange with the
    center.  ``rho`` is the elastic coefficient (α = η·ρ in the paper's
    notation, folded)."""
    lam = run.n_learners
    rng = np.random.default_rng(run.seed)
    log = VectorClockLog()
    eta = run.base_lr

    center = init_params
    local = [init_params] * lam
    done_mb = [0] * lam
    since_comm = [0] * lam
    heap = []
    for i in range(lam):
        heapq.heappush(heap, (duration_sampler(rng, run.minibatch), i, i))
    updates = mb = 0
    t = 0.0
    center_ts = 0
    pulled_ts = [0] * lam
    while updates < steps:
        t, _, li = heapq.heappop(heap)
        mb += 1
        grad = grad_fn(local[li], batch_fn(li, done_mb[li]))
        local[li] = sgd_step(local[li], grad, eta)
        done_mb[li] += 1
        since_comm[li] += 1
        if since_comm[li] >= comm_every:
            since_comm[li] = 0
            diff = jax.tree.map(lambda x, c: x - c, local[li], center)
            local[li] = jax.tree.map(lambda x, d: x - rho * d,
                                     local[li], diff)
            center = jax.tree.map(lambda c, d: c + rho * d, center, diff)
            center_ts += 1
            updates += 1
            log.record(center_ts, [pulled_ts[li]])
            pulled_ts[li] = center_ts
        heapq.heappush(heap, (t + duration_sampler(rng, run.minibatch),
                              mb + lam, li))
    return SimResult(log, updates, t, mb, center)


# ---------------------------------------------------------------------------
# Downpour-style gradient accrual (npush)
# ---------------------------------------------------------------------------
def simulate_accrual(run: RunConfig, *, steps: int, npush: int,
                     grad_fn: Callable = None, init_params=None,
                     batch_fn: Callable = None,
                     duration_sampler: Callable = _default_duration_sampler
                     ) -> SimResult:
    """Each learner locally SUMS npush gradients (all computed at its pulled
    weights) before pushing — DistBelief's npush.  The paper's §3.3 claim:
    this is effectively an μ·npush mini-batch.  Protocol at the PS is
    1-softsync over the accrued pushes."""
    from repro.core.protocols import ParameterServerState
    lam = run.n_learners
    rng = np.random.default_rng(run.seed)
    lr_policy = make_lr_policy(run)
    log = VectorClockLog()
    ps = ParameterServerState(init_params, c=lam, optimizer="sgd")
    pulled = [(init_params, 0)] * lam
    acc: List = [None] * lam
    acc_count = [0] * lam
    done_mb = [0] * lam
    heap = []
    for i in range(lam):
        heapq.heappush(heap, (duration_sampler(rng, run.minibatch), i, i))
    updates = mb = 0
    t = 0.0
    while updates < steps:
        t, _, li = heapq.heappop(heap)
        mb += 1
        p, ts = pulled[li]
        g = grad_fn(p, batch_fn(li, done_mb[li]))
        done_mb[li] += 1
        acc[li] = g if acc[li] is None else jax.tree.map(
            jnp.add, acc[li], g)
        acc_count[li] += 1
        if acc_count[li] >= npush:
            mean_g = jax.tree.map(lambda x: x / npush, acc[li])
            clocks = ps.push_gradient(mean_g, ts, lr_policy)
            acc[li], acc_count[li] = None, 0
            if clocks is not None:
                updates += 1
                log.record(ps.timestamp, clocks)
            pulled[li] = (ps.params, ps.timestamp)
        heapq.heappush(heap, (t + duration_sampler(rng, run.minibatch),
                              mb + lam, li))
    return SimResult(log, updates, t, mb, ps.params)
