"""PS topology: sharded parameter servers + hierarchical learner groups
(DESIGN.md §6).

The paper's runtime results come from Rudra's *scaled* architectures
(§3.2/3.3), which differ from the flat Rudra-base server in two structural
ways that this module describes declaratively:

* **Parameter-server sharding** (Rudra-adv): the flat weight buffer is
  partitioned into ``S`` contiguous equal-width shards, each an independent
  server with its own clock.  Learners pull the S slices as S separate
  messages, so the assembled weight vector a learner computes its gradient
  from may mix slices of *different* timestamps — the paper's "weights that
  may never have existed as one consistent version" (§3.1).  The schedule
  pass models this with a per-(pull, shard) completion skew
  (``RunConfig.shard_pull_jitter``, simulated seconds): updates landing
  between the logical pull and a shard's completion are visible in that
  shard's slice, giving shard-local staleness σ_s ≤ σ.

* **Hierarchical learner groups** (Rudra-adv*): the λ learners are
  partitioned into ``G`` contiguous groups of ``λ/G`` members.  A group
  aggregates member gradients locally (the learner broadcast tree) and
  pushes ONE averaged gradient; the PS sees G pushers instead of λ, and a
  group push takes the max of its members' compute durations (the local
  mini-barrier).

``Topology(shards=1, groups=0)`` is Rudra-base and degenerates *exactly* to
the pre-topology path: the trace layout, rng draw order, and replay scan
body are unchanged (pinned by ``tests/test_topology.py``).

Shard packing is equal-width: shard ``s`` owns ``flat[s·Dp : (s+1)·Dp]``
with ``Dp = ⌈D/S⌉`` and the last shard zero-padded — padding stays
identically zero through sgd/momentum/adagrad events, so packing is purely
a layout choice (the partition-invariance property in
``tests/test_topology.py`` holds for *any* boundary).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

# Rudra architecture presets (the paper's names).  `for_arch` resolves one
# against a learner count; benchmarks/topology_scaling.py sweeps them.
RUDRA_ARCHS = ("base", "adv", "adv*")


@dataclasses.dataclass(frozen=True)
class Topology:
    """Declarative PS topology.  Hashable → usable as a jit static.

    ``shards``  — S parameter-server shards over the flat weight buffer
                  (1 = the flat Rudra-base server).
    ``groups``  — G learner groups with group-level gradient aggregation
                  (0 = ungrouped: every learner pushes directly; G = λ is
                  equivalent — every group has one member).
    ``pull_jitter`` — per-(pull, shard) completion skew in simulated
                  seconds (0 = consistent snapshot reads; only meaningful
                  for S > 1).
    """

    shards: int = 1
    groups: int = 0
    pull_jitter: float = 0.0

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.groups < 0:
            raise ValueError(f"groups must be >= 0, got {self.groups}")
        if self.pull_jitter < 0:
            raise ValueError(f"pull_jitter must be >= 0, got {self.pull_jitter}")

    @classmethod
    def from_run(cls, run) -> "Topology":
        """The topology a RunConfig describes (validated against its λ)."""
        jitter = run.shard_pull_jitter
        topo = cls(shards=run.shards, groups=run.groups, pull_jitter=jitter)
        return topo.validate_for(run.n_learners)

    @classmethod
    def for_arch(cls, arch: str, lam: int, jitter: float = 0.0) -> "Topology":
        """Rudra preset → topology at λ learners.

        * ``base`` — flat PS, no groups.
        * ``adv``  — sharded PS (S = min(8, λ), the paper's PS-tree fanout).
        * ``adv*`` — sharded PS + learner groups of ~4 (the learner
          broadcast tree); pass ``jitter`` to enable inconsistent reads.
        """
        if arch == "base":
            return cls()
        shards = max(1, min(8, lam))
        if arch == "adv":
            return cls(shards=shards, pull_jitter=jitter)
        if arch == "adv*":
            for size in (4, 3, 2):
                if lam % size == 0:
                    groups = lam // size
                    return cls(shards=shards, groups=groups, pull_jitter=jitter)
            if lam == 1:
                return cls(shards=shards, pull_jitter=jitter)
            raise ValueError(
                f"adv* needs learner groups but λ={lam} has no group size "
                f"in (4, 3, 2); pick a divisible λ or build the Topology "
                f"explicitly"
            )
        raise ValueError(f"arch must be one of {RUDRA_ARCHS}, got {arch!r}")

    def validate_for(self, n_learners: int) -> "Topology":
        if self.groups and n_learners % self.groups != 0:
            raise ValueError(f"groups={self.groups} must divide λ={n_learners}")
        return self

    @property
    def grouped(self) -> bool:
        return self.groups > 0

    def n_pushers(self, n_learners: int) -> int:
        """Entities pushing gradients at the PS: groups, or raw learners."""
        return self.groups if self.grouped else n_learners

    def group_size(self, n_learners: int) -> int:
        """Members per pushing entity (1 ⇔ no effective grouping)."""
        if not self.grouped:
            return 1
        self.validate_for(n_learners)
        return n_learners // self.groups

    def members(self, n_learners: int) -> np.ndarray:
        """(P, gs) int32 learner ids of each pusher (contiguous blocks)."""
        gs = self.group_size(n_learners)
        return np.arange(n_learners, dtype=np.int32).reshape(-1, gs)

    def active_pushers(self, learner_active: np.ndarray) -> np.ndarray:
        """(P,) bool — which pushers are alive given a per-learner activity
        vector: a group keeps pushing as long as ONE member lives, and its
        pushes aggregate over the surviving members (the membership ×
        groups rule, DESIGN.md §7).  Ungrouped: the learners themselves."""
        active = np.asarray(learner_active, bool)
        return active[self.members(active.shape[0])].any(axis=1)

    def is_trivial(self, n_learners: int) -> bool:
        """Rudra-base: one shard, one learner per pusher — today's path."""
        return self.shards == 1 and self.group_size(n_learners) == 1

    def padded_width(self, dim: int) -> int:
        """Per-shard width Dp = ⌈D/S⌉ (last shard zero-padded)."""
        return -(-dim // self.shards)

    def shard_bounds(self, dim: int) -> List[Tuple[int, int]]:
        """[lo, hi) slice of the flat buffer owned by each shard."""
        dp = self.padded_width(dim)
        spans = [(s * dp, (s + 1) * dp) for s in range(self.shards)]
        return [(min(lo, dim), min(hi, dim)) for lo, hi in spans]

    def describe(self, n_learners: int) -> str:
        shape = f"shards={self.shards} groups={self.groups}"
        pushers = self.n_pushers(n_learners)
        size = self.group_size(n_learners)
        detail = f"pushers={pushers}, group_size={size}"
        return f"{shape} ({detail}, pull_jitter={self.pull_jitter})"
