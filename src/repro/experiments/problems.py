"""Problem registry for the experiment surface (DESIGN.md §5).

An :class:`ExperimentSpec` names its problem declaratively (a registry key +
keyword arguments) so a spec stays a frozen, JSON-serializable value; the
driver resolves the name to a **problem object** exposing the contract the
replay engine needs:

* ``init``                 — the initial parameter pytree;
* ``grad_fn(params, batch) -> grads`` — vmappable gradient;
* ``batch_fn_for(mu, seed) -> (learner, minibatch_idx) -> batch`` — host
  (numpy) batches, deterministic per (seed, learner, step);
* ``eval_fn(params) -> dict`` — the metric set (keys are metric names);
* ``dataset_size``         — samples per epoch (steps-from-epochs maths).

Problems are cached per (name, args): a sweep over 20 (protocol, seed) grid
points builds the teacher task and its jitted grad/eval functions once, and
every grid point shares the same ``grad_fn`` — the property that lets the
driver vmap shape-compatible grid points through one compiled scan.

``mlp_teacher`` — the repo's CIFAR-scale stand-in (2-layer MLP on the
teacher-classification task, DESIGN.md §11) — ships registered;
:func:`register_problem` adds new ones (see ``tests/test_experiments.py``
for a 4-line linear-regression example).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import TeacherClassification


def updates_for_epochs(epochs: float, mu: int, c: int, dataset: int,
                       group_size: int = 1) -> int:
    """Weight updates s.t. total samples == epochs·dataset (every update
    consumes c·μ·gs samples: c slots, each aggregating ``group_size``
    member minibatches — 1 without learner groups; hardsync has c = P)."""
    return max(1, int(epochs * dataset / (mu * c * group_size)))


# ---------------------------------------------------------------------------
# MLP learner on the teacher-classification task (the paper's CNN stand-in)
# ---------------------------------------------------------------------------
class MLPProblem:
    """2-layer MLP trained on TeacherClassification — the accuracy-axis
    vehicle for Figs. 5-7 / Tables 2-4 (non-convex, overfits, LR-sensitive:
    the properties the paper's claims depend on)."""

    def __init__(self, hidden: int = 64, task: TeacherClassification = None,
                 seed: int = 0):
        self.task = task or TeacherClassification()
        self.hidden = hidden
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        nf, nc = self.task.n_features, self.task.n_classes
        self.init = {
            "w1": jax.random.normal(k1, (nf, hidden)) / np.sqrt(nf),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, nc)) / np.sqrt(hidden),
            "b2": jnp.zeros((nc,)),
        }
        self._grad = jax.jit(jax.grad(self.loss))
        self._test_err = jax.jit(self._test_err_impl)

    @property
    def dataset_size(self) -> int:
        return self.task.n_train

    def loss(self, p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def _test_err_impl(self, p):
        x, y = self.task.x_test, self.task.y_test
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        pred = jnp.argmax(h @ p["w2"] + p["b2"], axis=-1)
        return 1.0 - jnp.mean((pred == y).astype(jnp.float32))

    def grad_fn(self, p, batch):
        return self._grad(p, batch)

    def batch_fn_for(self, mu: int, seed: int = 0) -> Callable:
        # returns host (numpy) arrays: the jitted grad_fn transfers them on
        # call, and the replay engine stages the whole trace's batches with
        # ONE device transfer per leaf instead of one per minibatch.
        def fn(learner: int, step: int):
            return self.task.minibatch(learner, step, mu, seed=seed)
        return fn

    def stage_minibatches(self, learner, mb_index, mu: int, seed: int = 0):
        """Whole-trace staging in one vectorized hash (optional problem
        protocol, see DESIGN.md §5): (steps, c) counter matrices → the
        (steps, c, …) batch pytree, element-identical to per-slot
        ``batch_fn`` calls.  This is what lets ``run_sweep`` stage a whole
        sweep cell in milliseconds instead of a steps×c Python loop per
        grid point."""
        return self.task.minibatch_array(learner, mb_index, mu, seed=seed)

    def test_error(self, p) -> float:
        return float(self._test_err(p))

    def eval_fn(self, p) -> Dict[str, float]:
        return {"test_error": self.test_error(p)}

    # -- serving hooks (train-while-serve, DESIGN.md §14) --------------------
    _REQUEST_RNG_TAG = 0x53525645

    def stage_requests(self, serving, fleet, seed: int = 0):
        """One batch of held-out samples per inference request: arrays with
        a leading (R,) request axis, staged host-side in one draw.  The rng
        stream is tagged independently of training batches, and the draw
        depends only on (R, request_samples, seed) — the same traffic asks
        the same questions whatever publication policy answers them."""
        rng = np.random.default_rng([seed, self._REQUEST_RNG_TAG])
        idx = rng.integers(0, self.task.n_test,
                           (serving.n_requests, fleet.request_samples))
        return (np.asarray(self.task.x_test)[idx],
                np.asarray(self.task.y_test)[idx])

    def request_metric(self, p, batch):
        """Accuracy of one request batch under the published weights —
        vmappable (the engine maps it over the (R,) request axis)."""
        x, y = batch
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        pred = jnp.argmax(h @ p["w2"] + p["b2"], axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# diagonal quadratic: the what-if replay vehicle (DESIGN.md §12)
# ---------------------------------------------------------------------------
class QuadraticProblem:
    """Diagonal quadratic loss ``0.5·mean(a·(w − w*)²)`` with closed-form
    gradients ``g = a ⊙ (w − w*)`` — the trace-driven *what-if* vehicle.

    Because the gradient is a flat elementwise expression, the replay
    engine evaluates it in-kernel (``flat_grad`` below) and never stages
    minibatch data: peak memory is the ring carry alone, which is what
    makes staleness what-if studies feasible at ``configs/`` big-model D
    (pass ``arch="qwen2_1_5b"`` etc. to size D to a registered
    architecture's parameter count).  ``a`` and ``w*`` are generated
    on-device from ``iota`` formulas — no (D,) host materialization, and
    deterministic in (d, seed).  The ``grad_fn``/``batch_fn_for`` twins
    keep the problem valid on every non-what-if path (stock impl, legacy
    oracle, sharded traces): the batch is a 1-element dummy the gradient
    ignores.
    """

    def __init__(self, d: int = 4096, arch: str = None, seed: int = 0):
        if arch is not None:
            from repro.configs import get_config
            d = int(get_config(arch).param_count())
        self.d = int(d)
        self._seed = seed

        def make(dd=self.d, s=seed):
            i = jnp.arange(dd, dtype=jnp.float32)
            # curvatures in [0.5, 1.5): positive definite, non-isotropic
            a = 0.5 + ((i + 37.0 * s) % 1000.0) / 1000.0
            wstar = jnp.sin(1e-3 * i + s)
            return a, wstar

        a, wstar = jax.jit(make)()
        self.flat_grad = ("quadratic", a, wstar)
        # a / w* enter the jit as ARGUMENTS, never closure constants: XLA
        # embeds closed-over arrays as program constants (an extra full-D
        # copy each, plus constant-folded derivatives like -w*), which at
        # what-if scale is tens of bytes/param of pure waste.
        self._loss = jax.jit(
            lambda w, a, ws: 0.5 * jnp.mean(a * (w - ws) ** 2))

    @property
    def init(self) -> Dict[str, jax.Array]:
        # a fresh zeros pytree per access: the engine flattens it and drops
        # the reference, so w0 never stays live across the replay — at
        # what-if D every avoided (D,) resident is 4 bytes/param of peak
        return {"w": jnp.zeros((self.d,), jnp.float32)}

    @property
    def dataset_size(self) -> int:
        return 1 << 16          # synthetic: epochs-maths placeholder

    def grad_fn(self, p, batch):
        a, wstar = self.flat_grad[1], self.flat_grad[2]
        return {"w": a * (p["w"] - wstar)}

    def batch_fn_for(self, mu: int, seed: int = 0) -> Callable:
        def fn(learner: int, step: int):
            return np.zeros((1,), np.float32)
        return fn

    def stage_minibatches(self, learner, mb_index, mu: int, seed: int = 0):
        return np.zeros(np.shape(learner) + (1,), np.float32)

    def eval_fn(self, p) -> Dict[str, float]:
        return {"loss": float(self._loss(p["w"], self.flat_grad[1],
                                         self.flat_grad[2]))}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable] = {}
_CACHE: Dict[Tuple, object] = {}


def register_problem(name: str, factory: Callable, version: int = 1) -> None:
    """Register ``factory(**kwargs) -> problem`` under ``name``.  The factory
    result must expose init / grad_fn / batch_fn_for / eval_fn /
    dataset_size (see module docstring).  ``version`` is the problem's
    content identity for spec hashing (DESIGN.md §15): bump it when the
    problem's semantics change and every cached result that used it goes
    stale."""
    from repro.experiments.spec_hash import register_problem_version
    register_problem_version(name, version)
    _REGISTRY[name] = factory


def problem_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_problem(name: str, args: Tuple[Tuple[str, object], ...] = ()):
    """Resolve (and cache) a registered problem.  ``args`` is the spec's
    hashable ``problem_args`` tuple-of-pairs."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown problem {name!r}; registered: "
                       f"{problem_names()}")
    key = (name, tuple(args))
    if key not in _CACHE:
        _CACHE[key] = _REGISTRY[name](**dict(args))
    return _CACHE[key]


register_problem("mlp_teacher", MLPProblem)
register_problem("quadratic_whatif", QuadraticProblem)
