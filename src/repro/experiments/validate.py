"""Validate results files against the RunResult record schema.

    PYTHONPATH=src python -m repro.experiments.validate benchmarks/results

Walks every ``*.json`` under the given paths (or the default
``benchmarks/results``), checks the envelope + each record
(``result.validate_results_file``), and exits non-zero on any violation —
the CI smoke lane's schema gate.
"""

from __future__ import annotations

import glob
import os
import sys

from repro.experiments.result import validate_results_file


def validate_paths(paths) -> int:
    """Validate every results JSON under ``paths``; returns the number of
    files checked.  Raises ValueError on the first schema violation, on a
    path that is neither a file nor a directory, and on a directory with no
    ``*.json`` at all — an empty or missing results directory must fail the
    CI gate loudly instead of "validating" nothing."""
    files = []
    for p in paths:
        if os.path.isdir(p):
            found = sorted(glob.glob(os.path.join(p, "*.json")))
            if not found:
                raise ValueError(
                    f"{p}: results directory contains no *.json files")
            files.extend(found)
        elif os.path.isfile(p):
            files.append(p)
        else:
            raise ValueError(f"{p}: no such results file or directory")
    if not files:
        raise ValueError("no results files given (empty path list)")
    for path in files:
        n = validate_results_file(path)
        print(f"[validate] {path}: ok ({n} records)")
    return len(files)


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or \
        [os.path.join("benchmarks", "results")]
    try:
        n = validate_paths(paths)
    except (ValueError, OSError) as e:
        # OSError: unreadable/vanished file — same loud failure as a schema
        # violation, never a silent green gate
        print(f"[validate] FAIL: {e}", file=sys.stderr)
        return 1
    print(f"[validate] {n} file(s) conform to the RunResult record schema")
    return 0


if __name__ == "__main__":
    sys.exit(main())
