"""Validate results files against the RunResult record schema AND the
campaign registry's content addresses (DESIGN.md §15).

    PYTHONPATH=src python -m repro.experiments.validate benchmarks/results
    PYTHONPATH=src python -m repro.experiments.validate --strict
    PYTHONPATH=src python -m repro.experiments.validate --migrate

Walks every ``*.json`` under the given paths (or the default
``benchmarks/results``), checks the envelope + each record
(``result.validate_results_file``), and exits non-zero on any schema
violation — the CI smoke lane's schema gate.

On top of the schema, every file owned by a registered cell is checked for
**staleness**: a legacy (v1) envelope, records missing ``spec_hash``, or a
campaign stamp that no longer matches the registry's cell hash all report
``STALE``.  Plain runs only warn (the schema stays the hard gate);
``--strict`` turns any STALE file into a non-zero exit.

``--migrate`` re-stamps legacy envelopes in place: each record gains the
``spec_hash`` of its **own recorded spec echo** (records are otherwise
byte-identical), the envelope gains the owning cell's name and campaign
block at the registry's default params, and ``schema_version`` bumps to the
current schema.  Idempotent; files with no owning cell are left alone.
"""

from __future__ import annotations

import glob
import json
import os
import sys

from repro.experiments.result import SCHEMA_VERSION, validate_results_file


def _collect(paths):
    files = []
    for p in paths:
        if os.path.isdir(p):
            found = sorted(glob.glob(os.path.join(p, "*.json")))
            if not found:
                raise ValueError(
                    f"{p}: results directory contains no *.json files")
            files.extend(found)
        elif os.path.isfile(p):
            files.append(p)
        else:
            raise ValueError(f"{p}: no such results file or directory")
    if not files:
        raise ValueError("no results files given (empty path list)")
    return files


def validate_paths(paths) -> int:
    """Validate every results JSON under ``paths``; returns the number of
    files checked.  Raises ValueError on the first schema violation, on a
    path that is neither a file nor a directory, and on a directory with no
    ``*.json`` at all — an empty or missing results directory must fail the
    CI gate loudly instead of "validating" nothing."""
    files = _collect(paths)
    for path in files:
        n = validate_results_file(path)
        print(f"[validate] {path}: ok ({n} records)")
    return len(files)


def staleness_report(paths) -> list:
    """(path, status, detail) for every file owned by a registered cell.

    STALE means the file no longer matches the registry's content address:
    legacy schema, records without ``spec_hash``, or a ``cell_hash`` stamp
    that differs from what the registered specs/params hash to today.
    Files whose stem no cell owns get status ``UNREGISTERED`` (informative,
    never an error: ad-hoc results are allowed to exist)."""
    from repro.experiments.campaign import cell_status
    from repro.experiments.registry import cell_for_result

    rows = []
    for path in _collect(paths):
        stem = os.path.splitext(os.path.basename(path))[0]
        cell = cell_for_result(stem)
        if cell is None:
            rows.append((path, "UNREGISTERED", "no cell owns this file"))
            continue
        status, detail = cell_status(cell,
                                     results_dir=os.path.dirname(path))
        rows.append((path, status, detail))
    return rows


def migrate_file(path: str) -> str:
    """Re-stamp one legacy envelope in place (see module docstring).
    Returns what happened: 'migrated', 'current', or 'unregistered'."""
    from repro.experiments.registry import cell_for_result, cell_hash
    from repro.experiments.spec_hash import spec_hash_from_echo

    stem = os.path.splitext(os.path.basename(path))[0]
    cell = cell_for_result(stem)
    if cell is None:
        return "unregistered"
    with open(path) as f:
        data = json.load(f)

    changed = data.get("schema_version") != SCHEMA_VERSION
    data["schema_version"] = SCHEMA_VERSION
    for rec in data.get("records", []):
        # the record's OWN echo is the identity — never the registry's
        # current spec list, which may legitimately differ (that's what
        # STALE is for)
        want = spec_hash_from_echo(rec["spec"])
        if rec.get("spec_hash") != want:
            rec["spec_hash"] = want
            changed = True
    stamp = {"cell_hash": cell_hash(cell),
             "params": cell.resolved_params(),
             "partial": False}
    if data.get("cell") != cell.name or data.get("campaign") != stamp:
        data["cell"] = cell.name
        data["campaign"] = stamp
        changed = True
    if not changed:
        return "current"
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
        f.write("\n")
    return "migrated"


def main(argv=None) -> int:
    args = list(argv if argv is not None else sys.argv[1:])
    strict = "--strict" in args
    migrate = "--migrate" in args
    paths = [a for a in args if a not in ("--strict", "--migrate")] or \
        [os.path.join("benchmarks", "results")]

    try:
        if migrate:
            for path in _collect(paths):
                outcome = migrate_file(path)
                print(f"[validate] migrate {path}: {outcome}")
        n = validate_paths(paths)
        rows = staleness_report(paths)
    except (ValueError, OSError, KeyError) as e:
        # OSError: unreadable/vanished file — same loud failure as a schema
        # violation, never a silent green gate
        print(f"[validate] FAIL: {e}", file=sys.stderr)
        return 1

    stale = [r for r in rows if r[1] in ("STALE", "PARTIAL")]
    for path, status, detail in rows:
        if status != "CURRENT":
            print(f"[validate] {path}: {status} ({detail})")
    print(f"[validate] {n} file(s) conform to the RunResult record schema; "
          f"{len(stale)} stale/partial vs the campaign registry")
    if strict and stale:
        print(f"[validate] FAIL (--strict): {len(stale)} file(s) are stale "
              f"against the registry — re-run the campaign or --migrate "
              f"re-stamps legacy envelopes", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
