"""Sweep: the declarative grid builder over ExperimentSpecs (DESIGN.md §5).

The paper is a *systematic sweep* over (σ, μ, λ, protocol, LR policy); a
:class:`Sweep` expresses such a grid as a base spec plus named axes:

    sweep = Sweep.over(base,
                       protocol=["hardsync", "softsync"],
                       minibatch=[4, 128],
                       seed=range(5))
    results = run_sweep(sweep)

Axis names resolve against ``RunConfig`` fields first (protocol, minibatch,
n_learners, seed, base_lr, …, including the elastic axes ``membership`` —
:class:`~repro.membership.MembershipTimeline` values, tagged by their
compact ``str()`` form — and ``backup``), then against ``ExperimentSpec``
fields (steps, epochs, eval_every, …).  The special axis ``cases`` takes
dicts of coupled field patches — e.g. the paper's (protocol, n_softsync,
lr_policy) combinations that only make sense together:

    Sweep.over(base, cases=[
        {"protocol": "hardsync", "lr_policy": "sqrt_scale"},
        {"protocol": "softsync", "n_softsync": 1,
         "lr_policy": "staleness_inverse"},
    ], seed=range(3))

Grid points are the cartesian product in axis-declaration order; each spec
gets an auto-tag like ``"protocol=softsync/seed=2"`` (a ``tag`` key inside
a case dict overrides its fragment).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List

from repro.config import RunConfig
from repro.experiments.spec import ExperimentSpec

_RUN_FIELDS = {f.name for f in dataclasses.fields(RunConfig)}
_SPEC_FIELDS = {f.name for f in dataclasses.fields(ExperimentSpec)} - {"run"}


def _apply(spec: ExperimentSpec, patch: Dict) -> ExperimentSpec:
    """Patch a spec: keys split between RunConfig and ExperimentSpec."""
    run_kw = {k: v for k, v in patch.items() if k in _RUN_FIELDS}
    spec_kw = {k: v for k, v in patch.items() if k in _SPEC_FIELDS}
    unknown = set(patch) - set(run_kw) - set(spec_kw)
    if unknown:
        raise ValueError(f"unknown sweep field(s) {sorted(unknown)}; "
                         f"RunConfig fields: {sorted(_RUN_FIELDS)}; "
                         f"ExperimentSpec fields: {sorted(_SPEC_FIELDS)}")
    if run_kw:
        spec_kw["run"] = spec.run.replace(**run_kw)
    return spec.replace(**spec_kw) if spec_kw else spec


def _fragment(axis: str, value) -> str:
    if axis == "cases":
        return value.get("tag", "/".join(f"{k}={v}"
                                         for k, v in value.items()))
    return f"{axis}={value}"


class Sweep:
    """A base ExperimentSpec crossed with named axes (see module docstring).
    Iterating yields the grid's ExperimentSpecs in product order."""

    def __init__(self, base: ExperimentSpec, axes: Dict[str, Iterable]):
        self.base = base
        self.axes = {name: list(values) for name, values in axes.items()}
        for name, values in self.axes.items():
            if not values:
                raise ValueError(f"axis {name!r} is empty")
            if name != "cases" and name not in _RUN_FIELDS | _SPEC_FIELDS:
                raise ValueError(f"unknown axis {name!r}")

    @classmethod
    def over(cls, base: ExperimentSpec, **axes) -> "Sweep":
        """The grid builder: ``Sweep.over(base, protocol=[...], seed=[...])``."""
        return cls(base, axes)

    def __len__(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return n

    def specs(self) -> List[ExperimentSpec]:
        names = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            spec = self.base
            frags = []
            for name, value in zip(names, combo):
                patch = dict(value) if name == "cases" else {name: value}
                spec = _apply(spec, patch)
                frags.append(_fragment(name, value))
            tag = "/".join(f for f in frags if f)
            if self.base.tag:
                tag = f"{self.base.tag}/{tag}" if tag else self.base.tag
            out.append(spec.replace(tag=tag))
        return out

    def __iter__(self):
        return iter(self.specs())
