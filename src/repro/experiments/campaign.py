"""The campaign runner: the whole paper as one resumable DAG (DESIGN.md §15).

::

    python -m repro.experiments.campaign paper                 # run the paper
    python -m repro.experiments.campaign paper --dry-run       # plan only
    python -m repro.experiments.campaign paper --only fig4     # one cell + deps
    python -m repro.experiments.campaign smoke --quick         # CI smoke lane
    python -m repro.experiments.campaign report                # claim report
    python -m repro.experiments.campaign list                  # registry dump

Each registered :class:`~repro.experiments.registry.Cell` resolves to a
**status** against the results directory before anything executes:

* ``CURRENT`` — the envelope's campaign stamp matches the cell's content
  hash and (for spec cells) its records cover every spec hash: skipped;
* ``PARTIAL`` — stamp matches but records cover a strict subset of the
  spec hashes (an interrupted grid): only the missing specs run, cached
  records are reused **byte-identically**;
* ``STALE`` — legacy v1 envelope, missing stamps, or a hash mismatch
  (spec change, config default change, problem version bump, dep cell
  re-addressed): re-executed;
* ``MISSING`` — no envelope: executed.

``--force`` re-executes regardless of status (scoped to ``--only`` cells
when given).  Spec cells flush a partial envelope every
``checkpoint_every`` completed specs, so an interrupted campaign resumes
at the first missing record, not the first missing cell.

Claims evaluate after derive and land in the envelope's campaign block;
``--strict`` turns any failed claim or non-CURRENT outcome into a
non-zero exit.  ``--status-json`` writes the per-cell action/seconds
ledger the CI cache-hit assertions read.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.experiments.registry import (Cell, cell_hash, cell_spec_hashes,
                                        cell_specs, cells_in,
                                        default_results_dir, get_cell,
                                        load_envelope, resolve_order,
                                        results_path)
from repro.experiments.result import RunResult, envelope

CAMPAIGNS = ("paper", "extended", "smoke")


# ---------------------------------------------------------------------------
# status
# ---------------------------------------------------------------------------
def cell_status(cell: Cell, params: Optional[Dict[str, Any]] = None,
                quick: bool = False, results_dir: Optional[str] = None
                ) -> Tuple[str, str]:
    """(status, detail) of the cell's envelope against its content hash."""
    data = load_envelope(cell, results_dir)
    if data is None:
        return "MISSING", "no results file"
    if data.get("schema_version") != 2:
        return "STALE", f"schema v{data.get('schema_version')} (legacy)"
    camp = data.get("campaign") or {}
    stamped = camp.get("cell_hash", "")
    want = cell_hash(cell, params, quick=quick)
    if stamped != want:
        return "STALE", f"cell_hash {stamped or '(none)'} != {want}"
    if cell.specs is None:
        return "CURRENT", "cell hash matches"
    have = [r.get("spec_hash", "") for r in data.get("records", [])]
    want_hashes = cell_spec_hashes(cell, params, quick=quick)
    unknown = [h for h in have if h not in set(want_hashes)]
    if unknown:
        return "STALE", f"{len(unknown)} record(s) match no spec"
    missing = [h for h in want_hashes if h not in set(have)]
    if missing:
        return ("PARTIAL",
                f"{len(want_hashes) - len(missing)}/{len(want_hashes)} "
                f"records present")
    return "CURRENT", f"all {len(want_hashes)} records present"


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
def _evaluate_claims(cell: Cell, derived: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for claim in cell.claims:
        ok, detail = claim.evaluate(derived)
        out[claim.name] = {"ok": ok, **({"detail": detail} if detail else {})}
    return out


def _campaign_block(cell: Cell, params: Dict[str, Any], quick: bool,
                    partial: bool, claims: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    block: Dict[str, Any] = {
        "cell_hash": cell_hash(cell, params, quick=quick),
        "params": cell.resolved_params(params, quick=quick),
        "partial": partial,
    }
    if quick:
        block["quick"] = True
    if claims is not None:
        block["claims"] = claims
    return block


def write_envelope(cell: Cell, records: List[Dict[str, Any]],
                   derived: Dict[str, Any], params: Dict[str, Any],
                   quick: bool, partial: bool, results_dir: Optional[str],
                   claims: Optional[Dict[str, Any]] = None) -> str:
    path = results_path(cell, results_dir)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    data = envelope(cell.result, records, derived, cell=cell.name,
                    campaign=_campaign_block(cell, params, quick, partial,
                                             claims))
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
        f.write("\n")
    return path


def _run_spec_cell(cell: Cell, params: Dict[str, Any], quick: bool,
                   results_dir: Optional[str], force: bool) -> Dict[str, Any]:
    """Execute (or resume) a spec cell; returns the claims dict."""
    from repro.experiments.driver import run_sweep

    specs = cell_specs(cell, params, quick=quick)
    hashes = cell_spec_hashes(cell, params, quick=quick)
    if len(set(hashes)) != len(hashes):
        dup = [h for h in hashes if hashes.count(h) > 1][0]
        raise ValueError(f"cell {cell.name!r}: duplicate spec hash {dup} — "
                         f"grid points must be distinguishable (tag them)")

    cached: Dict[str, Dict[str, Any]] = {}
    if not force:
        data = load_envelope(cell, results_dir)
        if data is not None and data.get("schema_version") == 2:
            stamped = (data.get("campaign") or {}).get("cell_hash", "")
            if stamped == cell_hash(cell, params, quick=quick):
                for rec in data.get("records", []):
                    h = rec.get("spec_hash", "")
                    if h in set(hashes):
                        cached[h] = rec      # reused verbatim: byte-stable

    todo = [(i, s) for i, (s, h) in enumerate(zip(specs, hashes))
            if h not in cached]
    done: Dict[str, Dict[str, Any]] = dict(cached)

    step = max(1, cell.checkpoint_every)
    for lo in range(0, len(todo), step):
        chunk = todo[lo:lo + step]
        for res in run_sweep([s for _, s in chunk]):
            rec = res.record()
            done[rec["spec_hash"]] = rec
        if lo + step < len(todo):       # mid-grid: flush a resumable partial
            partial_records = [done[h] for h in hashes if h in done]
            write_envelope(cell, partial_records, {}, params, quick,
                           partial=True, results_dir=results_dir)

    records = [done[h] for h in hashes]
    results = [RunResult.from_record(r) for r in records]
    p = cell.resolved_params(params, quick=quick)
    derived = cell.derive(results, p)
    claims = _evaluate_claims(cell, derived)
    write_envelope(cell, records, derived, params, quick, partial=False,
                   results_dir=results_dir, claims=claims)
    return claims


def _run_compute_cell(cell: Cell, params: Dict[str, Any], quick: bool,
                      results_dir: Optional[str]) -> Dict[str, Any]:
    p = cell.resolved_params(params, quick=quick)
    kw = dict(p)
    if cell.needs_results_dir:
        kw["results_dir"] = results_dir or default_results_dir()
    records, derived = cell.compute(**kw)
    claims = _evaluate_claims(cell, derived)
    write_envelope(cell, [r.record() if isinstance(r, RunResult) else r
                          for r in records],
                   derived, params, quick, partial=False,
                   results_dir=results_dir, claims=claims)
    return claims


def execute_cell(cell: Cell, params: Optional[Dict[str, Any]] = None,
                 quick: bool = False, results_dir: Optional[str] = None,
                 force: bool = False) -> Dict[str, Any]:
    """Run one cell to a finished envelope; returns its claims dict."""
    if cell.specs is not None:
        return _run_spec_cell(cell, params or {}, quick, results_dir, force)
    return _run_compute_cell(cell, params or {}, quick, results_dir)


def run_cell(name: str, params: Optional[Dict[str, Any]] = None,
             force: bool = True, quick: bool = False,
             results_dir: Optional[str] = None) -> Dict[str, Any]:
    """Execute a cell and return its envelope's ``derived`` dict — the
    compat entry point the deprecated ``benchmarks/*.py`` shims call."""
    cell = get_cell(name)
    if not force:
        status, _ = cell_status(cell, params, quick, results_dir)
        if status == "CURRENT":
            return (load_envelope(cell, results_dir) or {}).get("derived", {})
    execute_cell(cell, params, quick=quick, results_dir=results_dir,
                 force=force)
    return (load_envelope(cell, results_dir) or {}).get("derived", {})


# ---------------------------------------------------------------------------
# campaign loop
# ---------------------------------------------------------------------------
def plan(campaign: str, only: Sequence[str] = ()) -> List[Cell]:
    """The cells to visit, dependency-first."""
    if only:
        return [get_cell(n) for n in resolve_order(list(only))]
    return cells_in(campaign)


def run_campaign(campaign: str = "paper", only: Sequence[str] = (),
                 force: bool = False, dry_run: bool = False,
                 quick: bool = False, results_dir: Optional[str] = None,
                 out=sys.stdout) -> Dict[str, Any]:
    """Drive the DAG; returns the status ledger (also ``--status-json``)."""
    if quick and results_dir is None:
        # a quick grid must never clobber the checked-in full-size results
        results_dir = os.path.join(default_results_dir(), "quick")
    forced = set(only) if only else None    # --force scoped to --only cells
    ledger: Dict[str, Any] = {"campaign": campaign, "quick": quick,
                              "results_dir": results_dir or
                              default_results_dir(),
                              "cells": {}, "executed": 0, "cached": 0,
                              "skipped": 0, "failed_claims": 0}
    t_campaign = time.monotonic()
    for cell in plan(campaign, only):
        entry: Dict[str, Any] = {}
        t0 = time.monotonic()
        if quick and cell.skip_quick:
            entry.update(status="SKIPPED", action="skipped",
                         detail="skip_quick")
            ledger["skipped"] += 1
        else:
            status, detail = cell_status(cell, None, quick, results_dir)
            entry.update(status=status, detail=detail,
                         cell_hash=cell_hash(cell, None, quick=quick))
            do_force = force and (forced is None or cell.name in forced)
            if status == "CURRENT" and not do_force:
                entry["action"] = "cached"
                ledger["cached"] += 1
            elif dry_run:
                entry["action"] = "would-run"
            else:
                claims = execute_cell(cell, None, quick=quick,
                                      results_dir=results_dir,
                                      force=do_force or status == "STALE")
                entry["action"] = "executed"
                entry["claims"] = claims
                bad = [n for n, c in claims.items() if not c["ok"]]
                if bad:
                    entry["failed_claims"] = bad
                    ledger["failed_claims"] += len(bad)
                ledger["executed"] += 1
        entry["seconds"] = round(time.monotonic() - t0, 3)
        ledger["cells"][cell.name] = entry
        print(f"[campaign] {cell.name:<14} {entry['status']:<8} "
              f"{entry['action']:<10} {entry['seconds']:>8.2f}s  "
              f"{entry.get('detail', '')}", file=out)
    ledger["total_seconds"] = round(time.monotonic() - t_campaign, 3)
    print(f"[campaign] {campaign}: {ledger['executed']} executed, "
          f"{ledger['cached']} cached, {ledger['skipped']} skipped in "
          f"{ledger['total_seconds']:.1f}s", file=out)
    return ledger


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------
def report(campaign: str = "paper", results_dir: Optional[str] = None,
           out=sys.stdout) -> int:
    """Claim/status report over the registry; returns #problems."""
    problems = 0
    for cell in cells_in(campaign):
        status, detail = cell_status(cell, None, False, results_dir)
        if status != "CURRENT":
            problems += 1
        print(f"{cell.name:<14} {status:<8} {cell.title or cell.result}",
              file=out)
        data = load_envelope(cell, results_dir)
        claims = ((data or {}).get("campaign") or {}).get("claims") or {}
        for name, c in sorted(claims.items()):
            mark = "PASS" if c.get("ok") else "FAIL"
            if not c.get("ok"):
                problems += 1
            print(f"  claim {mark:<4} {name}"
                  + (f"  ({c['detail']})" if c.get("detail") else ""),
                  file=out)
    return problems


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.campaign",
        description="Run paper campaigns as a content-addressed DAG.")
    ap.add_argument("campaign", nargs="?", default="paper",
                    help=f"campaign name {CAMPAIGNS}, 'report', or 'list'")
    ap.add_argument("--only", action="append", default=[],
                    help="run only this cell (+ its deps); repeatable")
    ap.add_argument("--force", action="store_true",
                    help="re-execute even when CURRENT (scoped to --only)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the plan and each cell's status; run nothing")
    ap.add_argument("--quick", action="store_true",
                    help="cheap parameterizations (CI lane); writes to "
                         "<results>/quick unless --results-dir is given")
    ap.add_argument("--results-dir", default=None)
    ap.add_argument("--status-json", default=None,
                    help="write the per-cell action/seconds ledger here")
    ap.add_argument("--strict", action="store_true",
                    help="non-zero exit on failed claims or non-CURRENT "
                         "dry-run cells")
    args = ap.parse_args(argv)

    if args.campaign == "list":
        from repro.experiments.registry import cell_names
        for name in cell_names():
            cell = get_cell(name)
            kind = "spec" if cell.specs is not None else "compute"
            deps = f" deps={','.join(cell.deps)}" if cell.deps else ""
            print(f"{name:<14} {kind:<7} {cell.result:<20} "
                  f"[{','.join(cell.campaigns)}]{deps}  {cell.title}")
        return 0

    if args.campaign == "report":
        problems = report(results_dir=args.results_dir)
        return 1 if (args.strict and problems) else 0

    ledger = run_campaign(args.campaign, only=tuple(args.only),
                          force=args.force, dry_run=args.dry_run,
                          quick=args.quick, results_dir=args.results_dir)
    if args.status_json:
        with open(args.status_json, "w") as f:
            json.dump(ledger, f, indent=1)
    if args.strict:
        not_current = [n for n, e in ledger["cells"].items()
                       if e["status"] != "CURRENT"
                       and e["action"] in ("would-run", "cached")]
        if args.dry_run and not_current:
            print(f"[campaign] --strict: {len(not_current)} cell(s) not "
                  f"CURRENT: {not_current}", file=sys.stderr)
            return 1
        if ledger["failed_claims"]:
            print(f"[campaign] --strict: {ledger['failed_claims']} "
                  f"failed claim(s)", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
