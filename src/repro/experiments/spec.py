"""The declarative experiment description (DESIGN.md §5).

An :class:`ExperimentSpec` is a frozen value object holding everything one
run of the paper's study needs: the :class:`~repro.config.RunConfig` (the
(σ, μ, λ) knobs), the problem (a registry name, see ``problems.py``), the
budget (``steps`` or ``epochs``), the duration model feeding the runtime
axis, the metric schedule, and an engine choice.  ``run(spec)`` executes it;
``Sweep`` builds grids of them; the spec echoes itself into every
:class:`~repro.experiments.result.RunResult` so a results file is
self-describing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# The calibrated-duration grammar ("calibrated:<arch>[:<int>mb]") and its
# parser live in repro.config — ONE parser and error message shared with
# RunConfig.duration_model, which accepts the same strings (the two layers
# used to disagree: the spec allowed "calibrated:base:300mb" while the
# RunConfig one level down rejected it with a misleading message).
from repro.config import (CALIBRATED_ARCHS, CALIBRATED_PREFIX,  # noqa: F401
                          RunConfig, parse_calibrated)
from repro.experiments.problems import get_problem, updates_for_epochs

ENGINES = ("auto", "compiled", "legacy", "measure")


def _as_arg_tuple(args) -> Tuple[Tuple[str, object], ...]:
    if isinstance(args, dict):
        return tuple(sorted(args.items()))
    return tuple((str(k), v) for k, v in args)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment = one (RunConfig, problem, budget, metrics) point.

    ``problem=None`` is **measure mode**: no gradients, the schedule pass
    alone (staleness/runtime statistics — the paper's Fig. 4).  Exactly one
    of ``steps`` / ``epochs`` must be set; ``epochs`` is resolved against
    the problem's dataset size (measure mode requires explicit ``steps``).
    """

    run: RunConfig = dataclasses.field(default_factory=RunConfig)
    problem: Optional[str] = None
    problem_args: Tuple[Tuple[str, object], ...] = ()
    steps: Optional[int] = None
    epochs: Optional[float] = None
    duration: str = "config"
    eval_every: int = 0
    engine: str = "auto"
    tag: str = ""

    def __post_init__(self):
        object.__setattr__(self, "problem_args",
                           _as_arg_tuple(self.problem_args))
        if (self.steps is None) == (self.epochs is None):
            raise ValueError("set exactly one of steps / epochs")
        if self.steps is not None and self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {self.engine!r}")
        if self.duration != "config":
            try:
                parse_calibrated(self.duration)
            except ValueError as e:
                raise ValueError(
                    f"duration must be 'config' or match the calibrated "
                    f"grammar — {e}") from None
        if self.problem is None:
            if self.engine not in ("auto", "measure"):
                raise ValueError("problem=None (measure mode) only runs on "
                                 "engine 'auto'/'measure'")
            if self.epochs is not None:
                raise ValueError("measure mode needs explicit steps "
                                 "(no dataset to derive epochs from)")
        elif self.engine == "measure":
            raise ValueError("engine='measure' takes problem=None")
        if self.engine == "legacy" and (self.run.shards > 1
                                        or self.run.group_size > 1
                                        or self.run.elastic
                                        or self.run.backup
                                        or self.run.serving is not None):
            raise ValueError(
                "engine='legacy' (the per-arrival host PS) models the flat "
                "static Rudra-base server only; sharded/grouped topologies, "
                "elastic membership/backup, and serving fleets (shards/"
                "groups/membership/backup/serving on RunConfig) replay on "
                "the compiled engine")

    def replace(self, **kw) -> "ExperimentSpec":
        """Copy with fields changed; validation re-runs (frozen contract)."""
        return dataclasses.replace(self, **kw)

    # -- resolution ----------------------------------------------------------
    @property
    def measure_only(self) -> bool:
        return self.problem is None

    def resolve_problem(self):
        return (None if self.problem is None
                else get_problem(self.problem, self.problem_args))

    def resolved_steps(self) -> int:
        """The update budget: ``steps`` verbatim, or epochs·dataset samples
        converted at c·μ·group_size samples per update."""
        if self.steps is not None:
            return int(self.steps)
        prob = self.resolve_problem()
        return updates_for_epochs(self.epochs, self.run.minibatch,
                                  self.run.gradients_per_update,
                                  prob.dataset_size,
                                  group_size=self.run.group_size)

    def resolved_engine(self) -> str:
        if self.engine != "auto":
            return self.engine
        return "measure" if self.measure_only else "compiled"

    def duration_sampler(self):
        """The ``(rng, mu, learner) -> seconds`` sampler this spec implies,
        or None to defer to ``RunConfig.duration_model`` inside schedule()."""
        if self.duration == "config":
            return None
        from repro.core import tradeoff as to
        arch, model_bytes = parse_calibrated(self.duration)
        wl = to.WorkloadModel()
        if model_bytes is not None:
            wl = dataclasses.replace(wl, model_bytes=model_bytes)
        if self.problem is not None:
            prob = self.resolve_problem()
            wl = dataclasses.replace(
                wl, dataset_size=prob.dataset_size,
                epochs=self.epochs if self.epochs is not None else wl.epochs)
        # calibration pins the paper's CIFAR baseline wall-clock (§5.4); the
        # workload model then rescales it to this problem's dataset/epochs
        return to.minibatch_duration_sampler(
            arch, self.run.n_learners, to.calibrate_to_baseline(), wl)

    def echo(self) -> dict:
        """The JSON config echo embedded in every RunResult record."""
        d = dataclasses.asdict(self)
        d["problem_args"] = dict(self.problem_args)
        d["run"] = {k: (list(v) if isinstance(v, tuple) else v)
                    for k, v in dataclasses.asdict(self.run).items()}
        return d
