"""The campaign cell registry (DESIGN.md §15).

Every paper table/figure is a **Cell**: a named, versioned description of
how its results file is produced —

* ``specs(**params)`` — the cell's spec-graph: the list of
  :class:`ExperimentSpec`\\ s whose RunResults are the file's ``records``
  (None for compute cells);
* ``derive(results, params)`` — records → the free-form ``derived`` dict
  (claim inputs, curves, tables).  Pure in the records for spec cells;
  a handful of *timing* cells measure wall-clock here and are documented
  as such;
* ``compute(**params)`` — for cells with no spec-graph (analytic models,
  wall-clock benchmarks, subprocess measurements): returns
  ``(records, derived)`` directly;
* ``claims`` — declarative :class:`Claim` checks over ``derived``,
  evaluated by the campaign runner into the envelope's campaign block;
* ``deps`` — names of cells whose results this cell consumes, resolved
  as a DAG by the campaign CLI and folded into this cell's content hash.

Cells register under short names (``fig4``, ``table2``, ``sim_engine``)
via :func:`register_cell`; ``repro.experiments.cells`` imports every cell
module so loading the registry is one import.  Content addressing:

* ``cell_spec_hashes(cell, params)`` — the per-record addresses;
* ``cell_hash(cell, params)`` — the whole-cell address: name, version,
  schema, the spec hashes (or canonical params for compute cells), and
  the dep cells' hashes.  An envelope stamped with a matching cell hash
  whose records cover the spec hashes is CURRENT and never re-run.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.experiments.result import SCHEMA_VERSION
from repro.experiments.spec_hash import content_hash, spec_hash


def emit(name: str, value, derived: str = "") -> None:
    """CSV row ``name,value,derived`` — the benchmark output idiom."""
    print(f"{name},{value},{derived}")


@dataclasses.dataclass(frozen=True)
class Claim:
    """A declarative check over a cell's ``derived`` dict."""

    name: str
    check: Callable[[Dict[str, Any]], bool]
    detail: Optional[Callable[[Dict[str, Any]], str]] = None

    def evaluate(self, derived: Dict[str, Any]) -> Tuple[bool, str]:
        try:
            ok = bool(self.check(derived))
        except (KeyError, TypeError, ZeroDivisionError) as e:
            return False, f"check raised {type(e).__name__}: {e}"
        det = ""
        if self.detail is not None:
            try:
                det = self.detail(derived)
            except Exception:
                det = ""
        return ok, det


def derived_claims(*names: str) -> Tuple[Claim, ...]:
    """Claims over a derive() that already computes ``derived["claims"]``
    booleans — the declarative layer just re-asserts them by name."""
    return tuple(Claim(n, (lambda d, n=n: bool(d["claims"][n])))
                 for n in names)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One registered table/figure — see the module docstring."""

    name: str
    result: str                     # results file stem (benchmark field)
    title: str = ""
    specs: Optional[Callable[..., List]] = None
    derive: Optional[Callable[[List, Dict[str, Any]], Dict[str, Any]]] = None
    compute: Optional[Callable[..., Tuple[list, Dict[str, Any]]]] = None
    claims: Tuple[Claim, ...] = ()
    deps: Tuple[str, ...] = ()
    campaigns: Tuple[str, ...] = ("paper",)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    quick_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    skip_quick: bool = False        # minutes-long cells: not run by --quick
    needs_results_dir: bool = False  # compute/derive reads dep envelopes
    version: int = 1                # bump on semantic change → cache bust
    checkpoint_every: int = 8       # partial-envelope flush cadence

    def __post_init__(self):
        if (self.specs is None) == (self.compute is None):
            raise ValueError(f"cell {self.name!r}: exactly one of specs / "
                             f"compute must be set")
        if self.specs is not None and self.derive is None:
            raise ValueError(f"cell {self.name!r}: spec cells need derive")

    def resolved_params(self, params: Optional[Dict[str, Any]] = None,
                        quick: bool = False) -> Dict[str, Any]:
        out = dict(self.params)
        if quick:
            out.update(self.quick_params)
        out.update(params or {})
        return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_CELLS: Dict[str, Cell] = {}


def register_cell(cell: Cell) -> Cell:
    if cell.name in _CELLS:
        raise ValueError(f"cell {cell.name!r} already registered")
    clash = [c.name for c in _CELLS.values() if c.result == cell.result]
    if clash:
        raise ValueError(f"cell {cell.name!r}: result file "
                         f"{cell.result!r} already owned by {clash[0]!r}")
    _CELLS[cell.name] = cell
    return cell


def _load_cells() -> None:
    import repro.experiments.cells  # noqa: F401  (registers on import)


def get_cell(name: str) -> Cell:
    _load_cells()
    if name not in _CELLS:
        raise KeyError(f"unknown cell {name!r}; registered: {cell_names()}")
    return _CELLS[name]


def cell_names() -> Tuple[str, ...]:
    _load_cells()
    return tuple(sorted(_CELLS))


def cell_for_result(stem: str) -> Optional[Cell]:
    """The cell owning results file ``<stem>.json``, or None."""
    _load_cells()
    for cell in _CELLS.values():
        if cell.result == stem:
            return cell
    return None


def cells_in(campaign: str) -> List[Cell]:
    """The campaign's cells in topological (dependency) order."""
    _load_cells()
    members = [c.name for c in _CELLS.values() if campaign in c.campaigns]
    if not members:
        raise KeyError(f"no cells registered in campaign {campaign!r}; "
                       f"known: {sorted({g for c in _CELLS.values() for g in c.campaigns})}")
    return [_CELLS[n] for n in resolve_order(members)]


def resolve_order(names: Sequence[str]) -> List[str]:
    """Topological order over ``names`` plus every transitive dep; raises
    on cycles.  Deterministic: dependency-first, then registration order."""
    _load_cells()
    order: List[str] = []
    state: Dict[str, int] = {}      # 0 visiting, 1 done

    def visit(n: str, chain: Tuple[str, ...]):
        if state.get(n) == 1:
            return
        if state.get(n) == 0:
            cyc = " -> ".join(chain + (n,))
            raise ValueError(f"cell dependency cycle: {cyc}")
        if n not in _CELLS:
            raise KeyError(f"unknown cell {n!r} (dep chain "
                           f"{' -> '.join(chain) or 'root'})")
        state[n] = 0
        for d in _CELLS[n].deps:
            visit(d, chain + (n,))
        state[n] = 1
        order.append(n)

    for n in names:
        visit(n, ())
    return order


# ---------------------------------------------------------------------------
# content addressing
# ---------------------------------------------------------------------------
_SPECS_MEMO: Dict[Tuple[str, str], List] = {}


def cell_specs(cell: Cell, params: Optional[Dict[str, Any]] = None,
               quick: bool = False) -> List:
    """Build (and memoize) the cell's spec list at resolved params.  Spec
    construction must be deterministic — some cells run a dry measure-mode
    schedule to size horizons, which is deterministic but not free, hence
    the memo."""
    if cell.specs is None:
        return []
    p = cell.resolved_params(params, quick=quick)
    key = (cell.name, json.dumps(content_hash(p)))
    if key not in _SPECS_MEMO:
        _SPECS_MEMO[key] = list(cell.specs(**p))
    return _SPECS_MEMO[key]


def cell_spec_hashes(cell: Cell, params: Optional[Dict[str, Any]] = None,
                     quick: bool = False) -> List[str]:
    return [spec_hash(s) for s in cell_specs(cell, params, quick=quick)]


def cell_hash(cell: Cell, params: Optional[Dict[str, Any]] = None,
              quick: bool = False) -> str:
    """The whole-cell content address (see module docstring).  Dep cells
    enter at their *default* params — the registry identity, not whatever
    a particular invocation ran them with."""
    p = cell.resolved_params(params, quick=quick)
    payload: Dict[str, Any] = {
        "cell": cell.name,
        "version": cell.version,
        "schema": SCHEMA_VERSION,
        "deps": {d: cell_hash(get_cell(d)) for d in cell.deps},
    }
    if cell.specs is not None:
        payload["specs"] = cell_spec_hashes(cell, params, quick=quick)
    else:
        payload["params"] = p
    return content_hash(payload)


# ---------------------------------------------------------------------------
# results files
# ---------------------------------------------------------------------------
def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def default_results_dir() -> str:
    return os.environ.get(
        "REPRO_RESULTS_DIR",
        os.path.join(repo_root(), "benchmarks", "results"))


def results_path(cell: Cell, results_dir: Optional[str] = None) -> str:
    return os.path.join(results_dir or default_results_dir(),
                        f"{cell.result}.json")


def load_envelope(name_or_cell, results_dir: Optional[str] = None
                  ) -> Optional[Dict[str, Any]]:
    """The cell's envelope as written, or None if absent/unreadable."""
    cell = (name_or_cell if isinstance(name_or_cell, Cell)
            else get_cell(name_or_cell))
    path = results_path(cell, results_dir)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
