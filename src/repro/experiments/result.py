"""RunResult: the stable record schema every experiment emits (DESIGN.md §5).

One :class:`RunResult` per executed :class:`~repro.experiments.spec
.ExperimentSpec`:

* ``spec``      — the config echo (RunConfig + experiment fields);
* ``metrics``   — final metric values (the problem's ``eval_fn`` keys);
* ``curve``     — eval history rows ``{"update", "time", **metrics}``;
* ``runtime``   — trace-derived runtime axis summary (simulated seconds of
  the last update, updates, minibatches actually committed — an elastic
  trace's cancelled pushes don't count — plus ``replay_path``: which
  execution path produced the record, "batched" | "sequential" |
  "legacy" | "measure", so the sweep fast-path cliff is visible in every
  results file);
* ``staleness`` — Fig.-4 statistics off the trace (⟨σ⟩, σ_max, P(σ > 2n),
  ring-buffer K, histogram, ⟨σ⟩-series head).

The JSON form is the *record*; ``params``/``trace`` ride along in memory
only (a record must stay diff-able and loadable without JAX).  Results
files under ``benchmarks/results/`` share one envelope —
``{"schema_version", "benchmark", "cell", "campaign", "records": [...],
"derived": {...}}`` — with every record validating against
:func:`validate_record` (``python -m repro.experiments.validate`` gates
this in CI).

Schema v2 (the campaign layer, DESIGN.md §15) adds content addressing:

* every record carries ``spec_hash`` — the canonical content address of
  its spec echo (``spec_hash.spec_hash_from_echo``), stamped on write;
* the envelope carries ``cell`` (the registered campaign cell that owns
  the file, or null for free-standing files) and ``campaign`` (the cell
  hash, resolved params, partial-write flag, claim outcomes).

v1 files (no hashes) still **load** — ``validate_record`` accepts both
versions — but the campaign layer reports them STALE;
``python -m repro.experiments.validate --migrate`` re-stamps them.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

import numpy as np

SCHEMA_VERSION = 2
SUPPORTED_SCHEMAS = (1, 2)          # v1 loads (legacy); v2 is current

RECORD_KEYS = ("schema_version", "spec", "metrics", "curve", "runtime",
               "staleness")
RECORD_KEYS_V2 = RECORD_KEYS + ("spec_hash",)
ENVELOPE_KEYS = ("schema_version", "benchmark", "records", "derived")
ENVELOPE_KEYS_V2 = ENVELOPE_KEYS + ("cell", "campaign")


def _jsonable(x):
    """numpy scalars/arrays → plain python (json.dump chokes on np types)."""
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return [_jsonable(v) for v in x.tolist()]
    if isinstance(x, (np.floating, np.integer, np.bool_)):
        return x.item()
    return x


@dataclasses.dataclass
class RunResult:
    """The result of one experiment run.  JSON-stable fields only in
    :meth:`record`; device-side outputs stay in-memory attributes."""

    spec: Dict[str, Any]
    metrics: Dict[str, float]
    curve: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    runtime: Dict[str, float] = dataclasses.field(default_factory=dict)
    staleness: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION
    spec_hash: str = ""             # content address; self-stamped on write
    # ---- in-memory only (never serialized) --------------------------------
    params: Any = dataclasses.field(default=None, repr=False, compare=False)
    trace: Any = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def tag(self) -> str:
        return self.spec.get("tag", "")

    def record(self) -> Dict[str, Any]:
        """The stable JSON record (config echo + results, no arrays)."""
        if not self.spec_hash:
            # lazy import: hashing needs repro.config (and with it jax);
            # merely loading records must not
            from repro.experiments.spec_hash import spec_hash_from_echo
            self.spec_hash = spec_hash_from_echo(self.spec)
        return _jsonable({
            "schema_version": self.schema_version,
            "spec": self.spec,
            "spec_hash": self.spec_hash,
            "metrics": self.metrics,
            "curve": self.curve,
            "runtime": self.runtime,
            "staleness": self.staleness,
        })

    def to_json(self, **kw) -> str:
        return json.dumps(self.record(), **kw)

    @classmethod
    def from_record(cls, d: Dict[str, Any]) -> "RunResult":
        validate_record(d)
        return cls(spec=d["spec"], metrics=d["metrics"], curve=d["curve"],
                   runtime=d["runtime"], staleness=d["staleness"],
                   schema_version=d["schema_version"],
                   spec_hash=d.get("spec_hash", ""))

    @classmethod
    def from_json(cls, s: str) -> "RunResult":
        return cls.from_record(json.loads(s))


# ---------------------------------------------------------------------------
# validation — the CI gate for benchmarks/results/*.json
# ---------------------------------------------------------------------------
def validate_record(d: Dict[str, Any], where: str = "record") -> None:
    """Raise ValueError unless ``d`` is a valid RunResult record."""
    if not isinstance(d, dict):
        raise ValueError(f"{where}: not an object")
    keys = RECORD_KEYS_V2 if d.get("schema_version") == 2 else RECORD_KEYS
    missing = [k for k in keys if k not in d]
    if missing:
        raise ValueError(f"{where}: missing keys {missing}")
    if d["schema_version"] not in SUPPORTED_SCHEMAS:
        raise ValueError(f"{where}: schema_version {d['schema_version']} "
                         f"not in {SUPPORTED_SCHEMAS}")
    for key, typ in (("spec", dict), ("metrics", dict), ("curve", list),
                     ("runtime", dict), ("staleness", dict)):
        if not isinstance(d[key], typ):
            raise ValueError(f"{where}: {key} must be {typ.__name__}")
    if d["schema_version"] == 2 and not (
            isinstance(d["spec_hash"], str) and d["spec_hash"]):
        raise ValueError(f"{where}: spec_hash must be a non-empty string")
    if "run" not in d["spec"]:
        raise ValueError(f"{where}: spec echo lacks the RunConfig ('run')")
    for i, row in enumerate(d["curve"]):
        if not isinstance(row, dict) or "update" not in row:
            raise ValueError(f"{where}: curve[{i}] lacks 'update'")


def envelope(benchmark: str, records=(),
             derived: Optional[Dict[str, Any]] = None,
             cell: Optional[str] = None,
             campaign: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The shared results-file shape: RunResult records + free-form derived
    values (claim booleans, speedup tables, timing comparisons).  ``cell``
    / ``campaign`` carry the content-address stamp when the file is owned
    by a registered campaign cell (null / {} for free-standing files)."""
    recs = [r.record() if isinstance(r, RunResult) else r for r in records]
    return _jsonable({"schema_version": SCHEMA_VERSION,
                      "benchmark": benchmark,
                      "cell": cell,
                      "campaign": campaign or {},
                      "records": recs,
                      "derived": derived or {}})


def validate_results_file(path: str) -> int:
    """Validate one results JSON against the envelope + record schema.
    Returns the number of records checked; raises ValueError on violation."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: not an object")
    keys = ENVELOPE_KEYS_V2 if data.get("schema_version") == 2 \
        else ENVELOPE_KEYS
    missing = [k for k in keys if k not in data]
    if missing:
        raise ValueError(f"{path}: missing envelope keys {missing}")
    if data["schema_version"] not in SUPPORTED_SCHEMAS:
        raise ValueError(f"{path}: schema_version {data['schema_version']}")
    if not isinstance(data["records"], list):
        raise ValueError(f"{path}: records must be a list")
    if not isinstance(data["derived"], dict):
        raise ValueError(f"{path}: derived must be an object")
    for i, rec in enumerate(data["records"]):
        validate_record(rec, where=f"{path}: records[{i}]")
    return len(data["records"])
