"""Cell ``table1`` — paper Table 1: communication overlap for Rudra-base /
-adv / -adv* in the adversarial scenario (μ = 4, 300 MB model, ~60
learners).  Paper: base 11.52 %, adv 56.75 %, adv* 99.56 %.

Pure analytic cell over the structural topology model.
"""

from __future__ import annotations

from repro.experiments.registry import Cell, Claim, emit, register_cell

_PAPER = {"base": 0.1152, "adv": 0.5675, "adv*": 0.9956}


def compute(**params):
    from repro.core import tradeoff as to

    wl = to.WorkloadModel(model_bytes=300e6)
    out = {}
    for arch in ("base", "adv", "adv*"):
        o = to.communication_overlap(arch, 4, 60, wl=wl)
        out[arch] = {"overlap": o, "paper": _PAPER[arch]}
        emit(f"table1/{arch}/overlap", f"{o:.4f}", f"paper:{_PAPER[arch]}")
    ordered = out["base"]["overlap"] < out["adv"]["overlap"] \
        < out["adv*"]["overlap"]
    emit("table1/ordering_base<adv<adv*", ordered, "")
    emit("table1/adv*_near_full_overlap", out["adv*"]["overlap"] > 0.95, "")
    return [], out


register_cell(Cell(
    name="table1", result="table1_overlap",
    title="Table 1: communication overlap per architecture",
    compute=compute,
    claims=(
        Claim("ordering_base_adv_advstar",
              lambda d: (d["base"]["overlap"] < d["adv"]["overlap"]
                         < d["adv*"]["overlap"])),
        Claim("adv_star_near_full_overlap",
              lambda d: d["adv*"]["overlap"] > 0.95),
    )))
