"""Cell ``elastic`` — elastic clusters on the calibrated Table-1 workload
(DESIGN.md §7): accuracy/runtime curves for (no churn | 10% crash-restart |
backup-b hardsync, b ∈ {0, 1, 4}), multi-seed.

Spec construction runs a dry measure-mode schedule to size the churn
window off the no-churn horizon — deterministic, so the spec-graph (and
its content hashes) are stable across sessions; the dry run is memoized
per epochs value because it costs a schedule pass.
"""

from __future__ import annotations

import numpy as np

from repro.config import RunConfig
from repro.experiments.registry import (Cell, derived_claims, emit,
                                        register_cell)
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep
from repro.membership import MembershipTimeline

LAM = 16
MU = 4
MODEL_MB = 300            # Table-1 adversarial model size
DURATION = f"calibrated:base:{MODEL_MB}mb"
SEEDS = (0, 1, 2)
BACKUPS = (0, 1, 4)
CRASH_FRACTION = 0.10     # 10% of λ crash-restarts
EVAL_EVERY = 32

_SCENARIOS = ("none", "crash_restart") + tuple(
    f"hardsync_b{b}" for b in BACKUPS)
_SETUP_MEMO = {}


def _steps(run_cfg: RunConfig, epochs: float) -> int:
    from repro.experiments.problems import get_problem, updates_for_epochs
    dataset = get_problem("mlp_teacher").dataset_size
    return updates_for_epochs(epochs, MU, run_cfg.gradients_per_update,
                              dataset, group_size=run_cfg.group_size)


def _crash_timeline(horizon: float) -> MembershipTimeline:
    n_crash = max(1, int(round(CRASH_FRACTION * LAM)))
    victims = range(n_crash)
    return MembershipTimeline.crash_restart(
        victims, crash_at=0.25 * horizon, restart_after=0.20 * horizon)


def _setup(epochs: float):
    if epochs not in _SETUP_MEMO:
        from repro.experiments.driver import run as run_spec
        soft = RunConfig(protocol="softsync", n_softsync=1, n_learners=LAM,
                         minibatch=MU, base_lr=0.05,
                         lr_policy="staleness_inverse", optimizer="momentum")
        soft_steps = _steps(soft, epochs)
        dry = run_spec(ExperimentSpec(run=soft, steps=soft_steps,
                                      duration=DURATION))
        churn = _crash_timeline(dry.runtime["simulated_time"])
        hard = RunConfig(protocol="hardsync", n_learners=LAM, minibatch=MU,
                         base_lr=0.05, lr_policy="sqrt_scale",
                         optimizer="momentum")
        hard_steps = _steps(hard, epochs)
        _SETUP_MEMO[epochs] = (soft, hard, soft_steps, hard_steps, churn)
    return _SETUP_MEMO[epochs]


def _spec(run_cfg: RunConfig, steps: int, tag: str) -> ExperimentSpec:
    return ExperimentSpec(run=run_cfg, problem="mlp_teacher", steps=steps,
                          duration=DURATION, eval_every=EVAL_EVERY, tag=tag)


def _sweeps(epochs: float):
    soft, hard, soft_steps, hard_steps, churn = _setup(epochs)
    return {
        "none": Sweep.over(_spec(soft, soft_steps, "none"), seed=SEEDS),
        "crash_restart": Sweep.over(
            _spec(soft.replace(membership=churn), soft_steps,
                  "crash_restart"), seed=SEEDS),
        **{f"hardsync_b{b}": Sweep.over(
            _spec(hard.replace(backup=b), hard_steps, f"hardsync_b{b}"),
            seed=SEEDS)
           for b in BACKUPS},
    }


def specs(epochs: float = 2.0):
    return [s for sweep in _sweeps(epochs).values() for s in sweep]


def _mean_std(rows):
    errs = [r.metrics["test_error"] for r in rows]
    times = [r.runtime["simulated_time"] for r in rows]
    return {"test_error_mean": float(np.mean(errs)),
            "test_error_std": float(np.std(errs)),
            "train_s_mean": float(np.mean(times)),
            "train_s_std": float(np.std(times)),
            "curve": rows[0].curve}


def derive(results, params):
    epochs = params["epochs"]
    _, _, soft_steps, hard_steps, churn = _setup(epochs)
    stats = {}
    for i, name in enumerate(_SCENARIOS):
        rows = results[i * len(SEEDS):(i + 1) * len(SEEDS)]
        stats[name] = _mean_std(rows)
        emit(f"elastic_churn/{name}",
             f"err={stats[name]['test_error_mean']:.4f}",
             f"train_s={stats[name]['train_s_mean']:.0f} "
             f"std={stats[name]['test_error_std']:.4f}")

    t = {b: stats[f"hardsync_b{b}"]["train_s_mean"] for b in BACKUPS}
    e = {b: stats[f"hardsync_b{b}"]["test_error_mean"] for b in BACKUPS}
    noise = 2.0 * max(stats["hardsync_b0"]["test_error_std"],
                      stats["hardsync_b1"]["test_error_std"],
                      stats["none"]["test_error_std"], 1e-3)
    claims = {
        "backup_runtime_strictly_decreasing":
            t[4] < t[1] < t[0],
        "backup1_buys_most_of_the_gap":
            (t[0] - t[1]) >= 0.35 * (t[0] - t[4]),
        "backup1_accuracy_within_noise":
            abs(e[1] - e[0]) <= noise,
        "crash_restart_converges":
            (stats["crash_restart"]["test_error_mean"]
             <= stats["none"]["test_error_mean"] + 0.05),
    }
    for k, v in claims.items():
        emit(f"elastic_churn/claims/{k}", v)

    return {
        "lambda": LAM, "mu": MU, "epochs": epochs, "model_mb": MODEL_MB,
        "seeds": list(SEEDS), "backups": list(BACKUPS),
        "updates": {"softsync": soft_steps, "hardsync": hard_steps},
        "churn_timeline": [{"t": ev.t, "learner": ev.learner,
                            "kind": ev.kind} for ev in churn.events],
        "scenarios": stats, "claims": claims,
        "noise_band": noise,
    }


register_cell(Cell(
    name="elastic", result="elastic_churn",
    title="Elastic churn + backup-hardsync curves",
    specs=specs, derive=derive,
    claims=derived_claims("backup_runtime_strictly_decreasing",
                          "backup1_buys_most_of_the_gap",
                          "backup1_accuracy_within_noise",
                          "crash_restart_converges"),
    params={"epochs": 2.0}, quick_params={"epochs": 0.5}))
