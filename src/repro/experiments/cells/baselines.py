"""Cell ``baselines`` — related-work baselines (paper §6) vs n-softsync,
plus the §3.3 accrual claim and a straggler ablation the paper's
homogeneous-cluster assumption hides.

Claims examined:
  * SSP with slack s hard-bounds staleness (≤ s + O(1)) but pays stalls;
    1-softsync achieves comparable error without blocking.
  * EASGD converges with unbounded replica drift (damped, not bounded).
  * Accrual (npush=k at mini-batch μ) ≈ mini-batch kμ — the paper's §3.3
    argument for why Rudra-adv* refuses to accrue.
  * Stragglers: a 10× slow learner inflates λ-softsync staleness and
    hardsync round time; 1-softsync degrades gracefully.

Uses the legacy ``simulate``/``simulate_*`` entry points directly — the
baseline protocols (SSP stalls, EASGD elastic pull, accrual) are not
trace-replayable, so this stays a compute cell rather than a spec-graph.
"""

from __future__ import annotations

from repro.experiments.registry import Cell, derived_claims, emit, \
    register_cell


def compute(epochs: int = 8, base_lr: float = 0.35):
    from repro.config import RunConfig
    from repro.core.baselines import (simulate_accrual, simulate_easgd,
                                      simulate_ssp)
    from repro.core.simulator import _default_duration_sampler, simulate
    from repro.experiments.problems import get_problem, updates_for_epochs

    prob = get_problem("mlp_teacher")
    lam, mu = 16, 16
    out = {}

    # ---- protocol comparison at matched sample budgets ---------------------
    budget_updates = updates_for_epochs(epochs, mu, 1, prob.task.n_train)

    soft = simulate(
        RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                  minibatch=mu, base_lr=base_lr,
                  lr_policy="staleness_inverse", optimizer="sgd", seed=21),
        steps=budget_updates // lam, grad_fn=prob.grad_fn,
        init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
    out["1-softsync"] = {"err": prob.test_error(soft.params),
                         "mean_sigma": soft.clock_log.mean_staleness()}

    for slack in (2, 8):
        ssp = simulate_ssp(
            RunConfig(protocol="async", n_learners=lam, minibatch=mu,
                      base_lr=base_lr, lr_policy="staleness_inverse",
                      optimizer="sgd", seed=21),
            steps=budget_updates, slack=slack, grad_fn=prob.grad_fn,
            init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
        vals = ssp.clock_log.all_staleness_values()
        out[f"ssp_slack={slack}"] = {
            "err": prob.test_error(ssp.params),
            "mean_sigma": ssp.clock_log.mean_staleness(),
            "max_sigma": float(vals.max()),
            "stalls": getattr(ssp, "stalls", 0)}
        emit(f"baselines/ssp_s={slack}/max_staleness", f"{vals.max():.0f}",
             f"bound~slack+lam; stalls={getattr(ssp, 'stalls', 0)}")

    # SSP only *pays* under heterogeneity: with a 10x straggler the fast
    # learners hit the slack wall and block (the stall count), which is the
    # cost 1-softsync never pays.
    def straggler10(rng, m):
        base = _default_duration_sampler(rng, m)
        return base * (10.0 if rng.integers(0, lam) == 0 else 1.0)
    ssp_slow = simulate_ssp(
        RunConfig(protocol="async", n_learners=lam, minibatch=mu,
                  base_lr=base_lr, lr_policy="staleness_inverse",
                  optimizer="sgd", seed=21),
        steps=budget_updates // 2, slack=2, grad_fn=prob.grad_fn,
        init_params=prob.init, batch_fn=prob.batch_fn_for(mu),
        duration_sampler=straggler10)
    out["ssp_straggler"] = {"stalls": getattr(ssp_slow, "stalls", 0),
                            "time": ssp_slow.simulated_time}
    emit("baselines/ssp_stalls_under_straggler",
         getattr(ssp_slow, "stalls", 0) > 0,
         f"stalls={getattr(ssp_slow, 'stalls', 0)} (softsync never blocks)")

    eas = simulate_easgd(
        RunConfig(protocol="async", n_learners=lam, minibatch=mu,
                  base_lr=base_lr / 4, optimizer="sgd", seed=21),
        steps=budget_updates, rho=0.2, grad_fn=prob.grad_fn,
        init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
    out["easgd"] = {"err": prob.test_error(eas.params)}

    emit("baselines/1-softsync/err", f"{out['1-softsync']['err']:.4f}", "")
    emit("baselines/ssp_s=2/err", f"{out['ssp_slack=2']['err']:.4f}", "")
    emit("baselines/easgd/err", f"{out['easgd']['err']:.4f}", "")
    competitive = (out["1-softsync"]["err"]
                   <= min(out["ssp_slack=2"]["err"],
                          out["easgd"]["err"]) + 0.03)
    emit("baselines/softsync_competitive", competitive,
         "within 3pts of the best related-work baseline")

    # ---- accrual ≈ bigger μ (§3.3) -----------------------------------------
    k = 4
    acc = simulate_accrual(
        RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                  minibatch=mu, base_lr=base_lr,
                  lr_policy="staleness_inverse", optimizer="sgd", seed=23),
        steps=updates_for_epochs(epochs, mu * k, lam, prob.task.n_train),
        npush=k, grad_fn=prob.grad_fn, init_params=prob.init,
        batch_fn=prob.batch_fn_for(mu))
    bigmu = simulate(
        RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                  minibatch=mu * k, base_lr=base_lr,
                  lr_policy="staleness_inverse", optimizer="sgd", seed=23),
        steps=updates_for_epochs(epochs, mu * k, lam, prob.task.n_train),
        grad_fn=prob.grad_fn, init_params=prob.init,
        batch_fn=prob.batch_fn_for(mu * k))
    e_acc, e_big = prob.test_error(acc.params), prob.test_error(bigmu.params)
    out["accrual_k4"] = {"err": e_acc}
    out["mu_x4"] = {"err": e_big}
    emit("baselines/accrual_equals_bigger_mu", abs(e_acc - e_big) < 0.05,
         f"npush=4@mu16:{e_acc:.4f} vs mu64:{e_big:.4f} (paper §3.3)")

    # ---- straggler ablation -------------------------------------------------
    def straggler_sampler(rng, m):
        base = _default_duration_sampler(rng, m)
        return base * (10.0 if rng.integers(0, lam) == 0 else 1.0)

    meas_uniform = simulate(
        RunConfig(protocol="softsync", n_softsync=lam, n_learners=lam,
                  minibatch=mu, seed=29), steps=1500)
    meas_straggle = simulate(
        RunConfig(protocol="softsync", n_softsync=lam, n_learners=lam,
                  minibatch=mu, seed=29), steps=1500,
        duration_sampler=straggler_sampler)
    s_u = meas_uniform.clock_log.all_staleness_values().max()
    s_s = meas_straggle.clock_log.all_staleness_values().max()
    out["straggler"] = {"max_sigma_uniform": float(s_u),
                        "max_sigma_straggler": float(s_s)}
    emit("baselines/straggler_inflates_max_staleness", bool(s_s > s_u),
         f"{s_u:.0f} -> {s_s:.0f} (heterogeneity breaks the 2n bound)")

    out["claims"] = {
        "softsync_competitive": bool(competitive),
        "ssp_stalls_under_straggler": getattr(ssp_slow, "stalls", 0) > 0,
        "accrual_equals_bigger_mu": bool(abs(e_acc - e_big) < 0.05),
        "straggler_inflates_max_staleness": bool(s_s > s_u),
    }
    return [], out


register_cell(Cell(
    name="baselines", result="baselines",
    title="Related-work baselines: SSP / EASGD / accrual / stragglers",
    compute=compute,
    claims=derived_claims("softsync_competitive",
                          "ssp_stalls_under_straggler",
                          "accrual_equals_bigger_mu",
                          "straggler_inflates_max_staleness"),
    params={"epochs": 8, "base_lr": 0.35}, quick_params={"epochs": 3}))
