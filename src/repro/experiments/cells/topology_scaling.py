"""Cell ``topology`` — Rudra-base vs adv vs adv* runtime-vs-learners curves
(paper §3.2/3.3, Table 1 / Fig. 8 story) on the topology-aware simulator.

Measure-mode spec-graph: for each architecture and λ a fixed two-epoch
workload in the paper's adversarial communication scenario (μ = 4, 300 MB
model) runs through the calibrated per-minibatch cost model with the
matching structural topology; ``simulated_time`` of the last update is the
training-time axis.  ``derive`` also times the sharded+grouped replay
against the trivial replay (``engine_overhead_cell`` — a wall-clock
measurement, re-timed on every execution, not derivable from records).
"""

from __future__ import annotations

from repro.config import RunConfig
from repro.core.topology import RUDRA_ARCHS, Topology
from repro.experiments.registry import (Cell, derived_claims, emit,
                                        register_cell)
from repro.experiments.spec import ExperimentSpec

LAMBDAS = (4, 16, 32, 60)
MU = 4
DATASET = 50_000          # the paper's CIFAR epoch (tradeoff.WorkloadModel)
MODEL_MB = 300            # Table-1 adversarial model size
PULL_JITTER = 0.02


def _spec_for(arch: str, lam: int, epochs: float) -> ExperimentSpec:
    from repro.experiments.problems import updates_for_epochs
    topo = Topology.for_arch(arch, lam,
                             jitter=PULL_JITTER if arch == "adv*" else 0.0)
    run = RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                    minibatch=MU, shards=topo.shards, groups=topo.groups,
                    shard_pull_jitter=topo.pull_jitter, seed=29)
    steps = updates_for_epochs(epochs, MU, run.gradients_per_update,
                               DATASET, group_size=run.group_size)
    return ExperimentSpec(run=run, steps=steps,
                          duration=f"calibrated:{arch}:{MODEL_MB}mb",
                          tag=f"{arch}/lambda={lam}")


def specs(epochs: float = 2.0):
    return [_spec_for(arch, lam, epochs)
            for arch in RUDRA_ARCHS for lam in LAMBDAS]


def _engine_overhead_cell(updates: int = 40) -> dict:
    """Wall-clock of the sharded+grouped replay vs the trivial replay on
    the same step count (mlp_teacher, tiny shape)."""
    import time

    import jax.numpy as jnp

    from repro.experiments.driver import run as run_spec

    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=8,
                      minibatch=4, base_lr=0.05,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=17),
        problem="mlp_teacher", steps=updates)
    star = base.replace(run=base.run.replace(shards=4,
                                             shard_pull_jitter=0.1))

    def _time(spec):
        run_spec(spec)                               # compile
        t0 = time.perf_counter()
        res = run_spec(spec)
        jnp.asarray(res.params["w1"]).block_until_ready()
        return time.perf_counter() - t0

    t_base, t_star = _time(base), _time(star)
    return {"updates": updates, "trivial_s": t_base, "topology_s": t_star,
            "overhead_x": t_star / t_base}


def derive(results, params):
    curves = {arch: {} for arch in RUDRA_ARCHS}
    it = iter(results)
    for arch in RUDRA_ARCHS:
        for lam in LAMBDAS:
            res = next(it)
            seconds = res.runtime["simulated_time"]
            curves[arch][lam] = seconds
            emit(f"topology_scaling/{arch}/lambda={lam}/train_s",
                 f"{seconds:.0f}",
                 f"updates={res.runtime['updates']} "
                 f"<sigma>={res.staleness['mean']:.2f}")
    speedup_vs_base = {
        arch: {lam: curves["base"][lam] / curves[arch][lam]
               for lam in LAMBDAS}
        for arch in RUDRA_ARCHS}
    lam0, lam1 = LAMBDAS[0], LAMBDAS[-1]
    claims = {
        "adv_faster_than_base_at_scale":
            curves["adv"][lam1] < curves["base"][lam1],
        "adv_star_fastest_at_scale":
            curves["adv*"][lam1] <= curves["adv"][lam1],
        "base_scaling_saturates":
            curves["base"][lam0] / curves["base"][lam1] < 0.7 * lam1 / lam0,
    }
    overhead = _engine_overhead_cell()
    emit("topology_scaling/engine_overhead",
         f"{overhead['overhead_x']:.2f}x",
         f"trivial={overhead['trivial_s']:.3f}s "
         f"topology={overhead['topology_s']:.3f}s")
    return {"lambdas": list(LAMBDAS), "mu": MU, "epochs": params["epochs"],
            "train_seconds": curves, "speedup_vs_base": speedup_vs_base,
            "claims": claims, "engine_overhead_cell": overhead}


register_cell(Cell(
    name="topology", result="topology_scaling",
    title="Rudra base/adv/adv* runtime-vs-learners curves",
    specs=specs, derive=derive,
    claims=derived_claims("adv_faster_than_base_at_scale",
                          "adv_star_fastest_at_scale",
                          "base_scaling_saturates"),
    params={"epochs": 2.0}, quick_params={"epochs": 0.5}))
