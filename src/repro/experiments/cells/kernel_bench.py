"""Cell ``kernels`` — kernel-level benchmark: wall-clock of the XLA fallback
paths on CPU (chunked vs naive attention, chunked vs recurrent SSD/WKV) and
the fused ps_update's analytic HBM-traffic saving — the quantity the TPU
kernel buys.

Timings are real (CPU) and so non-deterministic: the cell re-times on every
execution; only the correctness booleans and the analytic traffic model are
claim-checked.  ``skip_quick`` because the timings are already tiny.
"""

from __future__ import annotations

import time

from repro.experiments.registry import Cell, Claim, emit, register_cell


def _time(fn, *args, reps: int = 5) -> float:
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6   # µs


def compute(**params):
    import jax
    import jax.numpy as jnp

    out = {}
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # --- attention: naive vs chunked (memory-bound difference) -------------
    from repro.models.attention import chunked_attention, naive_attention
    B, S, H, KV, D = 1, 1024, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    t_naive = _time(jax.jit(lambda q, k, v: naive_attention(
        q, k, v, causal=True)), q, k, v)
    t_chunk = _time(jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, q_chunk=256, kv_chunk=256)), q, k, v)
    out["attention"] = {"naive_us": t_naive, "chunked_us": t_chunk}
    emit("kernel/attention_naive", f"{t_naive:.0f}us", f"S={S}")
    emit("kernel/attention_chunked", f"{t_chunk:.0f}us",
         "peak-mem O(S*chunk) vs O(S^2)")

    # --- ssd: chunked vs recurrent ------------------------------------------
    from repro.kernels.ref import ssm_ref
    from repro.models.ssm import ssd_chunked
    Bt, Ss, Hs, P, N = 2, 2048, 4, 32, 32
    x = jax.random.normal(ks[3], (Bt, Ss, Hs, P)) * 0.3
    a = -jnp.abs(jax.random.normal(ks[4], (Bt, Ss, Hs))) * 0.1
    Bm = jax.random.normal(ks[5], (Bt, Ss, N)) * 0.3
    Cm = jax.random.normal(ks[6], (Bt, Ss, N)) * 0.3
    t_rec = _time(jax.jit(lambda *t: ssm_ref(*t)[0]), x, a, Bm, Cm)
    t_chk = _time(jax.jit(lambda *t: ssd_chunked(*t, chunk=128)[0]),
                  x, a, Bm, Cm)
    out["ssd"] = {"recurrent_us": t_rec, "chunked_us": t_chk,
                  "speedup": t_rec / t_chk}
    emit("kernel/ssd_recurrent", f"{t_rec:.0f}us", f"S={Ss}")
    emit("kernel/ssd_chunked", f"{t_chk:.0f}us",
         f"speedup={t_rec/t_chk:.1f}x")

    # --- ps_update fused traffic model --------------------------------------
    # Unfused PS applyUpdate: read W, read V, read each of c grads, write
    # partial sums (c-1 round trips), write V, write W
    #   = (2c + 3) * model_bytes   (sum materialized between each add)
    # Fused kernel: read W, V, c grads once; write W, V once
    #   = (c + 4) * model_bytes
    for c in (2, 4, 8, 15, 30):
        unfused = 2 * c + 3
        fused = c + 4
        out[f"ps_update_c={c}"] = {"unfused_passes": unfused,
                                   "fused_passes": fused,
                                   "traffic_reduction": unfused / fused}
        emit(f"kernel/ps_update_c={c}/traffic_reduction",
             f"{unfused/fused:.2f}x",
             f"{unfused}->{fused} model-size HBM passes")

    # interpret-mode correctness timing (not perf — CPU emulation)
    from repro.kernels import ops, ref as kref
    Dp = 1 << 16
    w = jax.random.normal(ks[7], (Dp,))
    vv = jnp.zeros((Dp,))
    g = jax.random.normal(ks[0], (4, Dp))
    coef = jnp.array([1.0, 0.5, 0.33, 0.25])
    w2, v2 = ops.ps_update(w, vv, g, coef, momentum=0.9, lr=0.1)
    w2r, v2r = kref.ps_update_ref(w, vv, g, coef, momentum=0.9, lr=0.1)
    ok = bool(jnp.allclose(w2, w2r, atol=1e-5))
    emit("kernel/ps_update_interpret_allclose", ok, "")
    out["ps_update_allclose"] = ok

    # --- ps_update fused vs unfused: TIMED (CPU; interpret-mode proxy) -----
    # unfused = the seed's semantics: materialize each partial sum of the
    # staleness-weighted reduction, then the optimizer step (2c+3 model-size
    # passes).  fused = one repro.optim pallas dispatch over the same flat
    # buffer.  On TPU the gap is the HBM-traffic model above; the CPU timing
    # recorded here only demonstrates both paths are real and equivalent.
    from repro.optim import UpdateSpec
    Db, cb = 1 << 18, 8
    wb = jax.random.normal(ks[1], (Db,))
    vb = jnp.zeros((Db,))
    gb = jax.random.normal(ks[2], (cb, Db)) * 0.1
    coefb = jnp.abs(jax.random.normal(ks[3], (cb,))) + 0.1
    lrsb = jnp.full((cb,), 0.05)
    spec = UpdateSpec(optimizer="momentum")

    @jax.jit
    def unfused(w, v, g, coef):
        acc = jnp.zeros_like(w)
        for i in range(cb):                  # c materialized partial sums
            acc = acc + coef[i] * g[i]
        v = spec.momentum * v + acc
        return w - 0.05 * v, v

    @jax.jit
    def fused(w, v, g, coef, lrs):
        from repro.kernels import ps_update as _psu
        return _psu.ps_apply(w, v, g, coef, lrs, spec=spec, mode="combine",
                             interpret=jax.default_backend() != "tpu")

    wu, vu = unfused(wb, vb, gb, coefb)
    wf, vf = fused(wb, vb, gb, coefb, lrsb)
    match = bool(jnp.allclose(wu, wf, atol=1e-5)
                 and jnp.allclose(vu, vf, atol=1e-5))
    t_unfused = _time(unfused, wb, vb, gb, coefb)
    t_fused = _time(fused, wb, vb, gb, coefb, lrsb)
    out["ps_update_timed"] = {
        "D": Db, "c": cb, "unfused_us": t_unfused, "fused_us": t_fused,
        "cpu_ratio": t_unfused / t_fused, "allclose": match,
        "note": "CPU wall-clock; TPU benefit is the HBM traffic model above"}
    emit("kernel/ps_update_unfused", f"{t_unfused:.0f}us",
         f"D=2^18 c={cb} multi-pass")
    emit("kernel/ps_update_fused", f"{t_fused:.0f}us",
         f"single pallas dispatch, allclose={match}")

    # --- replay megakernel: ring event vs stock chain (DESIGN.md §12) ------
    # One fused ring-read -> combine -> optimizer update -> ring-write event
    # (kernels/replay_ring, interpret mode on CPU) vs the stock XLA chain
    # the replay scan used before: gather row, apply_event_flat, .at[].set.
    # Also times the bf16 compressed ring with its error-feedback residue
    # (half the ring HBM traffic; the fp32 master chain stays exact).
    from repro.kernels import replay_ring
    from repro.optim import apply_event_flat
    spec_mk = UpdateSpec(optimizer="momentum")
    Kr, cr = 8, 8
    Dr = replay_ring.padded_width(1 << 18)
    ring0 = jax.random.normal(ks[4], (Kr, Dr), jnp.float32)
    s_mk = jnp.zeros((Dr,))
    g_mk = jax.random.normal(ks[5], (cr, Dr)) * 0.1
    coef_mk = jnp.full((cr,), 1.0 / cr)
    lrs_mk = jnp.full((cr,), 0.05)
    idx_mk = jnp.array([2, 3], jnp.int32)

    @jax.jit
    def stock_event(ring, s):
        w, s2 = apply_event_flat(spec_mk, ring[2], s, g_mk, coef_mk, lrs_mk,
                                 "combine")
        return ring.at[3].set(w), s2

    @jax.jit
    def mega_event(ring, s):
        ring2, s2, _ = replay_ring.ring_apply(
            ring, s, None, g_mk, coef_mk, lrs_mk, idx_mk,
            spec=spec_mk, mode="combine")
        return ring2, s2

    rs_, ss_ = stock_event(ring0, s_mk)
    rm_, sm_ = mega_event(ring0, s_mk)
    mk_bitwise = bool((rs_ == rm_).all() and (ss_ == sm_).all())
    t_stock = _time(stock_event, ring0, s_mk)
    t_mega = _time(mega_event, ring0, s_mk)

    ring_bf = ring0.astype(jnp.bfloat16)
    res0 = (ring0[2] - ring_bf[2].astype(jnp.float32))

    @jax.jit
    def mega_event_bf16(ring, s, res):
        return replay_ring.ring_apply(
            ring, s, res, g_mk, coef_mk, lrs_mk, idx_mk,
            spec=spec_mk, mode="combine")
    rb_, sb_, resb_ = mega_event_bf16(ring_bf, s_mk, res0)
    # master chain: bf16 row + residue reconstructs the exact fp32 update
    master = rb_[3].astype(jnp.float32) + resb_
    bf16_exact = bool((master == rs_[3]).all())
    t_bf16 = _time(mega_event_bf16, ring_bf, s_mk, res0)

    from repro.launch.roofline import ring_bytes as _ring_bytes
    out["replay_megakernel"] = {
        "D": Dr, "K": Kr, "c": cr,
        "stock_us": t_stock, "megakernel_us": t_mega, "bf16_us": t_bf16,
        "fp32_bitwise": mk_bitwise, "bf16_master_exact": bf16_exact,
        "ring_bytes_fp32": _ring_bytes(Kr, Dr, "fp32",
                                       "momentum")["total_bytes"],
        "ring_bytes_bf16": _ring_bytes(Kr, Dr, "bf16",
                                       "momentum")["total_bytes"],
        "note": "CPU interpret-mode wall clock; the TPU win is one kernel "
                "launch + K*D ring traffic halved at bf16"}
    emit("kernel/replay_megakernel_fp32", f"{t_mega:.0f}us",
         f"stock={t_stock:.0f}us bitwise={mk_bitwise} D=2^18 c={cr} K={Kr}")
    emit("kernel/replay_megakernel_bf16", f"{t_bf16:.0f}us",
         f"master_exact={bf16_exact} ring_bytes "
         f"{out['replay_megakernel']['ring_bytes_fp32']}"
         f"->{out['replay_megakernel']['ring_bytes_bf16']}")
    return [], out


register_cell(Cell(
    name="kernels", result="kernel_bench",
    title="Kernel bench: attention/SSD fallbacks + fused ps_update",
    compute=compute, skip_quick=True,
    claims=(
        Claim("ps_update_interpret_allclose",
              lambda d: d["ps_update_allclose"]),
        Claim("ps_update_fused_allclose",
              lambda d: d["ps_update_timed"]["allclose"]),
        Claim("megakernel_fp32_bitwise",
              lambda d: d["replay_megakernel"]["fp32_bitwise"]),
        Claim("megakernel_bf16_master_exact",
              lambda d: d["replay_megakernel"]["bf16_master_exact"]),
        Claim("fused_traffic_model_monotone",
              lambda d: (d["ps_update_c=30"]["traffic_reduction"]
                         > d["ps_update_c=2"]["traffic_reduction"])),
    )))
