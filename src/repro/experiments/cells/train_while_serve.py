"""Cell ``serve`` — train-while-serve on the calibrated Table-1 workload
(DESIGN.md §14): serving accuracy × staleness budget × tail latency, under
replica churn.

Spec construction runs a dry measure-mode schedule to size the fleet's
traffic and churn window off the training horizon — deterministic and
memoized per (epochs, requests).  The separate :func:`measure` cell feeds
the ``serving_requests_per_s`` CI floor in the ``bench_guard`` cell.
"""

from __future__ import annotations

import numpy as np

from repro.config import RunConfig
from repro.experiments.registry import (Cell, derived_claims, emit,
                                        register_cell)
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep
from repro.serve.fleet import FleetConfig
from repro.serve.publication import PublicationPolicy

LAM = 16
MU = 4
MODEL_MB = 300            # Table-1 adversarial model size
DURATION = f"calibrated:base:{MODEL_MB}mb"
SEEDS = (0, 1, 2)
BUDGETS = (1, 4, 16, 64)
REQUEST_SAMPLES = 32

_SCENARIOS = tuple(f"budget{b}" for b in BUDGETS) + ("on_demand",
                                                     "budget4_churn")
_SETUP_MEMO = {}


def _steps(run_cfg: RunConfig, epochs: float) -> int:
    from repro.experiments.problems import get_problem, updates_for_epochs
    dataset = get_problem("mlp_teacher").dataset_size
    return updates_for_epochs(epochs, MU, run_cfg.gradients_per_update,
                              dataset, group_size=run_cfg.group_size)


def _fleet(horizon: float, requests: int, policy: PublicationPolicy,
           membership=()) -> FleetConfig:
    """Fleet sized to the calibrated horizon: traffic covers the whole run,
    a publication blocks ~H/640, service times keep the queue subcritical
    so p99 reflects publication stalls, not saturation."""
    return FleetConfig(replicas=2, policy=policy,
                       request_rate=requests / horizon,
                       request_samples=REQUEST_SAMPLES,
                       publish_cost_s=horizon / 640.0,
                       service_base_s=2.5e-4 * horizon,
                       service_per_sample_s=1e-6 * horizon,
                       membership=membership)


def _setup(epochs: float, requests: int):
    key = (epochs, requests)
    if key not in _SETUP_MEMO:
        from repro.experiments.driver import run as run_spec
        soft = RunConfig(protocol="softsync", n_softsync=1, n_learners=LAM,
                         minibatch=MU, base_lr=0.05,
                         lr_policy="staleness_inverse", optimizer="momentum")
        steps = _steps(soft, epochs)
        dry = run_spec(ExperimentSpec(run=soft, steps=steps,
                                      duration=DURATION))
        _SETUP_MEMO[key] = (soft, steps, dry.runtime["simulated_time"])
    return _SETUP_MEMO[key]


def _scenarios(epochs: float, requests: int):
    soft, steps, horizon = _setup(epochs, requests)

    def spec(fleet: FleetConfig, tag: str) -> ExperimentSpec:
        return ExperimentSpec(run=soft.replace(serving=fleet),
                              problem="mlp_teacher", steps=steps,
                              duration=DURATION, tag=tag)

    churn = ((0.30 * horizon, 1, "crash"), (0.55 * horizon, 1, "join"))
    return {
        **{f"budget{b}": spec(_fleet(horizon, requests,
                                     PublicationPolicy(max_version_lag=b)),
                              f"budget{b}")
           for b in BUDGETS},
        "on_demand": spec(_fleet(horizon, requests,
                                 PublicationPolicy(kind="on_demand")),
                          "on_demand"),
        "budget4_churn": spec(_fleet(horizon, requests,
                                     PublicationPolicy(max_version_lag=4),
                                     membership=churn),
                              "budget4_churn"),
    }


def specs(epochs: float = 2.0, requests: int = 1024):
    return [s for sp in _scenarios(epochs, requests).values()
            for s in Sweep.over(sp, seed=SEEDS)]


def _stats(rows) -> dict:
    acc = [r.metrics["serving_accuracy"] for r in rows]
    errs = [r.metrics["test_error"] for r in rows]
    summaries = [r.runtime["serving"] for r in rows]
    return {
        "serving_accuracy_mean": float(np.mean(acc)),
        "serving_accuracy_std": float(np.std(acc)),
        "test_errors": [float(e) for e in errs],
        "staleness_mean": float(np.mean(
            [s["staleness_mean"] for s in summaries])),
        "staleness_max": int(max(s["staleness_max"] for s in summaries)),
        "latency_p50_s": float(np.mean(
            [s["latency_p50_s"] for s in summaries])),
        "latency_p99_s": float(np.mean(
            [s["latency_p99_s"] for s in summaries])),
        "refreshes_mean": float(np.mean(
            [s["n_refreshes"] for s in summaries])),
        "n_dropped": int(sum(s["n_dropped"] for s in summaries)),
    }


def derive(results, params):
    epochs, requests = params["epochs"], params["requests"]
    _, steps, horizon = _setup(epochs, requests)
    stats = {}
    for i, name in enumerate(_SCENARIOS):
        rows = results[i * len(SEEDS):(i + 1) * len(SEEDS)]
        stats[name] = _stats(rows)
        emit(f"train_while_serve/{name}",
             f"acc={stats[name]['serving_accuracy_mean']:.4f}",
             f"stale={stats[name]['staleness_mean']:.1f} "
             f"p99={stats[name]['latency_p99_s']:.2f}s "
             f"refreshes={stats[name]['refreshes_mean']:.0f}")

    acc = {b: stats[f"budget{b}"]["serving_accuracy_mean"] for b in BUDGETS}
    p99 = {b: stats[f"budget{b}"]["latency_p99_s"] for b in BUDGETS}
    ref = {b: stats[f"budget{b}"]["refreshes_mean"] for b in BUDGETS}
    noise = max(max(stats[f"budget{b}"]["serving_accuracy_std"]
                    for b in BUDGETS), 1e-3)
    pairs = list(zip(BUDGETS, BUDGETS[1:]))
    claims = {
        "accuracy_monotone_in_budget":
            all(acc[a] >= acc[b] - noise for a, b in pairs)
            and acc[BUDGETS[0]] > acc[BUDGETS[-1]] + noise,
        "refreshes_strictly_decreasing":
            all(ref[a] > ref[b] for a, b in pairs),
        "fresh_serving_pays_latency":
            p99[BUDGETS[0]] > p99[BUDGETS[-1]],
        "on_demand_is_freshest":
            stats["on_demand"]["staleness_mean"] == 0.0
            and (stats["on_demand"]["serving_accuracy_mean"]
                 >= acc[BUDGETS[0]] - noise),
        "budget_holds_under_churn":
            stats["budget4_churn"]["staleness_max"] <= 4
            and stats["budget4_churn"]["n_dropped"] == 0,
        "training_unperturbed_by_serving":
            all(s["test_errors"] == stats["budget1"]["test_errors"]
                for s in stats.values()),
    }
    for k, v in claims.items():
        emit(f"train_while_serve/claims/{k}", v)

    return {
        "lambda": LAM, "mu": MU, "epochs": epochs, "model_mb": MODEL_MB,
        "seeds": list(SEEDS), "budgets": list(BUDGETS),
        "updates": steps, "horizon_s": horizon, "requests": requests,
        "scenarios": stats, "claims": claims, "noise_band": noise,
    }


def measure(updates: int = 48, requests: int = 1024,
            repeats: int = 3) -> dict:
    """The bench-guard cell: wall-clock throughput of the serving lane
    (snapshot capture in the scan + the chunked vmapped request
    evaluation), requests sized to dominate the tiny training replay.
    Absolute, so the CI floor carries a wide margin."""
    import time

    from repro.core.engine import replay
    from repro.core.trace import schedule
    from repro.experiments.problems import get_problem

    prob = get_problem("mlp_teacher")
    base = RunConfig(protocol="softsync", n_softsync=1, n_learners=16,
                     minibatch=4, base_lr=0.05,
                     lr_policy="staleness_inverse", optimizer="momentum",
                     seed=17)
    horizon = schedule(base, updates).simulated_time
    cfg = base.replace(serving=FleetConfig(
        replicas=2, policy=PublicationPolicy(max_version_lag=4),
        request_rate=requests / horizon, request_samples=32))
    trace = schedule(cfg, updates)
    batches = prob.stage_requests(trace.serving, cfg.serving, seed=cfg.seed)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        sim = replay(trace, cfg, grad_fn=prob.grad_fn,
                     init_params=prob.init,
                     batch_fn=prob.batch_fn_for(cfg.minibatch),
                     serve_batches=batches,
                     serve_eval_fn=prob.request_metric)
        assert sim.serving.request_metric.shape[0] == trace.serving.n_requests
        best = min(best, time.perf_counter() - t0)
    n = trace.serving.n_requests
    return {"updates": updates, "requests": n, "seconds": best,
            "requests_per_s": n / best}


register_cell(Cell(
    name="serve", result="train_while_serve",
    title="Train-while-serve: staleness-budget serving fleet",
    specs=specs, derive=derive,
    claims=derived_claims("accuracy_monotone_in_budget",
                          "refreshes_strictly_decreasing",
                          "fresh_serving_pays_latency",
                          "on_demand_is_freshest",
                          "budget_holds_under_churn",
                          "training_unperturbed_by_serving"),
    params={"epochs": 2.0, "requests": 1024},
    quick_params={"epochs": 0.5, "requests": 256}))
