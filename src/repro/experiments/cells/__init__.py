"""Campaign cells: one module per paper table/figure (DESIGN.md §15).

Importing this package registers every cell with
``repro.experiments.registry``; the campaign CLI
(``python -m repro.experiments.campaign``) resolves them into a DAG.
The deprecated ``benchmarks/*.py`` entry points are thin shims over
these modules.
"""

from repro.experiments.cells import (baselines, bench_guard,  # noqa: F401
                                     cnn_fig5, distributed_replay,
                                     elastic_churn, fig4_staleness,
                                     fig5_lr_modulation, fig6_7_tradeoff,
                                     fig8_speedup, kernel_bench,
                                     ring_feasibility, sim_engine_bench,
                                     smoke_cells, table1_overlap,
                                     table2_mu_lambda, table3_4_summary,
                                     topology_scaling, train_while_serve)
