"""Cell ``table2`` — paper Table 2 / §5.3: μλ = constant ⇒ ≈ constant test
error, largely independent of staleness σ; error grows monotonically with
the μλ product.  Configurations mirror the paper's table scaled to the
teacher task (groups μλ ≈ {128, 512, 4096} with σ ∈ {1, λ}).
"""

from __future__ import annotations

import numpy as np

from repro.config import RunConfig
from repro.experiments.registry import Cell, Claim, emit, register_cell
from repro.experiments.spec import ExperimentSpec

_GROUPS = {
    128: [(1, 4, 32), (32, 4, 32), (8, 16, 8), (1, 128, 1)],
    512: [(1, 16, 32), (32, 16, 32), (8, 64, 8), (1, 128, 4)],
    4096: [(1, 128, 32), (32, 128, 32), (8, 256, 16)],
}


def _slots():
    return [(prod, n, mu, lam)
            for prod, cfgs in _GROUPS.items() for (n, mu, lam) in cfgs]


def specs(epochs: int = 10, base_lr: float = 0.35):
    out = []
    for prod, n, mu, lam in _slots():
        out.append(ExperimentSpec(
            run=RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                          minibatch=mu, base_lr=base_lr,
                          lr_policy="staleness_inverse", optimizer="sgd",
                          seed=9),
            problem="mlp_teacher", epochs=epochs,
            tag=f"prod={prod}/n={n}/mu={mu}/lam={lam}"))
    return out


def derive(results, params):
    out = {}
    errs_by_prod = {prod: [] for prod in _GROUPS}
    for (prod, n, mu, lam), res in zip(_slots(), results):
        err, sig = res.metrics["test_error"], res.staleness["mean"]
        out[res.tag] = {"test_error": err, "measured_staleness": sig}
        errs_by_prod[prod].append(err)
        emit(f"table2/prod={prod}/sigma={n}/mu={mu}/lam={lam}",
             f"{err:.4f}", f"<sigma>={sig:.1f}")
    for prod, errs in errs_by_prod.items():
        spread = float(np.max(errs) - np.min(errs))
        out[f"prod={prod}/spread"] = spread
        emit(f"table2/prod={prod}/error_spread", f"{spread:.4f}",
             "claim:small-within-group")
    out["mean_error_by_prod"] = {str(prod): float(np.mean(errs))
                                 for prod, errs in errs_by_prod.items()}
    mean_small = out["mean_error_by_prod"]["128"]
    mean_big = out["mean_error_by_prod"]["4096"]
    emit("table2/error_grows_with_product", mean_big > mean_small,
         f"128:{mean_small:.3f} 4096:{mean_big:.3f}")
    return out


register_cell(Cell(
    name="table2", result="table2_mu_lambda",
    title="Table 2: mu*lambda = const => const error",
    specs=specs, derive=derive,
    claims=(
        Claim("error_grows_with_product",
              lambda d: (d["mean_error_by_prod"]["4096"]
                         > d["mean_error_by_prod"]["128"])),
    ),
    params={"epochs": 10, "base_lr": 0.35}, quick_params={"epochs": 3}))
