"""Cell ``fig4`` — paper Fig. 4: ⟨σ⟩ per update and the σ distribution.

Measure-mode spec-graph (``problem=None``): the schedule pass alone carries
the Fig.-4 statistics.  Claims: ⟨σ⟩ ≈ n for the n-softsync protocol and
P(σ > 2n) stays below 1e-3; a scenario sweep exercises the beyond-paper
duration models (two-speed, Pareto stragglers) at fixed (λ, n).
"""

from __future__ import annotations

from repro.config import RunConfig
from repro.experiments.registry import Cell, Claim, emit, register_cell
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep

_LAM = 30
_NS = (1, 2, 4, _LAM)
_SCEN_N = 4
_CASES = (
    {"duration_model": "homogeneous", "tag": "homogeneous"},
    {"duration_model": "two_speed", "slow_fraction": 0.25,
     "slow_factor": 4.0, "tag": "two_speed"},
    {"duration_model": "pareto", "pareto_alpha": 1.5,
     "pareto_scale": 1.0, "tag": "pareto"},
)


def specs(steps: int = 4000):
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_learners=_LAM, minibatch=128,
                      seed=11),
        steps=steps)
    main = list(Sweep.over(base, n_softsync=list(_NS)))
    scen = list(Sweep.over(
        base.replace(run=base.run.replace(n_softsync=_SCEN_N)),
        cases=[dict(c) for c in _CASES]))
    return main + scen


def derive(results, params):
    out = {}
    for n, res in zip(_NS, results[:len(_NS)]):
        st = res.staleness
        row = {
            "n": n,
            "mean_staleness": st["mean"],
            "sigma_min": st["min"],
            "sigma_max": st["max"],
            "ring_buffer_K": st["ring_buffer_K"],
            "frac_exceeding_2n": st["frac_exceeding_2n"],
            "series_head": st["series_head"],
            "histogram": st["histogram"],
        }
        out[f"softsync_{n}"] = row
        claim = (abs(row["mean_staleness"] - n) <= max(0.6, 0.15 * n)
                 and row["frac_exceeding_2n"] < 1e-3)
        emit(f"fig4/softsync_n={n}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"claim<sigma>≈n:{'PASS' if claim else 'FAIL'}")
        emit(f"fig4/softsync_n={n}/frac_sigma>2n",
             f"{row['frac_exceeding_2n']:.5f}", "paper:<1e-4")
    for res in results[len(_NS):]:
        model = res.tag
        st = res.staleness
        row = {
            "mean_staleness": st["mean"],
            "sigma_max": st["max"],
            "frac_exceeding_2n": st["frac_exceeding_2n"],
            "simulated_time": res.runtime["simulated_time"],
        }
        out[f"scenario_{model}"] = row
        emit(f"fig4scenario/{model}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"sigma_max={row['sigma_max']:.0f} "
             f"time={row['simulated_time']:.0f}s")
    return out


register_cell(Cell(
    name="fig4", result="fig4_staleness",
    title="Fig. 4: staleness distribution per n-softsync",
    specs=specs, derive=derive,
    claims=(
        Claim("mean_staleness_tracks_n",
              lambda d: all(abs(d[f"softsync_{n}"]["mean_staleness"] - n)
                            <= max(0.6, 0.15 * n) for n in _NS)),
        Claim("staleness_tail_bounded",
              lambda d: all(d[f"softsync_{n}"]["frac_exceeding_2n"] < 1e-3
                            for n in _NS)),
    ),
    params={"steps": 4000}, quick_params={"steps": 1000}))
