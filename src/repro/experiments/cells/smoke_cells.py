"""The ``smoke`` campaign — three tiny cells (seconds each) exercising every
campaign-runner code path: a spec-graph sweep, a measure-mode sweep, and a
dependent compute report.  CI runs this campaign twice in one job and
asserts the second pass is 100% cache hits (the content-addressed caching
contract); tests drive the same cells for resume/force/staleness coverage.

Not part of the ``paper`` campaign: results land wherever ``--results-dir``
points (CI uses a temp dir) and are never checked in.
"""

from __future__ import annotations

import numpy as np

from repro.config import RunConfig
from repro.experiments.registry import (Cell, derived_claims, emit,
                                        load_envelope, register_cell)
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep

_LRS = (0.02, 0.1)
_SEEDS = (0, 1)
_NS = (1, 4)


def _grid_specs(steps: int = 12):
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=8,
                      minibatch=4, base_lr=0.05,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=0),
        problem="mlp_teacher", steps=steps)
    return list(Sweep.over(base, base_lr=list(_LRS), seed=list(_SEEDS)))


def _grid_derive(results, params):
    errs = {r.tag: r.metrics["test_error"] for r in results}
    mean = float(np.mean(list(errs.values())))
    emit("smoke_grid/mean_test_error", f"{mean:.4f}",
         f"{len(results)} grid points")
    return {"test_errors": errs, "mean_test_error": mean,
            "claims": {"all_errors_finite":
                       all(np.isfinite(v) for v in errs.values())}}


def _measure_specs(steps: int = 200):
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=8,
                      minibatch=4, seed=0),
        steps=steps)
    return list(Sweep.over(base, n_softsync=list(_NS)))


def _measure_derive(results, params):
    sig = {f"n={n}": r.staleness["mean"] for n, r in zip(_NS, results)}
    for k, v in sig.items():
        emit(f"smoke_measure/{k}/mean_staleness", f"{v:.2f}", "")
    return {"mean_staleness": sig,
            "claims": {"staleness_grows_with_n":
                       sig[f"n={_NS[-1]}"] > sig[f"n={_NS[0]}"]}}


def _report(results_dir: str = None):
    grid = (load_envelope("smoke_grid", results_dir) or {}).get("derived", {})
    meas = (load_envelope("smoke_measure", results_dir) or {}).get(
        "derived", {})
    out = {
        "grid_mean_test_error": grid.get("mean_test_error"),
        "measure_staleness": meas.get("mean_staleness", {}),
        "claims": {"deps_present": bool(grid) and bool(meas)},
    }
    emit("smoke_report/deps_present", out["claims"]["deps_present"], "")
    return [], out


register_cell(Cell(
    name="smoke_grid", result="smoke_grid",
    title="Smoke: tiny LR x seed spec-graph sweep",
    specs=_grid_specs, derive=_grid_derive,
    claims=derived_claims("all_errors_finite"),
    campaigns=("smoke",),
    params={"steps": 12}, quick_params={"steps": 6},
    checkpoint_every=2))

register_cell(Cell(
    name="smoke_measure", result="smoke_measure",
    title="Smoke: measure-mode staleness sweep",
    specs=_measure_specs, derive=_measure_derive,
    claims=derived_claims("staleness_grows_with_n"),
    campaigns=("smoke",),
    params={"steps": 200}, quick_params={"steps": 100}))

register_cell(Cell(
    name="smoke_report", result="smoke_report",
    title="Smoke: dependent report over the other smoke cells",
    compute=_report, deps=("smoke_grid", "smoke_measure"),
    needs_results_dir=True, campaigns=("smoke",),
    claims=derived_claims("deps_present")))
