"""Cell ``fig6_7`` — paper Figs. 6/7: (σ, μ, λ) tradeoff curves — test
error vs training time for hardsync / 1-softsync / λ-softsync over the
(μ, λ) grid.  Error axis from the compiled trace/replay engine; time axis
from the calibrated Rudra-base runtime model (``core/tradeoff.py``).
"""

from __future__ import annotations

from repro.config import RunConfig
from repro.experiments.registry import Cell, Claim, emit, register_cell
from repro.experiments.spec import ExperimentSpec

_DEF_MUS = (4, 16, 64, 128)
_DEF_LAMS = (1, 4, 10, 30)


def _grid(mus, lams):
    rows = []
    for proto, nfn in [("hardsync", lambda lam: 1),
                       ("softsync1", lambda lam: 1),
                       ("softsyncL", lambda lam: lam)]:
        base = "hardsync" if proto == "hardsync" else "softsync"
        policy = "sqrt_scale" if base == "hardsync" else "staleness_inverse"
        for mu in mus:
            for lam in lams:
                if lam == 1 and proto != "hardsync":
                    continue
                rows.append((proto, base, policy, nfn(lam), mu, lam))
    return rows


def specs(epochs: int = 6, base_lr: float = 0.35,
          mus=_DEF_MUS, lams=_DEF_LAMS):
    out = []
    for proto, base, policy, n, mu, lam in _grid(mus, lams):
        out.append(ExperimentSpec(
            run=RunConfig(protocol=base, n_softsync=n, n_learners=lam,
                          minibatch=mu, base_lr=base_lr, lr_policy=policy,
                          ref_batch=128, optimizer="sgd", seed=7),
            problem="mlp_teacher", epochs=epochs,
            tag=f"{proto}/mu={mu}/lam={lam}"))
    return out


def derive(results, params):
    from repro.core import tradeoff as to
    from repro.experiments.problems import get_problem

    epochs = params["epochs"]
    mus, lams = params.get("mus", _DEF_MUS), params.get("lams", _DEF_LAMS)
    hw = to.calibrate_to_baseline()
    wl = to.WorkloadModel(dataset_size=get_problem("mlp_teacher").dataset_size,
                          epochs=epochs)
    out = {}
    for (proto, base, policy, n, mu, lam), res in zip(_grid(mus, lams),
                                                      results):
        t = to.training_time("base", base, mu, lam, hw, wl)
        out[res.tag] = {"test_error": res.metrics["test_error"],
                        "train_time_s": t, "mu_lambda": mu * lam}

    small = out["hardsync/mu=4/lam=1"]["test_error"]
    large = out["hardsync/mu=128/lam=30"]["test_error"]
    emit("fig6/error_grows_with_mu_lambda", large > small,
         f"{small:.3f}->{large:.3f}")
    e_big = out["softsyncL/mu=128/lam=30"]["test_error"]
    e_small = out["softsyncL/mu=4/lam=30"]["test_error"]
    emit("fig7/small_mu_restores_error", e_small < e_big,
         f"mu128:{e_big:.3f} mu4:{e_small:.3f}")
    t1 = out["hardsync/mu=128/lam=1"]["train_time_s"]
    t30 = out["hardsync/mu=128/lam=30"]["train_time_s"]
    emit("fig6/time_falls_with_lambda", t30 < t1, f"{t1:.0f}s->{t30:.0f}s")
    return out


register_cell(Cell(
    name="fig6_7", result="fig6_7_tradeoff",
    title="Figs. 6/7: (sigma, mu, lambda) error/time tradeoff curves",
    specs=specs, derive=derive,
    claims=(
        Claim("error_grows_with_mu_lambda",
              lambda d: (d["hardsync/mu=128/lam=30"]["test_error"]
                         > d["hardsync/mu=4/lam=1"]["test_error"])),
        Claim("small_mu_restores_error",
              lambda d: (d["softsyncL/mu=4/lam=30"]["test_error"]
                         < d["softsyncL/mu=128/lam=30"]["test_error"])),
        Claim("time_falls_with_lambda",
              lambda d: (d["hardsync/mu=128/lam=30"]["train_time_s"]
                         < d["hardsync/mu=128/lam=1"]["train_time_s"])),
    ),
    params={"epochs": 6, "base_lr": 0.35}, quick_params={"epochs": 3}))
