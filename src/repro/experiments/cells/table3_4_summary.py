"""Cell ``table3_4`` — paper Tables 3/4 + Fig. 9: best-(σ, μ, λ) selection
(Table 3) and the ImageNet-scale analog — the four deployment configurations
base-hardsync / base-softsync / adv-softsync / adv*-softsync (Table 4), with
error from the protocol-faithful simulator and time/epoch from the
calibrated runtime model scaled to a 289 MB model.

Campaign report cell: depends on the topology / elastic / serve /
distributed / sim_engine cells and folds their ``derived`` blocks into the
summary envelope, reading them through the registry (``needs_results_dir``).
"""

from __future__ import annotations

from repro.experiments.registry import (Cell, Claim, emit, load_envelope,
                                        register_cell)

_DEPS = ("topology", "elastic", "serve", "distributed", "sim_engine")


def _sim_error(prob, protocol, n, mu, lam, epochs, base_lr=0.35,
               extra_staleness: float = 0.0):
    from repro.config import RunConfig
    from repro.core.simulator import simulate
    from repro.experiments.problems import updates_for_epochs

    policy = "sqrt_scale" if protocol == "hardsync" else "staleness_inverse"
    cfg = RunConfig(protocol=protocol, n_softsync=n, n_learners=lam,
                    minibatch=mu, base_lr=base_lr, lr_policy=policy,
                    ref_batch=128, optimizer="sgd", seed=13)
    steps = updates_for_epochs(epochs, mu, cfg.gradients_per_update,
                               prob.task.n_train)

    if extra_staleness > 0:
        # adv*: async comm threads add delivery delay ⇒ extra staleness.
        # Model as a duration sampler with heavier jitter.
        def sampler(rng, m):
            from repro.core.simulator import _default_duration_sampler
            return _default_duration_sampler(rng, m) * \
                rng.lognormal(0.0, 0.3)
        res = simulate(cfg, steps=steps, grad_fn=prob.grad_fn,
                       init_params=prob.init, batch_fn=prob.batch_fn_for(mu),
                       duration_sampler=sampler)
    else:
        res = simulate(cfg, steps=steps, grad_fn=prob.grad_fn,
                       init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
    return prob.test_error(res.params), res.clock_log.mean_staleness()


def _derived_of(cell_name: str, results_dir: str):
    env = load_envelope(cell_name, results_dir)
    return (env or {}).get("derived") or {}


def compute(epochs: int = 10, results_dir: str = None):
    from repro.core import tradeoff as to
    from repro.experiments.problems import get_problem

    prob = get_problem("mlp_teacher")
    hw = to.calibrate_to_baseline()
    out = {}

    # ---- Table 3: best configs (low error AND small time) ------------------
    candidates = [
        ("1-softsync", "softsync", 1, 4, 30),
        ("hardsync", "hardsync", 1, 8, 30),
        ("L-softsync", "softsync", 30, 4, 30),
        ("hardsync", "hardsync", 1, 4, 30),
        ("18-softsync", "softsync", 18, 8, 18),
    ]
    rows = []
    for label, proto, n, mu, lam in candidates:
        err, sig = _sim_error(prob, proto, n, mu, lam, epochs)
        t = to.training_time("base", proto, mu, lam, hw,
                             to.WorkloadModel(dataset_size=prob.task.n_train,
                                              epochs=epochs))
        rows.append({"config": f"{label}(s={n},mu={mu},lam={lam})",
                     "test_error": err, "time_s": t, "staleness": sig})
        emit(f"table3/{label}/s={n}_mu={mu}_lam={lam}",
             f"err={err:.4f}", f"time={t:.0f}s")
    out["table3"] = rows
    # paper's selection: fastest among the configurations within 1% absolute
    # error of the best (Table 3 is sorted by this combination)
    err_min = min(r["test_error"] for r in rows)
    near = [r for r in rows if r["test_error"] <= err_min + 0.01]
    best = min(near, key=lambda r: r["time_s"])
    emit("table3/best_config", best["config"],
         "paper-best: 1-softsync mu=4 lam=30")
    # the paper's Table-3 top-2 are 1-softsync(μ4,λ30) and hardsync(μ8,λ30);
    # our runtime model may order those two either way (GEMM-efficiency
    # calibration), but the winner must come from that pair.
    top2 = best["config"].startswith(("1-softsync(s=1,mu=4,lam=30",
                                      "hardsync(s=1,mu=8,lam=30"))
    emit("table3/best_in_paper_top2", top2, best["config"])
    out["table3_best"] = {"config": best["config"], "in_paper_top2": top2}

    # ---- Table 4: the four ImageNet-analog deployments ---------------------
    wl = to.WorkloadModel(model_bytes=289e6, dataset_size=prob.task.n_train,
                          epochs=epochs)
    deployments = [
        ("base-hardsync", "base", "hardsync", 1, 16, 18, 0.0),
        ("base-softsync", "base", "softsync", 1, 16, 18, 0.0),
        ("adv-softsync", "adv", "softsync", 1, 4, 54, 0.0),
        ("adv*-softsync", "adv*", "softsync", 1, 4, 54, 0.3),
    ]
    t4 = []
    for label, arch, proto, n, mu, lam, extra in deployments:
        err, sig = _sim_error(prob, proto, n, mu, lam, epochs,
                              extra_staleness=extra)
        t_epoch = to.epoch_time(arch, proto, mu, lam, hw, wl)
        t4.append({"config": label, "test_error": err,
                   "minutes_per_epoch_model": t_epoch / 60.0,
                   "staleness": sig})
        emit(f"table4/{label}", f"err={err:.4f}",
             f"epoch={t_epoch/60:.1f}min <sigma>={sig:.2f}")
    out["table4"] = t4
    speeds = [r["minutes_per_epoch_model"] for r in t4]
    emit("table4/speed_ordering_adv*<adv<base-soft<base-hard",
         speeds[3] < speeds[2] < speeds[1] < speeds[0], "")
    err_hard = t4[0]["test_error"]
    err_star = t4[3]["test_error"]
    emit("table4/hardsync_best_error", err_hard <= err_star + 0.05,
         f"{err_hard:.3f} vs adv*:{err_star:.3f}")

    # ---- dependency cells: fold their derived blocks in --------------------
    derived = _derived_of("topology", results_dir)
    if derived:
        out["topology_scaling"] = derived
        for arch, curve in sorted(derived.get("train_seconds", {}).items()):
            span = {int(k): v for k, v in curve.items()}
            lam0, lam1 = min(span), max(span)
            emit(f"summary/topology/{arch}",
                 f"train[{lam0}]={span[lam0]:.0f}s "
                 f"train[{lam1}]={span[lam1]:.0f}s",
                 f"speedup={span[lam0] / span[lam1]:.1f}x over "
                 f"{lam1 // lam0}x learners")

    derived = _derived_of("elastic", results_dir)
    if derived:
        out["elastic_churn"] = derived
        for name, s in sorted(derived.get("scenarios", {}).items()):
            emit(f"summary/elastic/{name}",
                 f"err={s['test_error_mean']:.4f}",
                 f"train_s={s['train_s_mean']:.0f}")
        claims = derived.get("claims", {})
        emit("summary/elastic/chen_ordering_holds",
             all(claims.values()) if claims else False,
             " ".join(k for k, v in sorted(claims.items()) if not v))

    derived = _derived_of("serve", results_dir)
    if derived:
        out["train_while_serve"] = derived
        for name, s in sorted(derived.get("scenarios", {}).items()):
            emit(f"summary/serve/{name}",
                 f"acc={s['serving_accuracy_mean']:.4f}",
                 f"stale={s['staleness_mean']:.1f} "
                 f"p99={s['latency_p99_s']:.2f}s")
        claims = derived.get("claims", {})
        emit("summary/serve/staleness_tradeoff_holds",
             all(claims.values()) if claims else False,
             " ".join(k for k, v in sorted(claims.items()) if not v))

    derived = _derived_of("distributed", results_dir)
    if derived:
        out["distributed_replay"] = derived
        ups = derived.get("updates_per_s", {})
        for key, v in sorted(ups.items()):
            emit(f"summary/distributed/{key}", f"{v:.1f}up/s",
                 f"devices={derived.get('devices')} D={derived.get('d')}")
        ratios = {k: v for k, v in derived.items()
                  if k.startswith("scaling_")}
        for key, v in sorted(ratios.items()):
            emit(f"summary/distributed/{key}", f"{v:.2f}x",
                 f"cpu_count={derived.get('cpu_count')}")

    rows = _derived_of("sim_engine", results_dir)
    if rows:
        out["sim_engine"] = rows
        for key, r in sorted(rows.items()):
            if "compiled_updates_per_s" in r:
                ring = (f" ring={r['ring_bytes_total'] / 1e6:.1f}MB"
                        if "ring_bytes_total" in r else "")
                emit(f"summary/sim_engine/{key}",
                     f"{r['compiled_updates_per_s']:.0f}up/s",
                     f"legacy={r['legacy_updates_per_s']:.0f} "
                     f"speedup={r['speedup']:.1f}x" + ring)
            elif "megakernel_vs_xla_ratio" in r:
                emit(f"summary/sim_engine/{key}",
                     f"{r['megakernel_updates_per_s']:.0f}up/s",
                     f"vs_xla={r['megakernel_vs_xla_ratio']:.2f}x "
                     f"bf16_ring_saves="
                     f"{r['bf16_ring_bytes_saved'] / 1e6:.1f}MB")
            elif "batched_s" in r:
                emit(f"summary/sim_engine/{key}",
                     f"{r['runs']}-run sweep {r['batched_s']:.2f}s batched",
                     f"sequential={r['sequential_s']:.2f}s "
                     f"speedup={r['speedup']:.1f}x")
    return [], out


register_cell(Cell(
    name="table3_4", result="table3_4_summary",
    title="Tables 3/4: best configs + deployment summary report",
    compute=compute, deps=_DEPS, needs_results_dir=True,
    claims=(
        Claim("best_in_paper_top2",
              lambda d: d["table3_best"]["in_paper_top2"]),
        Claim("table4_speed_ordering",
              lambda d: (d["table4"][3]["minutes_per_epoch_model"]
                         < d["table4"][2]["minutes_per_epoch_model"]
                         < d["table4"][1]["minutes_per_epoch_model"]
                         < d["table4"][0]["minutes_per_epoch_model"])),
        Claim("hardsync_best_error",
              lambda d: (d["table4"][0]["test_error"]
                         <= d["table4"][3]["test_error"] + 0.05)),
    ),
    params={"epochs": 10}, quick_params={"epochs": 3}))
