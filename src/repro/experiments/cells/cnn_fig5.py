"""Cell ``cnn`` — the paper's CIFAR10 CNN (§4.2: caffe cifar10_full —
3×(conv+pool) + fc, ~90K params) in pure JAX, trained with the protocol
stack on a synthetic 32×32×3 image-teacher task.  This is the
architecture-fidelity check for the MLP stand-in used by the fast
benchmarks: the Fig-5 LR-modulation claim must reproduce on the *paper's
own network shape* too.

At the defaults (1600 updates, α₀ = 0.15, λ = n = 8) this reproduces the
paper's Fig-5 headline on the paper's own network: α₀ unmodulated sticks at
~90% error (the paper's "constant high error rate of 90%"); α₀/⟨σ⟩ reaches
~7%.  Takes ~9 min on CPU: in the ``extended`` campaign (not ``paper``)
and ``skip_quick``.
    PYTHONPATH=src python -m repro.experiments.campaign extended --only cnn
"""

from __future__ import annotations

import numpy as np

from repro.experiments.registry import Cell, Claim, emit, register_cell


# ---------------------------------------------------------------------------
# the paper's CNN (caffe cifar10_full shape): conv32-pool-conv32-pool-
# conv64-pool-fc10, ~90K trainable parameters
# ---------------------------------------------------------------------------
def init_cnn(key, n_classes: int = 10):
    import jax
    import jax.numpy as jnp

    ks = jax.random.split(key, 4)

    def conv(k, cin, cout, hw=5):
        # 0.5×He: keeps initial logit std ~O(1); full He on this 3-stage
        # conv+pool stack yields std ≈ 3.4 and the first SGD steps kill the
        # network (observed: gradnorm 83 → dead-ReLU plateau at ln 10)
        return jax.random.normal(k, (cout, cin, hw, hw)) * (0.5 * np.sqrt(
            2.0 / (cin * hw * hw)))
    return {
        "c1": conv(ks[0], 3, 32), "b1": jnp.zeros((32,)),
        "c2": conv(ks[1], 32, 32), "b2": jnp.zeros((32,)),
        "c3": conv(ks[2], 32, 64), "b3": jnp.zeros((64,)),
        "fc": jax.random.normal(ks[3], (64 * 4 * 4, n_classes)) * 0.02,
        "fb": jnp.zeros((n_classes,)),
    }


def _conv_pool(x, w, b):
    import jax
    import jax.numpy as jnp

    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    y = jax.nn.relu(y + b[None, :, None, None])
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def cnn_forward(p, x):
    """x: (B, 3, 32, 32) -> logits (B, 10)."""
    h = _conv_pool(x, p["c1"], p["b1"])
    h = _conv_pool(h, p["c2"], p["b2"])
    h = _conv_pool(h, p["c3"], p["b3"])
    return h.reshape(h.shape[0], -1) @ p["fc"] + p["fb"]


def cnn_loss(p, batch):
    import jax
    import jax.numpy as jnp

    x, y = batch
    logits = cnn_forward(p, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


# ---------------------------------------------------------------------------
# synthetic image teacher task (fixed random CNN labels)
# ---------------------------------------------------------------------------
class ImageTeacher:
    """Prototype-based 10-class images: x = 0.6·prototype[y] + noise.
    Learnable by a small CNN with a real margin (Bayes error ≈ 0), which is
    what the Fig-5 divergence-vs-convergence contrast requires."""

    def __init__(self, n_train: int = 2048, n_test: int = 512, seed: int = 3):
        rng = np.random.default_rng(seed)
        protos = rng.normal(0, 1, (10, 3, 32, 32)).astype(np.float32)

        def make(n):
            y = rng.integers(0, 10, size=n).astype(np.int32)
            x = 0.6 * protos[y] + rng.normal(0, 1, (n, 3, 32, 32)
                                             ).astype(np.float32)
            return x.astype(np.float32), y
        self.x_train, self.y_train = make(n_train)
        self.x_test, self.y_test = make(n_test)
        self.n_train = n_train

    def batch_fn_for(self, mu):
        import jax.numpy as jnp

        def fn(l, step):
            rng = np.random.default_rng(l * 99991 + step)
            idx = rng.integers(0, self.n_train, size=mu)
            return jnp.asarray(self.x_train[idx]), jnp.asarray(
                self.y_train[idx])
        return fn


def compute(updates: int = 1600, base_lr: float = 0.15):
    import jax
    import jax.numpy as jnp

    from repro.config import RunConfig
    from repro.core.simulator import simulate

    task = ImageTeacher()
    params = init_cnn(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    emit("cnn/params", n_params, "paper: ~90K")
    grad_fn = jax.jit(jax.grad(cnn_loss))
    test_err_fn = jax.jit(lambda p: 1.0 - jnp.mean(
        (jnp.argmax(cnn_forward(p, jnp.asarray(task.x_test)), -1)
         == jnp.asarray(task.y_test)).astype(jnp.float32)))

    lam, mu, n = 8, 16, 8
    out = {"n_params": n_params}
    for policy in ("const", "staleness_inverse"):
        cfg = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                        minibatch=mu, base_lr=base_lr, lr_policy=policy,
                        optimizer="sgd", seed=1)
        res = simulate(cfg, steps=updates, grad_fn=grad_fn,
                       init_params=params, batch_fn=task.batch_fn_for(mu))
        err = float(test_err_fn(res.params))
        out[policy] = err
        emit(f"cnn_fig5/{policy}/test_error", f"{err:.4f}",
             f"<sigma>={res.clock_log.mean_staleness():.1f}")
    helps = (not np.isfinite(out["const"])) or \
        out["staleness_inverse"] <= out["const"] + 1e-6
    emit("cnn_fig5/modulation_helps_on_paper_cnn", helps,
         f"{out['staleness_inverse']:.3f} vs {out['const']:.3f}")
    return [], out


register_cell(Cell(
    name="cnn", result="cnn_fig5",
    title="Fig. 5 on the paper's own CNN shape",
    compute=compute, campaigns=("extended",), skip_quick=True,
    claims=(
        Claim("modulation_helps_on_paper_cnn",
              lambda d: ((not np.isfinite(d["const"]))
                         or d["staleness_inverse"] <= d["const"] + 1e-6)),
    ),
    params={"updates": 1600, "base_lr": 0.15}))
