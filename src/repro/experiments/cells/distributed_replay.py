"""Cell ``distributed`` — distributed-replay throughput:
``placement="spmd"`` on an emulated 8-device host (DESIGN.md §13).

The cell replays the calibrated adv workload — the what-if quadratic at
multi-million D under ``duration_model="calibrated:adv:300mb"`` — with the
PS ring sharded over S ∈ {1, 2, 4} "ps" devices, and reports updates/s per
S plus the S=4/S=1 scaling ratio.  The what-if body is the per-shard-
parallel showcase: closed-form gradients are shard-local, so each device
touches only its (K, ⌈D/S⌉) ring slice and per-event work drops ∝ 1/S.
Whether that shows up as *wall-clock* scaling depends on the host actually
having cores for the emulated devices to run on (``cpu_count`` rides in
the results; a 1-core container timeshares all S devices).  A
``placement="single"`` row at S=4 anchors the comparison.

Runs its measurement in a **subprocess** so the 8-device XLA flag applies
before jax initializes (the dry-run trick, ``launch/dryrun.py``) — the
parent process may already hold a 1-device jax.  The module is its own
subprocess entry point (``python -m repro.experiments.cells.\
distributed_replay --inner <json>``) so the child needs only ``src`` on
PYTHONPATH.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.experiments.registry import (Cell, Claim, emit, register_cell,
                                        repo_root)

DEVICES = 8
SHARDS = (1, 2, 4)
_MARKER = "DISTRIBUTED_REPLAY_RESULT:"


def _inner(payload: dict) -> dict:
    """Runs inside the 8-device subprocess: measure every cell."""
    from repro.launch.mesh import ensure_host_devices
    ensure_host_devices(payload["devices"])
    import jax

    from repro.config import RunConfig
    from repro.core.engine import replay
    from repro.core.trace import schedule_cached
    from repro.experiments.problems import QuadraticProblem

    updates = payload["updates"]
    repeats = payload["repeats"]
    prob = QuadraticProblem(d=payload["d"])

    def measure_one(cfg) -> float:
        trace = schedule_cached(cfg, updates)

        def once():
            res = replay(trace, cfg, grad_fn=prob.grad_fn,
                         init_params=prob.init,
                         batch_fn=prob.batch_fn_for(cfg.minibatch),
                         flat_grad=prob.flat_grad)
            jax.block_until_ready(res.params["w"])
            return res

        once()                                    # compile + warm
        best = min(_timed(once) for _ in range(repeats))
        return updates / best

    rows = {}
    for s in payload["shards"]:
        cfg = RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                        minibatch=4, base_lr=0.05,
                        lr_policy="staleness_inverse", optimizer="momentum",
                        duration_model="calibrated:adv:300mb", shards=s,
                        placement="spmd", ring_impl="fused", seed=0)
        rows[f"spmd_s{s}"] = measure_one(cfg)
    single = RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                       minibatch=4, base_lr=0.05,
                       lr_policy="staleness_inverse", optimizer="momentum",
                       duration_model="calibrated:adv:300mb",
                       shards=max(payload["shards"]), ring_impl="fused",
                       seed=0)
    rows["single_s%d" % max(payload["shards"])] = measure_one(single)

    s_lo, s_hi = min(payload["shards"]), max(payload["shards"])
    # per-"ps"-device ring residency: K rows of the ⌈D/S⌉ shard slice —
    # the ∝ 1/S per-device working set that wall-clock scaling rides on
    trace = schedule_cached(
        RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                  minibatch=4, base_lr=0.05,
                  lr_policy="staleness_inverse", optimizer="momentum",
                  duration_model="calibrated:adv:300mb", seed=0), updates)
    K = trace.max_staleness + 1
    ring_bytes = {f"spmd_s{s}": K * (-(-payload["d"] // s)) * 4
                  for s in payload["shards"]}
    return {
        "devices": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "d": payload["d"],
        "updates": updates,
        "updates_per_s": rows,
        "per_device_ring_bytes": ring_bytes,
        "scaling_s%d_over_s%d" % (s_hi, s_lo):
            rows[f"spmd_s{s_hi}"] / rows[f"spmd_s{s_lo}"],
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure(updates: int = 48, d: int = 2_000_000, repeats: int = 3,
            shards=SHARDS, devices: int = DEVICES) -> dict:
    """Spawn the 8-device subprocess and return its measurement dict."""
    payload = {"devices": devices, "updates": updates, "d": d,
               "repeats": repeats, "shards": list(shards)}
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={devices}"]).strip()
    root = repo_root()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")] if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.experiments.cells.distributed_replay",
         "--inner", json.dumps(payload)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"distributed_replay subprocess failed:\n{proc.stdout}\n"
            f"{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARKER):
            return json.loads(line[len(_MARKER):])
    raise RuntimeError(f"no result marker in subprocess output:\n"
                       f"{proc.stdout}\n{proc.stderr}")


def compute(updates: int = 48, d: int = 2_000_000, repeats: int = 3):
    out = measure(updates=updates, d=d, repeats=repeats)
    for key, ups in sorted(out["updates_per_s"].items()):
        emit(f"distributed_replay/{key}", f"{ups:.1f}up/s",
             f"D={d} updates={updates} devices={out['devices']}")
    s_lo, s_hi = min(SHARDS), max(SHARDS)
    ratio_key = "scaling_s%d_over_s%d" % (s_hi, s_lo)
    emit(f"distributed_replay/{ratio_key}", f"{out[ratio_key]:.2f}x",
         f"cpu_count={out['cpu_count']} (wall-clock scaling needs cores "
         f"for the emulated devices)")
    return [], out


if __name__ != "__main__":
    # running as the --inner subprocess entry point re-executes this module
    # under __main__ AFTER the cells package already imported (and
    # registered) it — don't register the cell twice
    register_cell(Cell(
        name="distributed", result="distributed_replay",
        title="SPMD distributed replay on the emulated device mesh",
        compute=compute,
        claims=(
            Claim("emulated_mesh_has_8_devices",
                  lambda d: d["devices"] == DEVICES),
            Claim("all_shard_counts_measured",
                  lambda d: all(f"spmd_s{s}" in d["updates_per_s"]
                                for s in SHARDS)),
        ),
        params={"updates": 48, "d": 2_000_000, "repeats": 3},
        quick_params={"updates": 32, "d": 1_000_000, "repeats": 2}))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", default=None,
                    help="(internal) JSON payload; run the measurement in "
                         "this process and print the marker line")
    args = ap.parse_args()
    if args.inner is None:
        ap.error("--inner payload required (use the campaign CLI to run "
                 "the cell)")
    result = _inner(json.loads(args.inner))
    print(_MARKER + json.dumps(result, default=float))
