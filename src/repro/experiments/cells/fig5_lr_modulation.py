"""Cell ``fig5`` — paper Fig. 5: α₀/⟨σ⟩ modulation rescues convergence for
n-softsync; the unmodulated rate diverges at high staleness.  Also measures
footnote 3's per-gradient α₀/σ_g modulation (suggested, never evaluated in
the paper).  base_lr is intentionally aggressive — divergence of the
``const`` policy is the point.
"""

from __future__ import annotations

import numpy as np

from repro.config import RunConfig
from repro.experiments.registry import Cell, Claim, emit, register_cell
from repro.experiments.spec import ExperimentSpec

_LAM, _MU = 30, 32
_POLICIES = ("const", "staleness_inverse", "per_gradient")


def specs(epochs: int = 12, base_lr: float = 2.0):
    out = []
    for n in [4, _LAM]:
        for policy in _POLICIES:
            spec = ExperimentSpec(
                run=RunConfig(protocol="softsync", n_softsync=n,
                              n_learners=_LAM, minibatch=_MU,
                              base_lr=base_lr, lr_policy=policy,
                              optimizer="sgd", seed=5),
                problem="mlp_teacher", epochs=epochs, tag=f"n={n}/{policy}")
            # error-vs-updates curve at ~10 points (per_gradient runs
            # final-only, matching the paper's footnote-3 spot check).
            # eval_every must divide steps: the trailing remainder segment
            # would compile a second scan program AND lose the final curve
            # point — pick the nearest divisor.
            if policy != "per_gradient":
                steps = spec.resolved_steps()
                target = max(1, steps // 10)
                eval_every = min((d for d in range(1, steps + 1)
                                  if steps % d == 0),
                                 key=lambda d: abs(d - target))
                spec = spec.replace(eval_every=eval_every)
            out.append(spec)
    return out


def derive(results, params):
    out = {}
    for res in results:
        final = res.metrics["test_error"]
        out[res.tag] = {
            "final_test_error": final,
            "trace": res.curve,
            "mean_staleness": res.staleness["mean"],
        }
        emit(f"fig5/{res.tag}/test_error",
             f"{final:.4f}" if np.isfinite(final) else "diverged", "")
    for n in [4, _LAM]:
        e_mod = out[f"n={n}/staleness_inverse"]["final_test_error"]
        e_const = out[f"n={n}/const"]["final_test_error"]
        better = (not np.isfinite(e_const)) or e_mod <= e_const + 1e-6
        emit(f"fig5/n={n}/modulation_helps", better,
             f"alpha0/n:{e_mod:.3f} vs alpha0:{e_const:.3f}")
        e_pg = out[f"n={n}/per_gradient"]["final_test_error"]
        emit(f"fig5fn3/n={n}/per_gradient_vs_mean", f"{e_pg:.4f}",
             f"mean-mod:{e_mod:.4f} "
             f"{'BETTER' if e_pg < e_mod else 'comparable/worse'}")
    return out


def _modulation_helps(d, n):
    e_mod = d[f"n={n}/staleness_inverse"]["final_test_error"]
    e_const = d[f"n={n}/const"]["final_test_error"]
    return (not np.isfinite(e_const)) or e_mod <= e_const + 1e-6


register_cell(Cell(
    name="fig5", result="fig5_lr_modulation",
    title="Fig. 5: staleness-modulated LR rescues n-softsync",
    specs=specs, derive=derive,
    claims=tuple(Claim(f"modulation_helps_n{n}",
                       lambda d, n=n: _modulation_helps(d, n))
                 for n in (4, _LAM)),
    params={"epochs": 12, "base_lr": 2.0}, quick_params={"epochs": 3}))
