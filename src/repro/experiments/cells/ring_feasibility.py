"""Cell ``ring`` — max feasible model size for trace-driven replay: stock
path vs the megakernel + what-if ring (DESIGN.md §12).

Part 1 — analytic: bytes/param of the replay working set, calibrated
against measured peak RSS (see ``measured_bytes_per_param`` in the
results).  Replaying a trace against a *real* model backward — the only
pre-megakernel option — materializes the (c, D) pulled-weight and (c, D)
per-slot gradient matrices every event on top of the undonated
double-buffered (K, D) ring:

    stock   ~ (2·K + 2·c) · 4          bytes/param
              [measured 987 at K=3, c=128 vs model 1048]

The what-if megakernel path carries only the donated ring (+ optimizer
state, + the bf16 error-feedback residue — ``roofline.ring_bytes``) and
streams the closed-form gradients in O(D):

    what-if ~ ring_bytes/param + ~16   bytes/param
              [measured 32.7 at K=3, fp32, sgd vs model 28]

At the Table-3 winner shape (1-softsync, c = λ) the gap is c-dominated:
10-100× more feasible parameters under the same memory budget, which is
what opens ``configs/`` big-model shapes to staleness what-if studies.

Part 2 — empirical: ``RLIMIT_AS``-capped subprocesses replay the same
trace shape (softsync n=1, λ=128, 8 updates) under the same 2.5 GiB
address-space cap.  The stock path with a real MLP backward dies at
D₀ ≈ 10 M params; the what-if megakernel on the bf16 error-feedback
ring replays 10·D₀ = 100 M.  ``skip_quick``: the capped subprocesses take
minutes.
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.experiments.registry import (Cell, derived_claims, emit,
                                        register_cell, repo_root)

# the empirical cell: 1-softsync lam=128 (c = 128), 8 updates, sgd.
# D0: MLP hidden=232558 -> D = 43*232558 + 10 = 10_000_004 ~ 10M params.
# The what-if lane replays 10*D0 sized to its kernel tile (a pad_flat
# no-op: the padded-aux copies of a / w* never materialize) on the bf16
# error-feedback ring.
_CAP_BYTES = 5 << 29            # 2.5 GiB address-space cap
_D0 = 10_000_004
_HIDDEN0 = 232_558              # the real-backward lane sized to D0
_D_WHATIF = 100_007_936         # replay_ring.padded_width(10 * _D0)
_LAM = 128
_STEPS = 8

_CHILD = """
import resource
cap = int({cap})
resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
from repro.config import RunConfig
from repro.experiments import ExperimentSpec
from repro.experiments import run as run_spec

cfg = RunConfig(protocol="softsync", n_softsync=1, n_learners={lam},
                minibatch=1, base_lr=0.01, optimizer="sgd", seed=5,
                ring_impl={impl!r}, ring_dtype={ring_dtype!r})
spec = ExperimentSpec(run=cfg, problem={problem!r},
                      problem_args={pargs!r}, steps={steps})
res = run_spec(spec)
peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
print("FEASIBLE", sorted(res.metrics)[0], f"peak_bytes={{peak}}")
"""


def _try_replay(label: str, d: int, impl: str, problem: str, pargs: tuple,
                ring_dtype: str = "fp32", cap: int = _CAP_BYTES) -> dict:
    """Run one capped replay in a subprocess; MemoryError / bad-alloc
    aborts count as infeasible (the allocator may kill the process
    outright rather than raise, so any nonzero exit is a fail)."""
    code = _CHILD.format(cap=cap, lam=_LAM, impl=impl, problem=problem,
                         pargs=pargs, steps=_STEPS, ring_dtype=ring_dtype)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo_root(), "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=600)
    ok = proc.returncode == 0 and "FEASIBLE" in proc.stdout
    return {"label": label, "d": d, "impl": impl, "problem": problem,
            "ring_dtype": ring_dtype, "cap_bytes": cap, "feasible": ok,
            "detail": (proc.stdout.strip() if ok else
                       (proc.stderr.strip().splitlines() or ["killed"])[-1]
                       [:200])}


def _stock_bytes_per_param(K: int, c: int) -> float:
    """Working-set bytes/param of the stock real-backward path: undonated
    (K, D) fp32 ring (2x across scan dispatches) + the per-event (c, D)
    pulled-weight and gradient fp32 matrices (live together through the
    vmapped backward).  Validated: 987 measured at K=3, c=128."""
    return 2.0 * K * 4 + 2.0 * c * 4


def _whatif_bytes_per_param(K: int, ring_dtype: str, optimizer: str) -> float:
    """Working-set bytes/param of the what-if megakernel path: the donated
    ring carry (+ state/residue, roofline.ring_bytes) plus the O(D)
    streaming set — a, w*, the accumulator, and one pulled row.
    Validated: 32.7 measured at K=3, fp32, sgd."""
    from repro.launch.roofline import ring_bytes
    carry = ring_bytes(K, 1 << 20, ring_dtype, optimizer)["bytes_per_param"]
    return carry + 4.0 * 4


def compute(**params):
    out = {}

    # ---- analytic: configs/ architectures under a 64 GB budget ------------
    # (one fat host or accelerator-pool node; the smallest configs/ arch is
    # 1.26 B params, so a 32 GB laptop budget unlocks nothing either way)
    budget = 64 << 30
    K, c = 3, _LAM          # 1-softsync lam=128: sigma <= 2n -> K = 3
    stock_bpp = _stock_bytes_per_param(K, c)
    rows = {}
    from repro.configs import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        n = int(get_config(arch).param_count())
        for dtype in ("fp32", "bf16"):
            bpp = _whatif_bytes_per_param(K, dtype, "momentum")
            rows[f"{arch}_{dtype}"] = {
                "params": n,
                "whatif_bytes_per_param": bpp,
                "whatif_gb": n * bpp / 2**30,
                "stock_gb": n * stock_bpp / 2**30,
                "whatif_fits_budget": n * bpp <= budget,
                "stock_fits_budget": n * stock_bpp <= budget,
            }
    out["configs_table"] = rows
    out["analytic"] = {
        "K": K, "c": c, "budget_gb": budget / 2**30,
        "stock_bytes_per_param": stock_bpp,
        "whatif_fp32_bytes_per_param": _whatif_bytes_per_param(
            K, "fp32", "momentum"),
        "whatif_bf16_bytes_per_param": _whatif_bytes_per_param(
            K, "bf16", "momentum"),
        "max_feasible_d_stock": int(budget / stock_bpp),
        "max_feasible_d_whatif_fp32": int(
            budget / _whatif_bytes_per_param(K, "fp32", "momentum")),
        "max_feasible_d_whatif_bf16": int(
            budget / _whatif_bytes_per_param(K, "bf16", "momentum")),
    }
    out["measured_bytes_per_param"] = {
        # peak-RSS calibration points behind the models above (dev box,
        # CPU XLA; softsync n=1 lam=128, 8 updates).  "capped" = under the
        # RLIMIT_AS cap, where the allocator reuses aggressively.
        "stock_mlp_backward_d4m_uncapped": 987.0,
        "whatif_fp32_sgd_d40m_uncapped": 32.7,
        "whatif_bf16_sgd_d100m_capped": 20.0,
    }
    gain = (out["analytic"]["max_feasible_d_whatif_bf16"]
            / out["analytic"]["max_feasible_d_stock"])
    out["analytic"]["feasible_d_gain_bf16"] = gain
    emit("ring_feasibility/analytic/max_feasible_D",
         f"stock={out['analytic']['max_feasible_d_stock']:.2e} "
         f"whatif_bf16={out['analytic']['max_feasible_d_whatif_bf16']:.2e}",
         f"gain={gain:.1f}x at K={K} c={c} under "
         f"{budget >> 30}GB")
    fits = [a for a in ARCH_IDS
            if rows[f"{a}_bf16"]["whatif_fits_budget"]
            and not rows[f"{a}_bf16"]["stock_fits_budget"]]
    emit("ring_feasibility/analytic/configs_unlocked",
         len(fits), ",".join(fits))

    # ---- empirical: RLIMIT_AS-capped subprocess replays -------------------
    # old path = real MLP backward through the stock engine at D0 (the only
    # pre-megakernel way to replay a trace); new path = what-if megakernel
    # on the closed-form quadratic at 10*D0, same trace shape and cap.
    trials = [
        _try_replay("stock_real_backward_D0", _D0, "stock", "mlp_teacher",
                    (("hidden", _HIDDEN0),)),
        _try_replay("whatif_megakernel_10xD0", _D_WHATIF, "auto",
                    "quadratic_whatif", (("d", _D_WHATIF),),
                    ring_dtype="bf16"),
    ]
    out["rlimit_demo"] = {
        "cap_gb": _CAP_BYTES / 2**30, "lam": _LAM, "steps": _STEPS,
        "trials": trials,
        "demonstrated_gain": (">=10x" if (not trials[0]["feasible"]
                                          and trials[1]["feasible"])
                              else "NOT demonstrated"),
    }
    for t in trials:
        emit(f"ring_feasibility/rlimit/{t['label']}",
             "feasible" if t["feasible"] else "OOM",
             f"d={t['d']:.0e} cap={_CAP_BYTES / 2**30:.1f}GB")
    emit("ring_feasibility/rlimit/gain",
         out["rlimit_demo"]["demonstrated_gain"],
         f"real backward dies at D0={_D0:.0e}; what-if replays 10*D0")

    out["claims"] = {
        "whatif_extends_feasible_d": gain > 10.0,
        "rlimit_gain_demonstrated":
            out["rlimit_demo"]["demonstrated_gain"] == ">=10x",
    }
    return [], out


register_cell(Cell(
    name="ring", result="ring_feasibility",
    title="Ring feasibility: stock vs what-if megakernel model-size limits",
    compute=compute, skip_quick=True,
    claims=derived_claims("whatif_extends_feasible_d",
                          "rlimit_gain_demonstrated")))
