"""Cell ``fig8`` — paper Fig. 8: training-time speed-up vs λ for hardsync /
1-softsync / λ-softsync at μ = 128 and μ = 4 (calibrated runtime model).

Pure analytic cell: no simulator runs, just the calibrated cost model, so
``compute`` is deterministic in its params.
"""

from __future__ import annotations

from repro.experiments.registry import Cell, Claim, emit, register_cell

LAMS = (1, 2, 4, 10, 18, 30)


def compute(**params):
    from repro.core import tradeoff as to

    hw = to.calibrate_to_baseline()
    out = {}
    for mu in (128, 4):
        base = to.training_time("base", "hardsync", mu, 1, hw)
        for proto, label in [("hardsync", "hardsync"),
                             ("softsync", "softsync1")]:
            for lam in LAMS:
                t = to.training_time("base", proto, mu, lam, hw)
                out[f"mu={mu}/{label}/lam={lam}"] = base / t
        # λ-softsync: the PS applies one update per gradient (λ× more
        # updates than 1-softsync) and each weight update stalls concurrent
        # pullWeights requests — the paper's μ=4/λ=30 runtime penalty.
        for lam in LAMS:
            wl = to.WorkloadModel()
            t = to.training_time("base", "softsync", mu, lam, hw, wl)
            t_svc = wl.model_bytes / hw.ps_service_bw + 2e-3
            penalty = 1.0 + (lam - 1) * t_svc / to.compute_time(mu, hw)
            out[f"mu={mu}/softsyncL/lam={lam}"] = base / (t * penalty)

    s128_1 = out["mu=128/softsync1/lam=30"]
    s128_h = out["mu=128/hardsync/lam=30"]
    emit("fig8/mu128/softsync1_speedup_30", f"{s128_1:.1f}", "")
    emit("fig8/mu128/softsync_beats_hardsync", s128_1 > s128_h,
         f"{s128_1:.1f}x vs {s128_h:.1f}x")
    s4_1 = out["mu=4/softsync1/lam=30"]
    s4_L = out["mu=4/softsyncL/lam=30"]
    emit("fig8/mu4/lambda_softsync_subdued", s4_L < s4_1,
         f"1-soft {s4_1:.1f}x vs L-soft {s4_L:.1f}x")
    return [], out


register_cell(Cell(
    name="fig8", result="fig8_speedup",
    title="Fig. 8: speed-up vs lambda per protocol",
    compute=compute,
    claims=(
        Claim("softsync_beats_hardsync",
              lambda d: (d["mu=128/softsync1/lam=30"]
                         > d["mu=128/hardsync/lam=30"])),
        Claim("lambda_softsync_subdued",
              lambda d: (d["mu=4/softsyncL/lam=30"]
                         < d["mu=4/softsync1/lam=30"])),
    )))
