"""Cell ``sim_engine`` — simulator engine throughput: legacy loop vs
compiled replay vs the batched sweep driver (DESIGN.md §4/§5).

Part 1 — per-run engines on the MLP stand-in at λ ∈ {8, 32, 128}, μ = 4
(the paper's small-minibatch sweet spot, Table 3), via the experiment
surface with ``engine="legacy"`` vs the default compiled trace/replay:

* ``1-softsync`` (c = λ) — the paper's Table-3 winner and the shape where
  the legacy loop hurts most: λ un-jitted ``grad_fn`` dispatches plus one
  host→device optimizer round-trip per update.
* ``(λ/4)-softsync`` (c = 4) — staleness-heavy: the replay ring buffer K
  grows to ~2n while per-update work stays fixed.
* ``λ-softsync`` (c = 1, Eq.-5 degenerate ≈ async) — maximal staleness:
  the ring buffer runs at its full K ≈ 2λ bound and the legacy loop pays
  one complete dispatch round-trip per single-gradient update.

Part 2 — the sweep headline: a 4-LR × 5-seed grid cell replayed as ONE
vmapped device program with one vectorized staging pass
(``run_sweep``/``core.engine.replay_batch``) vs the same grid executed as
sequential per-spec replays (``run_sweep(batch=False)``).

Timing protocol: per configuration both paths are warmed (jit + scan
compiles excluded — the sweep regime: one compile, many replays), then
timed best-of-N end-to-end through the public API on identical
RunConfig/seed grids (identical traces).  ``max_param_drift`` cross-checks
result equivalence on the benchmarked runs themselves.

Wall-clock throughput is machine-dependent, so the cell re-times on every
execution; only the drift/equivalence numbers are claim-checked.  The
``bench_guard`` cell consumes the throughput rows against its CI floors.
"""

from __future__ import annotations

import time

from repro.experiments.registry import Cell, Claim, emit, register_cell

LAMBDAS = (8, 32, 128)
MU = 4
MLP_D = 2762                    # mlp_teacher flat parameter count


def _wait(res):
    import jax.numpy as jnp
    jnp.asarray(res.params["w1"]).block_until_ready()
    return res


def _best_of(fn, repeats: int = 5):
    # min over repeats: discards scheduler noise on a shared CPU
    times, res = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        times.append(time.perf_counter() - t0)
    return min(times), res


def _bench_one(cfg, updates: int, warm_updates: int = 4,
               repeats: int = 5) -> dict:
    import jax.numpy as jnp

    from repro.experiments import ExperimentSpec
    from repro.experiments import run as run_spec
    from repro.launch.roofline import ring_bytes

    spec = ExperimentSpec(run=cfg, problem="mlp_teacher", steps=updates)
    legacy_spec = spec.replace(engine="legacy")

    _wait(run_spec(legacy_spec.replace(steps=warm_updates)))  # legacy warmup
    t_legacy, legacy = _best_of(lambda: _wait(run_spec(legacy_spec)), repeats)

    t0 = time.perf_counter()
    _wait(run_spec(spec))                                   # scan compile
    t_compile = time.perf_counter() - t0
    t_replay, compiled = _best_of(lambda: _wait(run_spec(spec)), repeats)

    drift = float(jnp.max(jnp.abs(
        jnp.asarray(legacy.params["w2"]) -
        jnp.asarray(compiled.params["w2"]))))
    K = compiled.staleness["ring_buffer_K"]
    return {
        "lambda": cfg.n_learners,
        "n_softsync": cfg.n_softsync,
        "c": cfg.gradients_per_update,
        "ring_buffer_K": K,
        "updates": updates,
        "legacy_updates_per_s": updates / t_legacy,
        "compiled_updates_per_s": updates / t_replay,
        "speedup": t_legacy / t_replay,
        "compile_s": t_compile,
        "max_param_drift": drift,
        "ring_bytes_total": ring_bytes(
            K, MLP_D, cfg.ring_dtype, cfg.optimizer)["total_bytes"],
    }


def _bench_sweep(updates: int = 60, lam: int = 32, mu: int = 1,
                 seeds: int = 5, repeats: int = 3) -> dict:
    """The batched-replay headline: 4 LRs × ``seeds`` seeds at 1-softsync
    (c = λ — the Table-3 winner shape) in the small-μ regime where per-slot
    staging dominates the hand-wired pipeline.  All grid points share one
    trace shape, so the whole cell is ONE vmapped scan."""
    import jax.numpy as jnp

    from repro.config import RunConfig
    from repro.experiments import ExperimentSpec, Sweep, run_sweep

    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                      minibatch=mu, base_lr=0.05,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=17),
        problem="mlp_teacher", steps=updates)
    sweep = Sweep.over(base, base_lr=[0.02, 0.05, 0.1, 0.2],
                       seed=range(seeds))

    def _wait_all(results):
        for r in results:
            jnp.asarray(r.params["w1"]).block_until_ready()
        return results

    _wait_all(run_sweep(sweep))                             # warm both paths
    _wait_all(run_sweep(sweep, batch=False))
    t_batch, batched = _best_of(lambda: _wait_all(run_sweep(sweep)), repeats)
    t_seq, seq = _best_of(
        lambda: _wait_all(run_sweep(sweep, batch=False)), repeats)
    drift = max(
        float(jnp.max(jnp.abs(jnp.asarray(a.params["w2"]) -
                              jnp.asarray(b.params["w2"]))))
        for a, b in zip(batched, seq))
    return {
        "grid": f"4xlr * {seeds}xseed",
        "runs": 4 * seeds,
        "protocol_shape": f"1-softsync lam={lam} c={lam} mu={mu}",
        "updates_per_run": updates,
        "sequential_s": t_seq,
        "batched_s": t_batch,
        "speedup": t_seq / t_batch,
        "max_param_drift": drift,
    }


def _bench_megakernel(updates: int = 96, lam: int = 32,
                      repeats: int = 5) -> dict:
    """Megakernel scan body vs the stock XLA gather/assemble/slice chain on
    the same trace and staged batches (DESIGN.md §12): both sides go
    through the driver's cached-trace + staged-minibatch path, so the
    ratio isolates the scan-body change — the fused read-update-write
    event with a donated (ring, state, residue) carry vs the undonated
    ``.at[slot].set`` chain.  Also times the bf16 compressed ring (same
    event count, half the ring bytes, error-feedback residue carried)."""
    import jax.numpy as jnp

    from repro.config import RunConfig
    from repro.experiments import ExperimentSpec
    from repro.experiments import run as run_spec
    from repro.launch.roofline import ring_bytes

    def cell(**kw):
        cfg = RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                        minibatch=MU, base_lr=0.05,
                        lr_policy="staleness_inverse", optimizer="momentum",
                        seed=17, **kw)
        return ExperimentSpec(run=cfg, problem="mlp_teacher", steps=updates)

    rows = {}
    ref = None
    for label, kw in (("xla_stock", {"ring_impl": "stock"}),
                      ("megakernel", {"ring_impl": "fused"}),
                      ("megakernel_bf16", {"ring_impl": "fused",
                                           "ring_dtype": "bf16"})):
        spec = cell(**kw)
        _wait(run_spec(spec))                               # compile + warm
        t, res = _best_of(lambda s=spec: _wait(run_spec(s)), repeats)
        K = res.staleness["ring_buffer_K"]
        rows[label] = {
            "updates_per_s": updates / t,
            "seconds": t,
            "ring_bytes_total": ring_bytes(
                K, MLP_D, spec.run.ring_dtype,
                spec.run.optimizer)["total_bytes"],
            "max_param_drift": (0.0 if ref is None else float(jnp.max(
                jnp.abs(jnp.asarray(ref.params["w2"]) -
                        jnp.asarray(res.params["w2"]))))),
        }
        if ref is None:
            ref = res
    out = {
        "protocol_shape": f"1-softsync lam={lam} c={lam} mu={MU}",
        "updates": updates,
        **{f"{k}_{m}": v for k, row in rows.items() for m, v in row.items()},
        "megakernel_vs_xla_ratio": (rows["megakernel"]["updates_per_s"]
                                    / rows["xla_stock"]["updates_per_s"]),
        "bf16_ring_bytes_saved": (rows["megakernel"]["ring_bytes_total"]
                                  - rows["megakernel_bf16"]
                                  ["ring_bytes_total"]),
    }
    return out


def _bench_whatif(updates: int = 96, d: int = 1_000_000,
                  repeats: int = 3) -> dict:
    """The what-if replay (in-kernel closed-form gradients, no staged
    data) vs the staged-gradient stock path on the same quadratic problem
    and trace.  Wall clock is ~parity (same FLOPs either way on CPU); the
    win is PEAK MEMORY — no (c, D) pulled/gradient matrices, a donated
    ring carry — which is what runs at ``configs/`` big-model D (the
    ``ring`` feasibility cell's limit study)."""
    import jax.numpy as jnp

    from repro.config import RunConfig
    from repro.experiments import ExperimentSpec
    from repro.experiments import run as run_spec
    from repro.launch.roofline import ring_bytes

    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=1, base_lr=0.02, optimizer="momentum", seed=11)
    args = (("d", d),)
    whatif = ExperimentSpec(run=cfg, problem="quadratic_whatif",
                            problem_args=args, steps=updates)
    stock = whatif.replace(run=cfg.replace(ring_impl="stock"))

    def wait_q(res):
        jnp.asarray(res.params["w"]).block_until_ready()
        return res

    wait_q(run_spec(whatif))
    t_whatif, rw = _best_of(lambda: wait_q(run_spec(whatif)), repeats)
    wait_q(run_spec(stock))
    t_stock, rs = _best_of(lambda: wait_q(run_spec(stock)), repeats)
    K = rw.staleness["ring_buffer_K"]
    drift = float(jnp.max(jnp.abs(jnp.asarray(rw.params["w"]) -
                                  jnp.asarray(rs.params["w"]))))
    return {
        "d": d, "updates": updates, "ring_buffer_K": K,
        "whatif_updates_per_s": updates / t_whatif,
        "staged_stock_updates_per_s": updates / t_stock,
        "vs_staged_ratio": t_stock / t_whatif,
        "max_param_drift": drift,
        "ring_bytes_total": ring_bytes(
            K, d, cfg.ring_dtype, cfg.optimizer)["total_bytes"],
    }


def compute(updates: int = 480):
    from repro.config import RunConfig

    out = {}
    for lam in LAMBDAS:
        for label, n in [("softsync_1", 1), ("softsync_quarter", lam // 4),
                         ("softsync_lambda", lam)]:
            cfg = RunConfig(protocol="softsync", n_softsync=n,
                            n_learners=lam, minibatch=MU, base_lr=0.05,
                            lr_policy="staleness_inverse",
                            optimizer="momentum", seed=17)
            row = _bench_one(cfg, updates)
            out[f"{label}_lambda_{lam}"] = row
            emit(f"sim_engine/{label}/lambda={lam}/updates_per_s",
                 f"legacy={row['legacy_updates_per_s']:.1f} "
                 f"compiled={row['compiled_updates_per_s']:.1f}",
                 f"speedup={row['speedup']:.1f}x c={row['c']} "
                 f"K={row['ring_buffer_K']} "
                 f"drift={row['max_param_drift']:.1e}")
    # scale the sweep cell's per-run budget with the engine rows' budget so
    # --quick stays quick
    sweep_row = _bench_sweep(updates=max(10, updates // 8))
    out["sweep_batched_vs_sequential"] = sweep_row
    emit("sim_engine/sweep_batched/4lr_x_5seed",
         f"sequential={sweep_row['sequential_s']:.2f}s "
         f"batched={sweep_row['batched_s']:.2f}s",
         f"speedup={sweep_row['speedup']:.1f}x "
         f"drift={sweep_row['max_param_drift']:.1e}")
    mk_row = _bench_megakernel(updates=max(24, updates // 5))
    out["megakernel_vs_xla"] = mk_row
    emit("sim_engine/megakernel_vs_xla",
         f"megakernel={mk_row['megakernel_updates_per_s']:.1f}up/s "
         f"xla={mk_row['xla_stock_updates_per_s']:.1f}up/s",
         f"ratio={mk_row['megakernel_vs_xla_ratio']:.2f}x "
         f"drift={mk_row['megakernel_max_param_drift']:.1e}")
    emit("sim_engine/megakernel_bf16_ring",
         f"{mk_row['megakernel_bf16_updates_per_s']:.1f}up/s",
         f"ring_bytes={mk_row['megakernel_bf16_ring_bytes_total']} "
         f"(saves {mk_row['bf16_ring_bytes_saved']}) "
         f"drift={mk_row['megakernel_bf16_max_param_drift']:.1e}")
    whatif_row = _bench_whatif(updates=max(24, updates // 5))
    out["whatif_quadratic"] = whatif_row
    emit("sim_engine/whatif_quadratic",
         f"{whatif_row['whatif_updates_per_s']:.1f}up/s at "
         f"D={whatif_row['d']}",
         f"staged={whatif_row['staged_stock_updates_per_s']:.1f}up/s "
         f"ratio={whatif_row['vs_staged_ratio']:.2f}x "
         f"ring={whatif_row['ring_bytes_total']/1e6:.0f}MB")
    return [], out


register_cell(Cell(
    name="sim_engine", result="sim_engine_bench",
    title="Engine throughput: legacy vs compiled vs batched sweep",
    compute=compute,
    claims=(
        Claim("engine_rows_drift_small",
              lambda d: all(v["max_param_drift"] < 1e-3
                            for k, v in d.items()
                            if k.startswith("softsync_"))),
        Claim("sweep_drift_small",
              lambda d: (d["sweep_batched_vs_sequential"]["max_param_drift"]
                         < 1e-3)),
        Claim("megakernel_drift_small",
              lambda d: (d["megakernel_vs_xla"]["megakernel_max_param_drift"]
                         < 1e-3)),
        Claim("whatif_drift_small",
              lambda d: d["whatif_quadratic"]["max_param_drift"] < 1e-3),
    ),
    params={"updates": 480}, quick_params={"updates": 40}))
