"""Cell ``bench_guard`` — CI perf-trajectory guard: tiny-shape engine +
sweep benchmarks vs a checked-in floor (``benchmarks/ci_floor.json``).

    PYTHONPATH=src python -m benchmarks.bench_guard

Runs the ``sim_engine`` single-run cell (legacy vs compiled replay) and the
``sweep_batched_vs_sequential`` cell on a tiny shape (≲1 min), then fails
(exit 1) if any guarded metric regresses more than ``tolerance`` (default
30%) below its floor — the regression gate for the perf the compiled
engine and the batched sweep driver earned (DESIGN.md §4/§5).

Guarded metrics:

* ``compiled_updates_per_s``  — absolute compiled-replay throughput.  The
  floor is deliberately far below the dev-machine measurement (CI runners
  vary ~2-3×); this catches collapse-scale regressions, not noise.
* ``engine_speedup``          — compiled vs legacy on the same trace.
  Machine-relative, so the floor can sit much closer to the measurement.
* ``batched_sweep_speedup``   — one vmapped program vs sequential replays
  for a shape-compatible grid cell.  Also machine-relative.
* ``elastic_schedule_updates_per_s`` — host-side throughput of the
  membership-resolution pass in ``core/trace.schedule`` on a churny
  timeline (crash-restarts + leaves).  Absolute, wide margin like the
  compiled throughput: catches the schedule pass collapsing (e.g. the
  threshold refresh going quadratic), not runner noise.
* ``megakernel_vs_xla_ratio``  — fused megakernel scan body vs the stock
  XLA chain on the same trace + staged batches (DESIGN.md §12).
  Machine-relative; fails if the default replay path regresses vs what
  plain XLA delivers.
* ``distributed_replay_updates_per_s`` — ``placement="spmd"`` what-if
  throughput at S=4 on the emulated 8-device host (DESIGN.md §13),
  measured in a subprocess so the device-count flag lands before jax
  initializes.  Absolute with a wide margin: guards the SPMD path
  collapsing (a stray host sync, a collective in the shard-local what-if
  body), not the S=4/S=1 wall-clock ratio — that needs real cores and is
  reported, unguarded, by the ``distributed`` cell.
* ``serving_requests_per_s`` — serving-lane throughput (DESIGN.md §14).
  Absolute, wide margin.

Fresh measurements land in ``benchmarks/results/bench_guard.json`` (the CI
job uploads it as a workflow artifact).  To demonstrate the gate trips:

    PYTHONPATH=src python -m benchmarks.bench_guard --floor-scale 100

multiplies every floor 100× and must exit 1.  ``--write-floor`` rewrites
the floor file from fresh measurements × per-metric safety margins (for
maintainers after an intentional perf change).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.experiments.registry import (Cell, Claim, emit, register_cell,
                                        repo_root)

FLOOR_PATH = os.path.join(repo_root(), "benchmarks", "ci_floor.json")

# floor = measured × margin when --write-floor regenerates the file.
# Absolute throughput gets a wide margin (unknown CI hardware); ratios are
# machine-relative and stay tight.
FLOOR_MARGINS = {
    "compiled_updates_per_s": 0.25,
    "engine_speedup": 0.55,
    "batched_sweep_speedup": 0.55,
    "elastic_schedule_updates_per_s": 0.25,
    # megakernel scan body vs the stock XLA chain on the same trace +
    # staged batches (machine-relative; ~1.0 on CPU where the fused body's
    # win is donation/memory, not FLOPs) — fails if the megakernel path
    # ever regresses the hot loop vs what plain XLA delivers
    "megakernel_vs_xla_ratio": 0.55,
    # absolute spmd throughput on the emulated mesh: wide margin, same
    # rationale as compiled_updates_per_s (CI hardware + core count vary)
    "distributed_replay_updates_per_s": 0.25,
    # serving-lane throughput (snapshot capture + chunked request eval,
    # DESIGN.md §14): absolute, wide margin like the other throughputs —
    # catches the lane collapsing (a per-request recompile, the snapshot
    # carry forcing a host sync), not runner noise
    "serving_requests_per_s": 0.25,
}


def _bench_elastic_schedule(updates: int = 600, repeats: int = 3) -> dict:
    """Host-side wall clock of ``schedule()`` with a churny membership
    timeline (the membership-resolution pass: event interleaving, dropped
    pushes, λ(t) threshold refreshes, mask assembly).  Deliberately calls
    the UNCACHED ``schedule`` — ``schedule_cached`` would return the same
    trace object after the first repeat and time a dict lookup."""
    import time

    from repro.config import RunConfig
    from repro.core.trace import schedule
    from repro.membership import MembershipTimeline

    churn = MembershipTimeline(tuple(
        [(2.0 + 1.5 * i, i % 12, "crash") for i in range(8)]
        + [(3.0 + 1.5 * i, i % 12, "join") for i in range(8)]
        + [(30.0, 13, "leave"), (45.0, 13, "join")]))
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=16,
                    minibatch=4, seed=17, membership=churn)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        trace = schedule(cfg, updates)
        best = min(best, time.perf_counter() - t0)
    assert trace.valid is not None          # the elastic path actually ran
    return {"updates": updates, "seconds": best,
            "updates_per_s": updates / best}


def measure() -> dict:
    """The tiny-shape measurement cell (~1 min on a CI runner)."""
    from repro.config import RunConfig
    from repro.experiments.cells.distributed_replay import \
        measure as _measure_dist
    from repro.experiments.cells.sim_engine_bench import (_bench_megakernel,
                                                          _bench_one,
                                                          _bench_sweep)
    from repro.experiments.cells.train_while_serve import \
        measure as _measure_serve

    cfg = RunConfig(protocol="softsync", n_softsync=1, n_learners=16,
                    minibatch=4, base_lr=0.05,
                    lr_policy="staleness_inverse", optimizer="momentum",
                    seed=17)
    row = _bench_one(cfg, updates=48, repeats=3)
    sweep = _bench_sweep(updates=30, lam=16, seeds=3, repeats=3)
    elastic = _bench_elastic_schedule()
    mk = _bench_megakernel(updates=48, lam=16, repeats=3)
    dist = _measure_dist(updates=32, d=1_000_000, repeats=2, shards=(1, 4))
    serve = _measure_serve(updates=32, requests=512, repeats=2)
    return {
        "metrics": {
            "compiled_updates_per_s": row["compiled_updates_per_s"],
            "engine_speedup": row["speedup"],
            "batched_sweep_speedup": sweep["speedup"],
            "elastic_schedule_updates_per_s": elastic["updates_per_s"],
            "megakernel_vs_xla_ratio": mk["megakernel_vs_xla_ratio"],
            "distributed_replay_updates_per_s":
                dist["updates_per_s"]["spmd_s4"],
            "serving_requests_per_s": serve["requests_per_s"],
        },
        "engine_cell": row,
        "sweep_cell": sweep,
        "elastic_schedule_cell": elastic,
        "megakernel_cell": mk,
        "distributed_replay_cell": dist,
        "serving_cell": serve,
    }


def check(metrics: dict, floor: dict, floor_scale: float = 1.0) -> list:
    """Each guarded metric vs floor·scale·(1 − tolerance); returns rows."""
    tol = float(floor.get("tolerance", 0.30))
    rows = []
    for name, value in metrics.items():
        bound = floor["floors"][name] * floor_scale * (1.0 - tol)
        rows.append({"metric": name, "measured": value,
                     "floor": floor["floors"][name] * floor_scale,
                     "min_allowed": bound, "ok": value >= bound})
    return rows


def compute(floor_scale: float = 1.0, floor_path: str = None):
    measured = measure()
    metrics = measured["metrics"]
    for name, value in metrics.items():
        emit(f"bench_guard/{name}", f"{value:.2f}")
    with open(floor_path or FLOOR_PATH) as f:
        floor = json.load(f)
    rows = check(metrics, floor, floor_scale)
    for r in rows:
        status = "ok" if r["ok"] else "REGRESSED"
        print(f"[bench-guard] {r['metric']}: measured={r['measured']:.2f} "
              f"min_allowed={r['min_allowed']:.2f} -> {status}")
    return [], {"measured": measured, "floor": floor,
                "floor_scale": floor_scale, "checks": rows}


def main(argv=None) -> int:
    """CLI gate (``python -m benchmarks.bench_guard``): run the cell, write
    its envelope, exit 1 on any floor trip."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--floor", default=FLOOR_PATH,
                    help="floor file (default benchmarks/ci_floor.json)")
    ap.add_argument("--floor-scale", type=float, default=1.0,
                    help="multiply floors (e.g. 100 to prove the gate "
                         "trips; see module docstring)")
    ap.add_argument("--write-floor", action="store_true",
                    help="rewrite the floor file from fresh measurements "
                         "x safety margins")
    args = ap.parse_args(argv)

    if args.write_floor:
        measured = measure()
        metrics = measured["metrics"]
        floor = {
            "tolerance": 0.30,
            "floors": {k: round(v * FLOOR_MARGINS[k], 3)
                       for k, v in metrics.items()},
            "note": "bench-guard floors: fail if a metric drops >30% below "
                    "its floor. Absolute throughput floors carry a wide "
                    "margin vs the dev-machine measurement (CI hardware "
                    "varies); speedup ratios are machine-relative. "
                    "Regenerate: python -m benchmarks.bench_guard "
                    "--write-floor",
        }
        with open(args.floor, "w") as f:
            json.dump(floor, f, indent=1)
            f.write("\n")
        print(f"[bench-guard] wrote floors to {args.floor}")

    # only non-default params enter the cell hash: the default invocation
    # stays content-addressed identically across machines (an absolute
    # --floor path would poison the hash)
    params = {"floor_scale": args.floor_scale}
    if args.floor != FLOOR_PATH:
        params["floor_path"] = args.floor

    from repro.experiments.campaign import run_cell
    derived = run_cell("bench_guard", params=params, force=True)
    failed = [r for r in derived["checks"] if not r["ok"]]
    if failed:
        print(f"[bench-guard] FAIL: {len(failed)} metric(s) below the "
              f"floor - see benchmarks/results/bench_guard.json",
              file=sys.stderr)
        return 1
    print("[bench-guard] all perf floors hold")
    return 0


register_cell(Cell(
    name="bench_guard", result="bench_guard",
    title="CI perf floors: engine/sweep/schedule/megakernel/spmd/serving",
    compute=compute, deps=("sim_engine",), skip_quick=True,
    claims=(
        Claim("all_perf_floors_hold",
              lambda d: all(r["ok"] for r in d["checks"]),
              detail=lambda d: " ".join(r["metric"] for r in d["checks"]
                                        if not r["ok"])),
    ),
    params={"floor_scale": 1.0}))
