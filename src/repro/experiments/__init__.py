"""One experiment surface (DESIGN.md §5): declarative ``ExperimentSpec`` →
``run()`` → ``RunResult``, with ``Sweep`` grids batched on-device.

    from repro.config import RunConfig
    from repro.experiments import ExperimentSpec, Sweep, run, run_sweep

    spec = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=4, n_learners=30,
                      minibatch=32, base_lr=0.35,
                      lr_policy="staleness_inverse", optimizer="momentum"),
        problem="mlp_teacher", epochs=4, eval_every=50)
    res = run(spec)                        # schedule → compiled replay
    res.metrics["test_error"], res.runtime["simulated_time"]

    grid = Sweep.over(spec, seed=range(5), base_lr=[0.1, 0.35])
    results = run_sweep(grid)              # shape-compatible cells vmapped

Everything a run produces lands in the RunResult record (config echo,
final/curve metrics, trace-derived runtime axis, staleness statistics,
JSON round-trip) — the schema shared by ``benchmarks/results/*.json``.
"""

from repro.experiments.driver import execute, run, run_sweep
from repro.experiments.problems import (MLPProblem, get_problem,
                                        problem_names, register_problem,
                                        updates_for_epochs)
from repro.experiments.result import (RunResult, SCHEMA_VERSION, envelope,
                                      validate_record, validate_results_file)
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep

__all__ = [
    "ExperimentSpec", "Sweep", "RunResult", "run", "run_sweep", "execute",
    "MLPProblem", "register_problem", "get_problem", "problem_names",
    "updates_for_epochs",
    "SCHEMA_VERSION", "envelope", "validate_record", "validate_results_file",
]
