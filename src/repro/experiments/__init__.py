"""One experiment surface (DESIGN.md §5): declarative ``ExperimentSpec`` →
``run()`` → ``RunResult``, with ``Sweep`` grids batched on-device.

    from repro.config import RunConfig
    from repro.experiments import ExperimentSpec, Sweep, run, run_sweep

    spec = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=4, n_learners=30,
                      minibatch=32, base_lr=0.35,
                      lr_policy="staleness_inverse", optimizer="momentum"),
        problem="mlp_teacher", epochs=4, eval_every=50)
    res = run(spec)                        # schedule → compiled replay
    res.metrics["test_error"], res.runtime["simulated_time"]

    grid = Sweep.over(spec, seed=range(5), base_lr=[0.1, 0.35])
    results = run_sweep(grid)              # shape-compatible cells vmapped

Everything a run produces lands in the RunResult record (config echo,
final/curve metrics, trace-derived runtime axis, staleness statistics,
JSON round-trip, content-addressed ``spec_hash``) — the schema shared by
``benchmarks/results/*.json``.

On top sits the campaign layer (DESIGN.md §15): every paper table/figure is
a registered ``Cell`` (a named spec-graph + derive + claims), executed,
cached, and resumed by content address:

    PYTHONPATH=src python -m repro.experiments.campaign paper --dry-run
    from repro.experiments import run_cell
    derived = run_cell("fig4")
"""

from repro.experiments.driver import execute, run, run_sweep
from repro.experiments.spec_hash import (content_hash, spec_hash,
                                         spec_hash_from_echo)
from repro.experiments.problems import (MLPProblem, get_problem,
                                        problem_names, register_problem,
                                        updates_for_epochs)
from repro.experiments.result import (RunResult, SCHEMA_VERSION, envelope,
                                      validate_record, validate_results_file)
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep

__all__ = [
    "ExperimentSpec", "Sweep", "RunResult", "run", "run_sweep", "execute",
    "MLPProblem", "register_problem", "get_problem", "problem_names",
    "updates_for_epochs",
    "SCHEMA_VERSION", "envelope", "validate_record", "validate_results_file",
    "content_hash", "spec_hash", "spec_hash_from_echo",
    "Cell", "Claim", "get_cell", "cells_in", "run_cell", "run_campaign",
]


def __getattr__(name):
    # campaign/registry symbols resolve lazily: registry._load_cells()
    # imports every cells/ module, and eager import here would make
    # ``import repro.experiments`` pull the whole cell graph in.
    if name in ("Cell", "Claim", "get_cell", "cells_in", "register_cell",
                "cell_hash", "cell_for_result"):
        import repro.experiments.registry as _registry
        return getattr(_registry, name)
    if name in ("run_cell", "run_campaign", "cell_status"):
        import repro.experiments.campaign as _campaign
        return getattr(_campaign, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
