"""Canonical content hashing for ExperimentSpecs (DESIGN.md §15).

The campaign layer caches results by **content address**: a ``spec_hash``
is a sha256 (truncated to 16 hex chars) over a canonical form of the
spec's JSON echo, the results schema version, and the registered problem
identity.  Two constructions of the same experiment — live
``ExperimentSpec`` or a JSON-round-tripped record ``spec`` dict, today or
after new config fields grow defaults — must hash identically, so the
canonical form normalizes everything that is representation rather than
meaning:

* **dict ordering** — keys are sorted at serialization time;
* **tuple vs list** — tuples become lists (``echo()`` vs ``asdict`` vs
  JSON round-trips disagree here);
* **float formatting** — integral floats collapse to ints (``6.0`` and
  ``6`` are the same epoch budget; JSON writers disagree on the rest);
* **default materialization** — fields equal to their dataclass default
  are pruned, so a record written before a config field existed hashes
  the same as one written after (the new field's default is "absent").
  A *non-default* nested config (an attached serving fleet) keeps an
  explicit ``{}`` marker even when all its own fields are defaults —
  ``serving=FleetConfig()`` and ``serving=None`` are different
  experiments.

Flipping any semantic field of ``ExperimentSpec`` / ``RunConfig`` /
``FleetConfig`` must change the hash; ``tests/test_campaign.py`` audits
every field (the ``_FIELD_FLIPS`` idiom from the schedule-cache audit).

This module stays import-light: ``repro.config`` (which drags jax) loads
lazily on first hash, so ``repro.experiments.result`` can keep its
"records load without JAX" contract while stamping hashes on write.
"""

from __future__ import annotations

import functools
import hashlib
import json
import math
from typing import Any, Dict, Mapping, Optional

# Bumping the results schema (result.SCHEMA_VERSION) intentionally
# invalidates every content address — ``validate --migrate`` re-stamps.
HASH_LEN = 16

# ---------------------------------------------------------------------------
# problem identity: name@version, jax-free
# ---------------------------------------------------------------------------
# Versions live HERE (not on the problem objects) so hashing a stored
# record never has to import / construct the problem.  Bump a version when
# a problem's semantics change (task data, loss, eval) — every cached
# result that used it goes stale.  Problems registered dynamically without
# an explicit version hash as version 1 everywhere, which keeps the hash
# independent of whether the defining module happens to be imported.
_PROBLEM_VERSIONS: Dict[str, int] = {
    "mlp_teacher": 1,
    "quadratic_whatif": 1,
}


def register_problem_version(name: str, version: int = 1) -> None:
    prev = _PROBLEM_VERSIONS.get(name)
    if prev is not None and prev != version:
        raise ValueError(f"problem {name!r} already registered at version "
                         f"{prev}; re-register with the same version or "
                         f"pick a new name")
    _PROBLEM_VERSIONS[name] = int(version)


def problem_identity(name: Optional[str]) -> str:
    """``name@version`` for the hash payload; measure mode is ``-@0``."""
    if name is None:
        return "-@0"
    return f"{name}@{_PROBLEM_VERSIONS.get(name, 1)}"


# ---------------------------------------------------------------------------
# canonicalization
# ---------------------------------------------------------------------------
def canonical_value(x: Any) -> Any:
    """Representation-independent form: tuples→lists, numpy→python,
    integral floats→int, non-finite floats→strings (deterministic JSON)."""
    if isinstance(x, dict):
        return {str(k): canonical_value(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [canonical_value(v) for v in x]
    if isinstance(x, bool):
        return x
    if isinstance(x, float):
        if math.isnan(x):
            return "__nan__"
        if math.isinf(x):
            return "__inf__" if x > 0 else "__-inf__"
        if x.is_integer() and abs(x) < 2**53:
            return int(x)
        return x
    if hasattr(x, "item") and not isinstance(x, (str, bytes, int)):
        try:  # numpy scalars without importing numpy here
            return canonical_value(x.item())
        except Exception:
            return x
    return x


@functools.lru_cache(maxsize=1)
def _run_defaults() -> Dict[str, Any]:
    import dataclasses

    from repro.config import RunConfig
    return canonical_value(dataclasses.asdict(RunConfig()))


@functools.lru_cache(maxsize=1)
def _fleet_defaults() -> Dict[str, Any]:
    import dataclasses

    from repro.serve.fleet import FleetConfig
    return canonical_value(dataclasses.asdict(FleetConfig()))


# ExperimentSpec's own field defaults in echo() form.  Kept literal (the
# spec module imports the problem registry and with it jax); the field
# audit in tests/test_campaign.py fails if this drifts from the dataclass.
_SPEC_DEFAULTS: Dict[str, Any] = {
    "problem": None,
    "problem_args": {},
    "steps": None,
    "epochs": None,
    "duration": "config",
    "eval_every": 0,
    "engine": "auto",
    "tag": "",
}

# Nested configs whose parent default is None: when present they prune
# against their own type's defaults instead of surviving whole (so a new
# FleetConfig field with a default does not re-address old serving runs).
_AUX_DEFAULT_TREES = {
    "serving": _fleet_defaults,
}


def _prune(value: Dict[str, Any], defaults: Mapping[str, Any]
           ) -> Dict[str, Any]:
    out = {}
    for k, v in value.items():
        if k in defaults:
            dv = defaults[k]
            if v == dv:
                continue
            if isinstance(v, dict) and isinstance(dv, dict):
                out[k] = _prune(v, dv)          # {} survives: "non-default
                continue                        # but default-valued inside"
            if isinstance(v, dict) and dv is None and k in _AUX_DEFAULT_TREES:
                out[k] = _prune(v, _AUX_DEFAULT_TREES[k]())
                continue
        out[k] = v
    return out


def canonical_echo(echo: Mapping[str, Any]) -> Dict[str, Any]:
    """The hash-relevant residue of a spec echo: canonicalized, with
    default-valued fields pruned at every level."""
    c = canonical_value(dict(echo))
    defaults = dict(_SPEC_DEFAULTS)
    defaults["run"] = _run_defaults()
    return _prune(c, defaults)


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------
def content_hash(obj: Any) -> str:
    """sha256 (truncated) over the canonical JSON form of ``obj`` — the
    generic content address used for cell hashes and dry-run job specs."""
    blob = json.dumps(canonical_value(obj), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:HASH_LEN]


def spec_hash_from_echo(echo: Mapping[str, Any]) -> str:
    """The content address of one experiment, computed from its JSON echo
    (works identically on live ``spec.echo()`` and stored record specs)."""
    from repro.experiments.result import SCHEMA_VERSION
    payload = {
        "schema": SCHEMA_VERSION,
        "problem": problem_identity(echo.get("problem")),
        "spec": canonical_echo(echo),
    }
    return content_hash(payload)


def spec_hash(spec) -> str:
    """The content address of an :class:`ExperimentSpec`."""
    return spec_hash_from_echo(spec.echo())
