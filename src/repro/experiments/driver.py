"""The one experiment driver: ``run(spec) -> RunResult`` (DESIGN.md §5).

``run`` executes a declarative :class:`ExperimentSpec` end-to-end —
resolve problem and budget, schedule the arrival trace, replay it on the
compiled engine (or the legacy per-arrival oracle, or measure-only), and
fold trace + metrics into a :class:`RunResult` record.

``run_sweep`` executes a grid.  Its performance headline: grid points
whose traces are **shape-compatible** (same steps and c — e.g. a 5-seed ×
4-LR cell at fixed protocol shape) and share problem/optimizer/μ are
replayed as ONE vmapped device program (``core.engine.replay_batch``)
instead of sequential replays; everything else falls back to per-spec
:func:`run` semantics.  Results always come back in spec order and are
identical to sequential execution (``tests/test_experiments.py``).

``execute`` is the raw-callable escape hatch for callers with a
hand-written ``grad_fn``/``batch_fn`` instead of a registered problem
(the pre-PR-3 ``simulate_compiled`` / ``simulate_measure`` shims over it
are gone — this and the spec surface are the only entry points).
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.config import RunConfig
from repro.core.engine import replay, replay_batch
from repro.core.simulator import SimResult, simulate
from repro.core.trace import ArrivalTrace, schedule, schedule_cached
from repro.experiments.result import RunResult
from repro.experiments.spec import ExperimentSpec
from repro.experiments.sweep import Sweep
from repro.optim import spec_from_run


def execute(run_cfg: RunConfig, *,
            steps: int,
            grad_fn: Optional[Callable] = None,
            init_params=None,
            batch_fn: Optional[Callable] = None,
            eval_fn: Optional[Callable] = None,
            eval_every: int = 0,
            duration_sampler: Optional[Callable] = None,
            engine: str = "compiled",
            serve_batches=None,
            serve_eval_fn: Optional[Callable] = None) -> SimResult:
    """Run one simulation from raw callables (no problem registry).

    ``engine``: "compiled" (schedule + lax.scan replay; measure-only when
    ``grad_fn`` is None), "measure" (schedule pass only), or "legacy" (the
    per-arrival oracle loop in ``core/simulator.py``).
    """
    if engine == "legacy":
        return simulate(run_cfg, steps=steps, grad_fn=grad_fn,
                        init_params=init_params, batch_fn=batch_fn,
                        eval_fn=eval_fn, eval_every=eval_every,
                        duration_sampler=duration_sampler)
    if engine not in ("compiled", "measure"):
        raise ValueError(f"unknown engine {engine!r}")
    trace = schedule(run_cfg, steps, duration_sampler=duration_sampler)
    if grad_fn is None or engine == "measure":
        return SimResult(trace.clock_log(), trace.steps,
                         trace.simulated_time, trace.minibatches)
    return replay(trace, run_cfg, grad_fn=grad_fn, init_params=init_params,
                  batch_fn=batch_fn, eval_fn=eval_fn, eval_every=eval_every,
                  serve_batches=serve_batches, serve_eval_fn=serve_eval_fn)


# ---------------------------------------------------------------------------
# spec → RunResult
# ---------------------------------------------------------------------------
_SERIES_HEAD = 50


def _staleness_stats(trace: ArrivalTrace, run_cfg: RunConfig) -> Dict:
    """The Fig.-4 statistics block of every record, off the trace."""
    log = trace.clock_log()
    vals = log.all_staleness_values()
    expected = run_cfg.expected_staleness
    return {
        "mean": log.mean_staleness(),
        "min": float(vals.min()) if len(vals) else 0.0,
        "max": float(vals.max()) if len(vals) else 0.0,
        "expected": expected,
        "frac_exceeding_2n": log.fraction_exceeding(2 * max(1.0, expected)),
        "ring_buffer_K": trace.max_staleness + 1,
        "histogram": log.staleness_histogram().tolist(),
        "series_head": log.average_staleness_series()[:_SERIES_HEAD].tolist(),
    }


def _result(spec: ExperimentSpec, trace: ArrivalTrace,
            sim: Optional[SimResult], problem,
            replay_path: str = "sequential") -> RunResult:
    metrics: Dict = {}
    curve: List[Dict] = []
    params = None
    if sim is not None and sim.params is not None:
        params = sim.params
        metrics = dict(problem.eval_fn(params))
        curve = list(sim.history or [])
    runtime = {"simulated_time": trace.simulated_time,
               "updates": trace.steps,
               "minibatches": trace.minibatches,
               # which execution path produced this record: "batched"
               # (one vmapped program over a sweep cell), "sequential"
               # (per-spec compiled replay), "legacy", or "measure" —
               # the sweep fast path is a ~3.6× cliff, so the record
               # says which side of it this run landed on
               "replay_path": replay_path}
    if sim is not None and sim.serving is not None:
        # serving lane (DESIGN.md §14): headline numbers into metrics so
        # sweep tables pick them up, the full summary into runtime
        summary = sim.serving.summary()
        metrics["serving_accuracy"] = summary["accuracy"]
        metrics["serving_staleness_mean"] = summary["staleness_mean"]
        metrics["serving_latency_p99_s"] = summary["latency_p99_s"]
        runtime["serving"] = summary
    return RunResult(
        spec=spec.echo(),
        metrics=metrics,
        curve=curve,
        runtime=runtime,
        staleness=_staleness_stats(trace, spec.run),
        params=params,
        trace=trace,
    )


# staged-minibatch memo: repeated replays of the same (problem, trace, μ)
# grid point — benchmark loops, sweep repeats over cached traces — reuse
# the staged (steps, c, …) pytree instead of re-hashing the whole trace.
# Keys are object ids, so entries keep strong refs and re-check identity
# (an id can be recycled after gc); the bound keeps params-sized pytrees
# from accumulating in long-lived processes.
_STAGED_CACHE: Dict = {}
_STAGED_CACHE_MAX = 8


def _staged_cached(problem, trace, mu: int, build: Callable):
    key = (id(problem), id(trace), mu)
    hit = _STAGED_CACHE.get(key)
    if hit is not None and hit[0] is problem and hit[1] is trace:
        return hit[2]
    staged = build()
    if staged is not None:
        if len(_STAGED_CACHE) >= _STAGED_CACHE_MAX:
            _STAGED_CACHE.pop(next(iter(_STAGED_CACHE)))
        _STAGED_CACHE[key] = (problem, trace, staged)
    return staged


class _Job:
    """One grid point, scheduled: everything replay needs, plus its slot."""

    def __init__(self, index: int, spec: ExperimentSpec):
        self.index = index
        self.spec = spec
        self.engine = spec.resolved_engine()
        self.steps = spec.resolved_steps()
        self.problem = spec.resolve_problem()
        sampler = spec.duration_sampler()
        # built-in duration models are pure in (run, steps): share one
        # trace object across repeated replays of the same grid point
        # (and let the staged-batches cache key on its identity)
        self.trace = (schedule_cached(spec.run, self.steps)
                      if sampler is None
                      else schedule(spec.run, self.steps,
                                    duration_sampler=sampler))

    @property
    def batch_fn(self):
        return self.problem.batch_fn_for(self.spec.run.minibatch)

    def staged_batches(self):
        """The whole trace's minibatches via the problem's vectorized
        staging hook (None if the problem only offers per-slot batch_fn) —
        one hash/gather pass instead of a steps×c Python loop, feeding the
        batched replay's stacked (B, steps, c, …) inputs.  With learner
        groups the slot counters expand to the (steps, c, gs) member
        matrices (every member of a slot shares its push counter)."""
        stage = getattr(self.problem, "stage_minibatches", None)
        if stage is None:
            return None

        def build():
            members = self.trace.member_learners()
            if members is None:
                return stage(self.trace.learner, self.trace.mb_index,
                             self.spec.run.minibatch)
            mb = np.broadcast_to(self.trace.mb_index[:, :, None],
                                 members.shape)
            return stage(members, mb, self.spec.run.minibatch)

        return _staged_cached(self.problem, self.trace,
                              self.spec.run.minibatch, build)

    def batch_exclusion(self) -> Optional[str]:
        """Why this compiled grid point can never join a vmapped batch
        group — the ~3.6× sweep cliff ``run_sweep`` warns about — or None
        when it is batch-eligible (measure/legacy jobs are also None: they
        have no compiled fast path to fall off)."""
        if self.engine != "compiled" or self.problem is None:
            return None
        opt = spec_from_run(self.spec.run)
        if not opt.kernel_supported:
            return (f"optimizer {opt.optimizer!r} has no flat lane layout")
        if not self.trace.topology.is_trivial(self.spec.run.n_learners):
            # covers elastic grouped traces too: member_valid masks only
            # arise with group_size > 1, which is already non-trivial
            return (f"non-trivial topology (shards="
                    f"{self.spec.run.shards}, groups={self.spec.run.groups})")
        if self.spec.run.placement != "single":
            return (f"placement={self.spec.run.placement!r} replays on its "
                    f"own device mesh (no lane axis)")
        if self.trace.serving is not None:
            return ("serving lane (run.serving) adds a snapshot carry and "
                    "a post-scan request evaluation — no vmapped lane "
                    "layout")
        return None

    def batch_key(self):
        """Grid points with equal keys replay as one vmapped program:
        same problem (⇒ same grad_fn/init/batch shapes), same trace shape
        (steps, c), same optimizer event, same μ, eval schedule, and
        elasticity (masked elastic lanes batch together — the per-event
        coefficients are lane data).  Sharded/grouped topologies replay
        per-spec (no vmapped lane layout), so they never join a group."""
        if (self.engine != "compiled" or self.problem is None
                or self.batch_exclusion() is not None):
            return None
        opt = spec_from_run(self.spec.run)
        return (id(self.problem), self.steps, self.trace.c, self.trace.mode,
                opt, self.spec.run.minibatch, self.spec.eval_every,
                self.trace.valid is not None,
                # lanes must agree on ring storage/impl: a bf16 lane's
                # carry has a different dtype + residue layout, and
                # replay_batch rejects mixed groups
                self.spec.run.ring_impl, self.spec.run.ring_dtype)

    def run_single(self) -> RunResult:
        if self.engine == "measure":
            return _result(self.spec, self.trace, None, None,
                           replay_path="measure")
        if self.engine == "legacy":
            sim = simulate(self.spec.run, steps=self.steps,
                           grad_fn=self.problem.grad_fn,
                           init_params=self.problem.init,
                           batch_fn=self.batch_fn,
                           eval_fn=self.problem.eval_fn,
                           eval_every=self.spec.eval_every,
                           duration_sampler=self.spec.duration_sampler())
            return _result(self.spec, self.trace, sim, self.problem,
                           replay_path="legacy")
        # prefer whole-trace staged minibatches (one vectorized hash +
        # one device transfer per leaf) over the per-slot batch_fn loop —
        # the loop dominated sequential-replay wall clock before PR 6 —
        # and hand the problem's closed-form gradient (if any) to the
        # what-if replay path
        staged = self.staged_batches()
        serve_kw = {}
        if self.trace.serving is not None:
            stage_requests = getattr(self.problem, "stage_requests", None)
            request_metric = getattr(self.problem, "request_metric", None)
            if stage_requests is None or request_metric is None:
                raise ValueError(
                    f"run.serving is set but problem {self.spec.problem!r} "
                    f"has no serving hooks — implement "
                    f"stage_requests(serving_trace, fleet, seed) and "
                    f"request_metric(params, request_batch) (see "
                    f"MLPProblem), or drop serving from the RunConfig")
            serve_kw = {
                "serve_batches": stage_requests(self.trace.serving,
                                                self.spec.run.serving,
                                                seed=self.spec.run.seed),
                "serve_eval_fn": request_metric,
            }
        sim = replay(self.trace, self.spec.run,
                     grad_fn=self.problem.grad_fn,
                     init_params=self.problem.init,
                     batch_fn=None if staged is not None else self.batch_fn,
                     batches=staged,
                     eval_fn=self.problem.eval_fn,
                     eval_every=self.spec.eval_every,
                     flat_grad=getattr(self.problem, "flat_grad", None),
                     **serve_kw)
        return _result(self.spec, self.trace, sim, self.problem,
                       replay_path="sequential")


def run(spec: ExperimentSpec) -> RunResult:
    """Execute one ExperimentSpec.  THE public entry point."""
    return _Job(0, spec).run_single()


def run_sweep(sweep: Union[Sweep, Sequence[ExperimentSpec]], *,
              batch: bool = True) -> List[RunResult]:
    """Execute a grid of specs; results in spec order.

    ``batch=True`` (default) replays shape-compatible compiled grid points
    as one vmapped program per group; ``batch=False`` forces sequential
    per-spec execution (the equivalence oracle in tests/benchmarks).

    Falling off the batched fast path is a ~3.6× per-spec cliff, so it is
    never silent: compiled grid points that can't batch (non-kernel
    optimizer, non-trivial topology — which includes elastic grouped
    traces) raise ONE RuntimeWarning per sweep naming the reasons, and
    every RunResult
    records the path that produced it in ``runtime["replay_path"]``
    ("batched" | "sequential" | "legacy" | "measure").
    """
    specs = list(sweep)
    jobs = [_Job(i, s) for i, s in enumerate(specs)]
    results: List[Optional[RunResult]] = [None] * len(jobs)

    groups: Dict = {}
    if batch:
        reasons: Dict[str, int] = {}
        for job in jobs:
            why = job.batch_exclusion()
            if why is not None:
                reasons[why] = reasons.get(why, 0) + 1
            key = job.batch_key()
            if key is not None:
                groups.setdefault(key, []).append(job)
        if reasons:
            detail = "; ".join(f"{n} spec(s): {why}"
                               for why, n in sorted(reasons.items()))
            warnings.warn(
                f"run_sweep: {sum(reasons.values())} of {len(jobs)} "
                f"spec(s) fall back from the batched (vmapped) sweep path "
                f"to sequential per-spec replay — {detail}. Sequential "
                f"replay is ~3.6x slower per spec; see "
                f"runtime['replay_path'] on each RunResult.",
                RuntimeWarning, stacklevel=2)

    done = set()
    for key, members in groups.items():
        if len(members) < 2:
            continue
        staged = [j.staged_batches() for j in members]
        if any(s is None for s in staged):
            staged = None
        sims = replay_batch(
            [j.trace for j in members],
            [j.spec.run for j in members],
            grad_fn=members[0].problem.grad_fn,
            init_params=members[0].problem.init,
            batch_fns=(None if staged else [j.batch_fn for j in members]),
            batches=staged,
            eval_fn=members[0].problem.eval_fn,
            eval_every=members[0].spec.eval_every)
        for job, sim in zip(members, sims):
            results[job.index] = _result(job.spec, job.trace, sim,
                                         job.problem, replay_path="batched")
            done.add(job.index)

    for job in jobs:
        if job.index not in done:
            results[job.index] = job.run_single()
    return results
