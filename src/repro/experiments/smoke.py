"""CI smoke: one tiny sweep through the whole experiment surface (≤30 s).

    PYTHONPATH=src python -m repro.experiments.smoke

2 protocol cases × 2 seeds on the MLP teacher problem, batched where
shape-compatible, then cross-checked against sequential execution and the
record schema.  Exits non-zero on any mismatch — the fast-lane gate that
the declarative surface, the vmapped batch replay, and the RunResult
schema all still agree.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.config import RunConfig
from repro.experiments import (ExperimentSpec, RunResult, Sweep, run_sweep,
                               validate_record)


def main() -> int:
    t0 = time.time()
    base = ExperimentSpec(
        run=RunConfig(n_learners=8, minibatch=8, base_lr=0.2,
                      optimizer="momentum", seed=0),
        problem="mlp_teacher", steps=60, eval_every=30)
    sweep = Sweep.over(base, cases=[
        {"protocol": "softsync", "n_softsync": 2,
         "lr_policy": "staleness_inverse"},
        {"protocol": "async", "lr_policy": "per_gradient"},
    ], seed=[0, 1])
    batched = run_sweep(sweep)                 # 2 configs × 2 seeds
    sequential = run_sweep(sweep, batch=False)
    assert len(batched) == len(sequential) == 4
    for b, s in zip(batched, sequential):
        validate_record(b.record())
        np.testing.assert_allclose(b.metrics["test_error"],
                                   s.metrics["test_error"], atol=1e-6)
        assert b.record() == RunResult.from_json(b.to_json()).record()
        err = b.metrics["test_error"]
        assert np.isfinite(err) and 0.0 <= err <= 1.0
        print(f"[smoke] {b.tag}: test_error={err:.4f} "
              f"<sigma>={b.staleness['mean']:.2f} "
              f"time={b.runtime['simulated_time']:.1f}s")
    print(f"[smoke] ok: 4 runs (batched ≡ sequential, records valid) "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
