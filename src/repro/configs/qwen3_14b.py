"""Qwen3-14B — dense decoder with qk-norm and GQA.

Assigned: [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B].
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=1e6,
    source="Qwen3 [hf:Qwen/Qwen3-8B]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512)
