"""RWKV6 (Finch) 7B — attention-free with data-dependent decay.

Assigned: [ssm] 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
[arXiv:2404.05892].  Constant-size recurrent state ⇒ native long_500k.
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    source="RWKV-6 Finch [arXiv:2404.05892]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, d_ff=512, vocab_size=512,
    rwkv_head_dim=32)
