"""Llama-4 Maverick 400B-A17B — MoE with interleaved dense layers.

Assigned: [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 128 experts top-1 [hf:meta-llama/Llama-4-Scout-17B-16E].  Alternating
dense/MoE layers (unit = [attn, moe] × 24); early fusion heritage noted —
the text-only decoder is what the shapes exercise.
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn", "moe"),
    n_units=24,
    n_experts=128,
    top_k=1,
    moe_d_ff=8192,
    rope_theta=5e5,
    source="Llama-4 Maverick [hf:meta-llama/Llama-4-Scout-17B-16E]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=1, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, n_experts=4, top_k=1, moe_d_ff=512)
