"""Llama-3 405B — the largest dense assigned architecture.

Assigned: [dense] 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
[arXiv:2407.21783].  Requires FSDP-style 2-D parameter sharding
(data × model) to fit v5e HBM (DESIGN.md §9).
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    block_pattern=("attn",),
    rope_theta=5e5,
    source="Llama 3 [arXiv:2407.21783]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=512, n_heads=8, n_kv_heads=2,
    d_ff=1024, vocab_size=512)
