"""StarCoder2-7B — dense code model, GQA + RoPE.

Assigned: [dense] 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173].
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    block_pattern=("attn",),
    rope_theta=1e5,
    source="StarCoder2 [arXiv:2402.19173]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=288, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512)
