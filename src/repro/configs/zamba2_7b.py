"""Zamba2-7B — hybrid: Mamba2 blocks + weight-shared attention blocks.

Assigned: [hybrid] 81L d_model=3584 32H (GQA kv=32 = MHA) d_ff=14336
vocab=32000, ssm_state=64 [arXiv:2411.15242].  Repeating unit
[shared-attn, mamba2, mamba2] × 27 = 81 layers; the attention (+MLP) weights
are shared across all 27 units (Zamba2's shared transformer block).
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("shared_attn", "mamba", "mamba"),
    n_units=27,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    source="Zamba2 [arXiv:2411.15242]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, n_units=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, ssm_state=16, ssm_head_dim=32)
