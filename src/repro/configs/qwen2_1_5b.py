"""Qwen2-1.5B — dense decoder with QKV bias.

Assigned: [dense] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2407.10671].
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1e6,
    source="Qwen2 [arXiv:2407.10671]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512)
