"""HuBERT X-Large — audio: encoder-only transformer (wav2vec2 arch).

Assigned: [audio] 48L d_model=1280 16H (GQA kv=16 = MHA) d_ff=5120 vocab=504
[arXiv:2106.07447].  The conv feature extractor is a stub (precomputed frame
embeddings per the assignment); the model is the 48-layer bidirectional
encoder with a 504-way masked-prediction head.  Encoder-only ⇒ no decode
shapes (DESIGN.md §8).
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    block_pattern=("attn",),
    causal=False,
    frontend="audio",
    source="HuBERT X-Large [arXiv:2106.07447]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=64)
