"""Architecture registry: one module per assigned architecture.

Every module exports ``CONFIG`` (the exact assigned full-scale config, cited)
and ``SMOKE`` (a reduced same-family variant: ≤2–3 units, d_model ≤ 512,
≤ 4 experts) used by the CPU smoke tests.  ``get_config(name)`` /
``get_smoke(name)`` resolve by CLI ``--arch`` id.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "internvl2_2b",
    "hubert_xlarge",
    "rwkv6_7b",
    "qwen3_14b",
    "starcoder2_7b",
    "zamba2_7b",
    "llama4_maverick_400b_a17b",
    "qwen2_1_5b",
    "llama3_405b",
    "arctic_480b",
]

# CLI aliases with dashes/dots
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES["qwen2-1.5b"] = "qwen2_1_5b"
ALIASES["llama4-maverick-400b-a17b"] = "llama4_maverick_400b_a17b"


def _resolve(name: str) -> str:
    name = name.strip()
    if name in ARCH_IDS:
        return name
    if name in ALIASES:
        return ALIASES[name]
    norm = name.replace("-", "_").replace(".", "_")
    if norm in ARCH_IDS:
        return norm
    raise KeyError(f"unknown architecture {name!r}; known: {ARCH_IDS}")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """Sliding-window variant for long_500k on full-attention archs
    (DESIGN.md §8).  No-op for attention-free models."""
    if cfg.attention_free or cfg.sliding_window:
        return cfg
    return dataclasses.replace(cfg, sliding_window=window)
