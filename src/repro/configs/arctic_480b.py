"""Snowflake Arctic 480B — dense-MoE hybrid (dense residual ∥ 128-expert MoE).

Assigned: [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].
Every layer: attention + (dense SwiGLU d_ff=4864 in parallel with top-2 MoE).
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=("moe_dense",),
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    source="Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, moe_d_ff=256)
