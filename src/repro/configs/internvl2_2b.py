"""InternVL2-2B — VLM: InternViT frontend (stub) + InternLM2-1.8B decoder.

Assigned: [vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821].  The vision frontend supplies 256 precomputed patch
embeddings per image (stub per assignment); the decoder is InternLM2-style:
GQA, RoPE, SwiGLU.
"""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=("attn",),
    rope_theta=1e6,
    frontend="vision",
    n_prefix_embeds=256,
    source="InternVL2 [arXiv:2404.16821]; InternLM2 decoder",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_units=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, n_prefix_embeds=16)
