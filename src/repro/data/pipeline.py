"""Data pipeline: per-learner sharded sampling with background prefetch.

Mirrors the paper's Data Server (§3.2): each learner has an I/O thread that
prefetches the next mini-batch via random sampling, fully overlapped with
compute.  Here the "global file system" is a synthetic generator; the
prefetch overlap is a real double-buffered background thread.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.config import InputShape, ModelConfig
from repro.data.synthetic import lm_token_stream


def make_batch_fn(cfg: ModelConfig, batch: int, seq: int,
                  seed: int = 0) -> Callable[[int], Dict[str, np.ndarray]]:
    """Returns step -> batch dict matching the model's input layout."""

    def fn(step: int) -> Dict[str, np.ndarray]:
        if cfg.frontend == "audio":
            rng = np.random.default_rng(seed * 7919 + step)
            frames = rng.normal(0, 1, (batch, seq, cfg.d_model)
                                ).astype(np.float32)
            labels = rng.integers(0, cfg.vocab_size, (batch, seq)
                                  ).astype(np.int32)
            return {"frames": frames, "labels": labels,
                    "loss_mask": np.ones((batch, seq), np.float32)}
        if cfg.frontend == "vision":
            npfx = cfg.n_prefix_embeds
            rng = np.random.default_rng(seed * 7919 + step)
            b = lm_token_stream(cfg.vocab_size, batch, seq - npfx,
                                seed=seed, step=step)
            patches = rng.normal(0, 1, (batch, npfx, cfg.d_model)
                                 ).astype(np.float32)
            # labels over the full fused sequence; prefix positions masked out
            labels = np.concatenate(
                [np.zeros((batch, npfx), np.int32), b["labels"]], axis=1)
            mask = np.concatenate(
                [np.zeros((batch, npfx), np.float32), b["loss_mask"]], axis=1)
            return {"patches": patches, "tokens": b["tokens"],
                    "labels": labels, "loss_mask": mask}
        return lm_token_stream(cfg.vocab_size, batch, seq,
                               seed=seed, step=step)

    return fn


class PrefetchIterator:
    """Double-buffered background prefetch (the paper's I/O thread)."""

    def __init__(self, batch_fn: Callable[[int], Dict], steps: int,
                 prefetch: int = 2, to_device: bool = True):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._steps = steps
        self._to_device = to_device
        self._thread = threading.Thread(
            target=self._worker, args=(batch_fn,), daemon=True)
        self._thread.start()

    def _worker(self, batch_fn):
        for step in range(self._steps):
            self._q.put(batch_fn(step))
        self._q.put(None)

    def __iter__(self) -> Iterator[Dict]:
        while True:
            item = self._q.get()
            if item is None:
                return
            if self._to_device:
                item = jax.tree.map(jax.numpy.asarray, item)
            yield item


def shard_batch_for_learner(batch: Dict[str, np.ndarray], learner: int,
                            n_learners: int) -> Dict[str, np.ndarray]:
    """Split a global batch into the per-learner μ-sized slice."""
    def slc(x):
        per = x.shape[0] // n_learners
        return x[learner * per:(learner + 1) * per]
    return {k: slc(v) for k, v in batch.items()}
