"""Deterministic synthetic datasets.

CIFAR10/ImageNet are not available offline; the paper's claims are about
*optimization dynamics* (staleness vs. accuracy vs. μλ), so the benchmarks
use learnable synthetic tasks with the same protocol machinery:

* ``TeacherClassification`` — inputs from a Gaussian mixture, labels from a
  fixed random teacher MLP: a non-convex, learnable, CIFAR-like 10-class
  problem whose Bayes error is ~0 (generalization gap behaviour mirrors the
  paper's test-error axis).
* ``lm_token_stream`` — deterministic synthetic token sequences with local
  structure (orderful n-gram chains) for LM training examples.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class TeacherClassification:
    """Fixed random-teacher classification task."""
    n_features: int = 32
    n_classes: int = 10
    n_train: int = 8_192
    n_test: int = 2_048
    teacher_hidden: int = 64
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.W1 = rng.normal(0, 1.0 / np.sqrt(self.n_features),
                             (self.n_features, self.teacher_hidden))
        self.W2 = rng.normal(0, 1.0 / np.sqrt(self.teacher_hidden),
                             (self.teacher_hidden, self.n_classes))
        self.x_train = rng.normal(size=(self.n_train, self.n_features)
                                  ).astype(np.float32)
        self.x_test = rng.normal(size=(self.n_test, self.n_features)
                                 ).astype(np.float32)
        self.y_train = self._labels(self.x_train)
        self.y_test = self._labels(self.x_test)

    def _labels(self, x: np.ndarray) -> np.ndarray:
        h = np.tanh(x @ self.W1)
        return np.argmax(h @ self.W2, axis=-1).astype(np.int32)

    def _indices(self, learner: np.ndarray, step: np.ndarray, mu: int,
                 seed: int) -> np.ndarray:
        """splitmix64 indices for (…,) learner/step counter arrays → (…, mu).
        One hash implementation serves the scalar per-arrival path and the
        whole-trace vectorized staging path (bit-identical by construction)."""
        # (seed·M + learner)·M + step  mod 2^64, M = 1_000_003 — the seed
        # term folds in python-int space (arbitrarily large seeds wrap),
        # the counter terms in uint64 space (wrapping unsigned arithmetic)
        m = np.uint64(1_000_003)
        seed_term = np.uint64((seed * 1_000_003 * 1_000_003)
                              & 0xFFFFFFFFFFFFFFFF)
        base = (seed_term + learner.astype(np.uint64) * m
                + step.astype(np.uint64))
        z = base[..., None] + (np.arange(1, mu + 1, dtype=np.uint64)
                               * np.uint64(0x9E3779B97F4A7C15))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
        return (z % np.uint64(self.n_train)).astype(np.int64)

    def minibatch(self, learner: int, step: int, mu: int,
                  seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """getMinibatch: random sampling, deterministic per (learner, step).

        Indices come from a vectorized splitmix64 hash of the (seed,
        learner, step, slot) counter instead of a freshly constructed
        Generator — this is the simulators' per-arrival hot path (a
        ``default_rng`` construction costs ~80 μs, the hash ~2 μs)."""
        idx = self._indices(np.asarray(learner), np.asarray(step), mu, seed)
        return self.x_train[idx], self.y_train[idx]

    def minibatch_array(self, learner: np.ndarray, step: np.ndarray,
                        mu: int, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """All minibatches of a trace in ONE vectorized hash: ``learner`` /
        ``step`` are (steps, c) counter matrices; returns (steps, c, μ, F)
        inputs and (steps, c, μ) labels, element-for-element identical to
        per-slot :meth:`minibatch` calls (~75× cheaper per trace — the sweep
        driver's staging pass)."""
        idx = self._indices(np.asarray(learner), np.asarray(step), mu, seed)
        return self.x_train[idx], self.y_train[idx]

    @property
    def test_set(self):
        return self.x_test, self.y_test


def lm_token_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
                    step: int = 0) -> Dict[str, np.ndarray]:
    """Synthetic LM batch with learnable structure: each sequence follows a
    deterministic affine n-gram chain x_{t+1} = (a·x_t + b) mod V with
    per-sequence (a, b) — a next-token task a model can actually learn."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    a = rng.integers(1, vocab - 1, size=(batch, 1))
    b = rng.integers(0, vocab - 1, size=(batch, 1))
    x0 = rng.integers(0, vocab, size=(batch, 1))
    toks = np.zeros((batch, seq + 1), np.int64)
    toks[:, :1] = x0
    for t in range(seq):
        toks[:, t + 1] = (a[:, 0] * toks[:, t] + b[:, 0]) % vocab
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels,
            "loss_mask": np.ones((batch, seq), np.float32)}
