"""Configuration system for the repro framework.

Two levels of config:

* :class:`ModelConfig` — architecture hyperparameters, covering all six
  assigned families (dense / moe / ssm / hybrid / vlm / audio).  A model is
  described as a *repeating unit* of blocks (``block_pattern``) stacked
  ``n_units`` times; parameters for the units are stacked on a leading axis
  and the forward pass scans over them (``jax.lax.scan``) so that HLO size is
  independent of depth.

* :class:`RunConfig` — everything about a run that is not the model:
  synchronization protocol (the paper's contribution), learning-rate policy,
  mesh/sharding choices, micro-batching, data shape.

Configs are plain frozen dataclasses; ``src/repro/configs/<arch>.py`` each
export a ``CONFIG`` built from these.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

from repro.membership import MembershipTimeline
from repro.optim.spec import KERNEL_OPTIMIZERS
from repro.serve.fleet import FleetConfig

# replay weight-ring knobs (core/engine.py compiled replay, DESIGN.md §12)
RING_DTYPES = ("fp32", "bf16")
RING_IMPLS = ("auto", "pallas", "fused", "stock")

# replay placement (DESIGN.md §13): "single" replays the whole trace on one
# device; "spmd" shard_maps the scan over a (ps, learner) emulated device
# mesh with real cross-shard collectives.
PLACEMENTS = ("single", "spmd")

# ---------------------------------------------------------------------------
# Block types that can appear inside a repeating unit.
# ---------------------------------------------------------------------------
BLOCK_ATTN = "attn"                      # attention + dense MLP
BLOCK_MOE = "moe"                        # attention + mixture-of-experts MLP
BLOCK_MOE_DENSE_RESIDUAL = "moe_dense"   # attention + (dense MLP ∥ MoE)  [arctic]
BLOCK_MAMBA = "mamba"                    # Mamba2 SSD block
BLOCK_RWKV = "rwkv"                      # RWKV6 (Finch) block
BLOCK_SHARED_ATTN = "shared_attn"        # weight-shared attention block [zamba2]

VALID_BLOCKS = {
    BLOCK_ATTN,
    BLOCK_MOE,
    BLOCK_MOE_DENSE_RESIDUAL,
    BLOCK_MAMBA,
    BLOCK_RWKV,
    BLOCK_SHARED_ATTN,
}

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")

# per-minibatch compute-duration models for the simulator's schedule pass
# (core/trace.make_duration_sampler dispatches on these)
DURATION_MODELS = ("homogeneous", "two_speed", "pareto")

# The calibrated-duration grammar: "calibrated:<arch>[:<int>mb]" plugs the
# calibrated per-minibatch cost model of core/tradeoff.py into the schedule
# pass for arch ∈ CALIBRATED_ARCHS, optionally overriding the workload's
# model size (e.g. "calibrated:base:300mb" — the paper's Table-1 adversarial
# scenario).  ONE parser serves both layers that accept these strings:
# RunConfig.duration_model and ExperimentSpec.duration.
CALIBRATED_PREFIX = "calibrated:"
CALIBRATED_ARCHS = ("base", "adv", "adv*")


def parse_calibrated(duration: str):
    """``'calibrated:<arch>[:<int>mb]'`` → ``(arch, model_bytes | None)``;
    raises ValueError (with the shared grammar message) on anything else."""
    parts = duration[len(CALIBRATED_PREFIX):].split(":")
    err = ValueError(
        f"bad calibrated duration {duration!r}: expected "
        f"'calibrated:<arch>[:<int>mb]' with arch in {CALIBRATED_ARCHS}")
    if not duration.startswith(CALIBRATED_PREFIX) or len(parts) not in (1, 2):
        raise err
    arch = parts[0]
    if arch not in CALIBRATED_ARCHS:
        raise err
    if len(parts) == 1:
        return arch, None
    size = parts[1]
    if not (size.endswith("mb") and size[:-2].isdigit()):
        raise err
    return arch, float(size[:-2]) * 1e6


def validate_duration_model(value: str) -> None:
    """The ONE validator for ``RunConfig.duration_model``: a sampler name
    from DURATION_MODELS, or a calibrated-grammar string (accept-and-defer:
    ``core/trace.make_duration_sampler`` resolves it against the cost model
    of ``core/tradeoff.py``)."""
    if value.startswith(CALIBRATED_PREFIX):
        parse_calibrated(value)
        return
    if value not in DURATION_MODELS:
        raise ValueError(
            f"unknown duration_model {value!r}: expected one of "
            f"{DURATION_MODELS} or 'calibrated:<arch>[:<int>mb]' with arch "
            f"in {CALIBRATED_ARCHS}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  See src/repro/configs/ for instances."""

    name: str
    family: str                           # one of FAMILIES
    # --- transformer spine -------------------------------------------------
    n_layers: int                         # total layer count (for bookkeeping)
    d_model: int
    n_heads: int                          # query heads (0 for attn-free)
    n_kv_heads: int                       # GQA KV heads
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = (BLOCK_ATTN,)
    n_units: int = 0                      # stacked repeats of block_pattern
    d_head: int = 0                       # 0 -> d_model // n_heads
    # --- attention flavour --------------------------------------------------
    causal: bool = True                   # False for encoder-only (audio)
    qk_norm: bool = False                 # qwen3
    qkv_bias: bool = False                # qwen2
    rope_theta: float = 1e4
    sliding_window: int = 0               # 0 = full attention; >0 = window size
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                     # 0 -> d_ff
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # --- SSM (Mamba2) -------------------------------------------------------
    ssm_state: int = 0                    # N, state dim per head
    ssm_expand: int = 2                   # d_inner = expand * d_model
    ssm_head_dim: int = 64                # P
    ssm_chunk: int = 256                  # chunk length for SSD scan
    ssm_conv: int = 4                     # depthwise conv width
    # --- RWKV6 --------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 256
    # --- modality frontend (stub per spec) ----------------------------------
    frontend: str = "none"                # "none" | "audio" | "vision"
    n_prefix_embeds: int = 0              # vision patches / audio frames prepended
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"               # compute/param dtype
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- provenance ----------------------------------------------------------
    source: str = ""                      # paper / model-card citation

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        for b in self.block_pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block type {b!r}")
        if self.n_units == 0:
            object.__setattr__(
                self, "n_units",
                max(1, self.n_layers // max(1, len(self.block_pattern))))
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def effective_layers(self) -> int:
        return self.n_units * len(self.block_pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/head shard over
        the model axis (standard practice; padded ids are never emitted by
        the data pipeline)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def has_attention(self) -> bool:
        return any(b in (BLOCK_ATTN, BLOCK_MOE, BLOCK_MOE_DENSE_RESIDUAL,
                         BLOCK_SHARED_ATTN) for b in self.block_pattern)

    @property
    def attention_free(self) -> bool:
        return not self.has_attention

    @property
    def subquadratic(self) -> bool:
        """Can this model run very long contexts (long_500k)?"""
        if not self.has_attention:
            return True
        return self.sliding_window > 0

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    # -- analytic parameter count (used by roofline & runtime model) --------
    def param_count(self) -> int:
        M, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.d_head
        total = V * M                                    # embedding
        if not self.tie_embeddings:
            total += V * M                               # lm head
        per_unit = 0
        for b in self.block_pattern:
            if b in (BLOCK_ATTN, BLOCK_MOE, BLOCK_MOE_DENSE_RESIDUAL,
                     BLOCK_SHARED_ATTN):
                attn = M * (H * Dh) + 2 * M * (KV * Dh) + (H * Dh) * M
                if self.qkv_bias:
                    attn += (H + 2 * KV) * Dh
                per_unit_attn = attn + 2 * M             # 2 norms
                if b == BLOCK_ATTN:
                    per_unit += per_unit_attn + 3 * M * F
                elif b == BLOCK_MOE:
                    mf = self.moe_d_ff or F
                    per_unit += per_unit_attn + self.n_experts * 3 * M * mf \
                        + M * self.n_experts
                elif b == BLOCK_MOE_DENSE_RESIDUAL:
                    mf = self.moe_d_ff or F
                    per_unit += per_unit_attn + 3 * M * F \
                        + self.n_experts * 3 * M * mf + M * self.n_experts
                elif b == BLOCK_SHARED_ATTN:
                    # zamba2 shared block: parameters shared across units;
                    # counted once outside the loop.
                    per_unit += 2 * M
            elif b == BLOCK_MAMBA:
                Din = self.ssm_d_inner
                Hs, N = self.ssm_n_heads, self.ssm_state
                G = 1  # n_groups
                conv_dim = Din + 2 * G * N
                per_unit += (
                    M * (2 * Din + 2 * G * N + Hs)       # in_proj
                    + conv_dim * self.ssm_conv           # conv1d
                    + 2 * Hs                             # A_log, D
                    + Hs                                 # dt_bias
                    + Din                                # gated norm
                    + Din * M                            # out_proj
                    + 2 * M)                             # norms
            elif b == BLOCK_RWKV:
                P = self.rwkv_head_dim
                Hr = self.rwkv_n_heads
                lora = 64            # decay LoRA rank (models.rwkv)
                per_unit += (
                    5 * M * M        # r, k, v, gate, output
                    + 2 * M * lora   # data-dependent decay LoRA (A, B)
                    + Hr * P         # bonus u
                    + 7 * M          # token-shift mixes + ln_x + decay_w0
                    + 2 * M * F      # channel-mix squared-relu FFN
                    + 2 * M)
        total += per_unit * self.n_units
        if BLOCK_SHARED_ATTN in self.block_pattern:
            attn = M * (H * Dh) + 2 * M * (KV * Dh) + (H * Dh) * M
            total += attn + 3 * M * F                    # shared attn + its MLP
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        mf = self.moe_d_ff or self.d_ff
        dead = 0
        for b in self.block_pattern:
            if b in (BLOCK_MOE, BLOCK_MOE_DENSE_RESIDUAL):
                dead += (self.n_experts - self.top_k) * 3 * self.d_model * mf
        return int(self.param_count() - dead * self.n_units)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Run configuration — the paper's knobs live here.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything about a run besides the architecture.

    The paper's (σ, μ, λ) knobs:
      * ``protocol``     — "hardsync" | "softsync" | "async"
      * ``n_softsync``   — the splitting parameter n (protocol="softsync");
                           n = λ degenerates to async (Eq. 5).
      * ``n_learners``   — λ.  In the distributed runtime this is the size of
                           the learner (data) mesh axis; in the simulator it
                           is the number of simulated learner processes.
      * ``minibatch``    — μ, per-learner mini-batch size.
      * ``lr_policy``    — "const" | "staleness_inverse" (Eq. 6)
                           | "sqrt_scale" (hardsync α₀√(λμ/B))
                           | "per_gradient" (footnote-3 fine-grained variant).
    """

    protocol: str = "hardsync"
    n_softsync: int = 1
    n_learners: int = 1
    minibatch: int = 128
    base_lr: float = 0.001
    ref_batch: int = 128                  # B in α₀√(λμ/B)
    lr_policy: str = "const"
    momentum: float = 0.9
    optimizer: str = "momentum"           # "momentum" | "adagrad" | "adamw"
    weight_decay: float = 0.0
    warmstart_epochs: int = 0             # paper §5.5 hardsync warm start
    seed: int = 0
    # --- simulated cluster heterogeneity (trace schedule pass) --------------
    # Per-minibatch compute-duration model used by the event-queue schedule
    # (core/trace.py).  "homogeneous" is the paper's cluster (lognormal
    # jitter); "two_speed" splits learners into a slow and a fast tier;
    # "pareto" adds a heavy straggler tail (Dutta et al., "Slow and Stale
    # Gradients Can Win the Race").
    duration_model: str = "homogeneous"   # | "two_speed" | "pareto"
    slow_fraction: float = 0.25           # two_speed: fraction of slow learners
    slow_factor: float = 4.0              # two_speed: slowdown multiplier
    pareto_alpha: float = 2.5             # pareto: tail index (smaller=heavier)
    pareto_scale: float = 0.5             # pareto: straggler magnitude
    # --- PS topology (Rudra-base / adv / adv*; core/topology.py) ------------
    # shards: S parameter-server shards over the flat weight buffer (1 = the
    # flat Rudra-base server).  groups: G learner groups with group-level
    # gradient aggregation (0 = ungrouped — each learner pushes directly;
    # must divide λ otherwise).  shard_pull_jitter: per-(pull, shard)
    # completion skew in simulated seconds — updates landing between the
    # logical pull and a shard's completion are visible in that shard's
    # slice (shard-local staleness; 0 = consistent snapshot reads).
    shards: int = 1
    groups: int = 0
    shard_pull_jitter: float = 0.0
    # --- replay weight ring (compiled simulator hot loop; DESIGN.md §12) ----
    # ring_dtype: storage dtype of the (K, D) snapshot ring.  "bf16" halves
    # ring bytes and carries an fp32 error-feedback residue so the master
    # weight chain stays exactly the fp32 trajectory — the only
    # approximation is gradients being evaluated at quantized snapshots.
    # ring_impl: which scan body executes an update event — "auto" (Pallas
    # replay megakernel on TPU, its fused jnp twin elsewhere) or a forced
    # "pallas" / "fused" / "stock" ("stock" is the pre-megakernel
    # gather→update→set chain, the bitwise baseline; fp32 only).
    ring_dtype: str = "fp32"
    ring_impl: str = "auto"
    # --- replay placement (DESIGN.md §13) -----------------------------------
    # placement: "single" (default) compiles the replay scan for one device;
    # "spmd" shard_maps it over a make_sim_mesh(S, L) device mesh — each PS
    # shard's (K, Dp) ring lives on its own "ps"-axis device and the c
    # gradient slots of an update split across L "learner"-axis devices, with
    # cross-shard pulls / combine pushes as real all_gather/psum/ppermute
    # collectives.  spmd_learners: L (0 = auto — the largest divisor of c
    # that fits the visible device count).
    placement: str = "single"
    spmd_learners: int = 0
    # --- elastic membership (repro.membership; core/trace schedule pass) ----
    # membership: join/leave/crash-restart events per learner.  Resolves
    # entirely at schedule time: joins/leaves move the effective λ(t) that
    # n-softsync's splitting threshold c(t) = max(1, ⌊P(t)/n⌋) follows, a
    # crashed learner's in-flight push is dropped (a validity mask on the
    # trace), and a restarted learner re-pulls with fresh timestamps.  An
    # empty timeline reproduces the pre-elastic schedule bit-for-bit.
    # backup: Chen et al. backup learners (protocol="hardsync" only): each
    # round commits the first P − backup arrivals and cancels the rest —
    # hardsync's accuracy at near-async runtime, a first-class point on the
    # staleness axis.
    membership: MembershipTimeline = MembershipTimeline()
    backup: int = 0
    # --- distributed runtime ------------------------------------------------
    num_microbatches: int = 1
    remat: bool = True
    fsdp: bool = False                    # shard params over data axis too
    use_pallas: bool = False              # TPU fast-path kernels
    attn_impl: str = "chunked"            # "naive" | "chunked" | "pallas"
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    # unroll: trace structural loops as python loops instead of lax.scan.
    # Used by the roofline cost probes — XLA's cost_analysis counts a while
    # body ONCE regardless of trip count, so probes unroll (launch/roofline).
    unroll: bool = False
    # sequence-parallel residual (Korthikanti et al.) for head-parallel
    # archs: constrain the residual stream to this PartitionSpec between
    # blocks so Megatron's fp32 partial-sum all-reduces become bf16
    # reduce-scatter/all-gather pairs and norms/residuals shard over `model`
    # (§Perf iteration B1).  None = no constraint (CPU tests, seq-par mode).
    residual_spec: Optional[tuple] = None
    # --- train-while-serve (repro.serve; DESIGN.md §14) ---------------------
    # serving: a FleetConfig attaches a serving fleet to the run — N serving
    # replicas publishing weight versions from the PS ring under a
    # PublicationPolicy while inference traffic arrives.  The schedule pass
    # resolves publications/requests host-side (rng stream independent of
    # the arrival schedule) and the replay engine captures exactly the
    # published ring rows; None reproduces the pre-serving engine bit for
    # bit.
    serving: Optional[FleetConfig] = None

    def __post_init__(self):
        if self.protocol not in ("hardsync", "softsync", "async"):
            raise ValueError(f"unknown protocol {self.protocol!r}")
        if self.lr_policy not in ("const", "staleness_inverse", "sqrt_scale",
                                  "per_gradient"):
            raise ValueError(f"unknown lr_policy {self.lr_policy!r}")
        validate_duration_model(self.duration_model)
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.groups < 0:
            raise ValueError(f"groups must be >= 0, got {self.groups}")
        if self.groups and self.n_learners % self.groups != 0:
            raise ValueError(f"groups={self.groups} must divide "
                             f"n_learners={self.n_learners}")
        if self.shard_pull_jitter < 0:
            raise ValueError(f"shard_pull_jitter must be >= 0, "
                             f"got {self.shard_pull_jitter}")
        if not isinstance(self.membership, MembershipTimeline):
            # accept raw event sequences (or None) for convenience
            object.__setattr__(
                self, "membership",
                MembershipTimeline(tuple(self.membership or ())))
        self.membership.validate_for(self.n_learners)
        if self.backup < 0:
            raise ValueError(f"backup must be >= 0, got {self.backup}")
        if self.backup and self.protocol != "hardsync":
            raise ValueError(
                f"backup={self.backup} is the Chen et al. backup-learner "
                f"variant of hardsync; protocol {self.protocol!r} already "
                f"tolerates stragglers via staleness")
        if self.backup >= self.n_pushers:
            raise ValueError(
                f"backup={self.backup} must leave at least one committed "
                f"arrival per round (P = {self.n_pushers} pushers)")
        if self.ring_dtype not in RING_DTYPES:
            raise ValueError(f"unknown ring_dtype {self.ring_dtype!r}: "
                             f"expected one of {RING_DTYPES}")
        if self.ring_impl not in RING_IMPLS:
            raise ValueError(f"unknown ring_impl {self.ring_impl!r}: "
                             f"expected one of {RING_IMPLS}")
        if self.ring_dtype == "bf16":
            if self.ring_impl == "stock":
                raise ValueError(
                    "ring_dtype='bf16' needs the fused megakernel scan body "
                    "to carry the error-feedback residue; ring_impl='stock' "
                    "keeps the fp32 ring (use 'auto', 'fused' or 'pallas')")
            if self.optimizer not in KERNEL_OPTIMIZERS:
                raise ValueError(
                    f"ring_dtype='bf16' requires a kernel-supported "
                    f"optimizer {KERNEL_OPTIMIZERS}; {self.optimizer!r} "
                    f"replays on the pytree path with an fp32 ring")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {self.placement!r}: "
                             f"expected one of {PLACEMENTS}")
        if self.spmd_learners < 0:
            raise ValueError(f"spmd_learners must be >= 0, "
                             f"got {self.spmd_learners}")
        if self.spmd_learners and self.placement != "spmd":
            raise ValueError(
                f"spmd_learners={self.spmd_learners} only applies to "
                f"placement='spmd' (got placement={self.placement!r})")
        if self.placement == "spmd":
            if self.optimizer not in KERNEL_OPTIMIZERS:
                raise ValueError(
                    f"placement='spmd' needs a kernel-supported optimizer "
                    f"{KERNEL_OPTIMIZERS} (flat per-shard ring carries); "
                    f"{self.optimizer!r} replays on the pytree path")
            if (self.spmd_learners
                    and self.gradients_per_update % self.spmd_learners):
                raise ValueError(
                    f"spmd_learners={self.spmd_learners} must divide the "
                    f"update width c={self.gradients_per_update} so every "
                    f"learner device owns an equal slot block")
        if self.serving is not None:
            if not isinstance(self.serving, FleetConfig):
                raise ValueError(
                    f"serving must be a repro.serve.fleet.FleetConfig, "
                    f"got {type(self.serving).__name__}")
            if self.placement == "spmd":
                raise ValueError(
                    "serving is not supported with placement='spmd': the "
                    "serving lane captures published ring rows inside the "
                    "single-device replay scan, which shard_map splits into "
                    "per-shard (K, Dp) rings; replay the serving trace with "
                    "placement='single' (the default)")
            if self.shards > 1 and self.ring_impl == "stock":
                raise ValueError(
                    "serving with shards>1 needs the fused ring "
                    "(ring_impl='auto'/'fused'/'pallas'): the stock sharded "
                    "scan keeps a (S, K, Dp) ring with no flat row for a "
                    "publication to read")
        if self.elastic and self.lr_policy == "per_gradient":
            raise ValueError(
                "per_gradient LRs imply sequential optimizer events, which "
                "cannot mask an elastic timeline's cancelled pushes; use a "
                "scalar lr_policy with elastic membership")

    def replace(self, **kw) -> "RunConfig":
        """A copy with ``kw`` fields changed — ``dataclasses.replace`` with
        ``__post_init__`` validation re-run (the frozen-dataclass contract),
        so sweep builders don't import ``dataclasses`` everywhere."""
        return dataclasses.replace(self, **kw)

    @property
    def n_pushers(self) -> int:
        """Entities pushing gradients at the PS: with learner groups the
        group is the pusher (one aggregated gradient per group round),
        otherwise every learner pushes directly."""
        return self.groups if self.groups else self.n_learners

    @property
    def group_size(self) -> int:
        """Learners aggregated per push (1 ⇔ no effective grouping)."""
        return self.n_learners // self.n_pushers

    @property
    def elastic(self) -> bool:
        """True when the membership timeline actually changes the cluster."""
        return not self.membership.static

    @property
    def gradients_per_update(self) -> int:
        """c = ⌊P/n⌋ (Eq. 5 over the P pushing entities; P = λ ungrouped).
        hardsync: P − backup (each round commits the first P − backup
        arrivals; Chen et al.).  With an elastic timeline this is the
        *width bound* of a trace row — rows fired while λ(t) < λ commit
        fewer slots, masked on the trace."""
        if self.protocol == "hardsync":
            return max(1, self.n_pushers - self.backup)
        if self.protocol == "async":
            return 1
        return max(1, self.n_pushers // self.n_softsync)

    @property
    def expected_staleness(self) -> float:
        """⟨σ⟩ for LR modulation.  Paper: ⟨σ⟩ = n for pipelined n-softsync."""
        if self.protocol == "hardsync":
            return 0.0
        if self.protocol == "async":
            return float(self.n_pushers)
        return float(self.n_softsync)

    def learning_rate(self, measured_staleness: Optional[float] = None) -> float:
        """Resolve the paper's LR policies (Eq. 6 / hardsync scaling)."""
        if self.lr_policy == "const":
            return self.base_lr
        if self.lr_policy == "sqrt_scale":
            return self.base_lr * math.sqrt(
                self.n_learners * self.minibatch / self.ref_batch)
        sigma = (measured_staleness if measured_staleness is not None
                 else self.expected_staleness)
        return self.base_lr / max(1.0, sigma)


def validate_pairing(model: ModelConfig, shape: InputShape) -> Optional[str]:
    """Return a skip-reason string if (model, shape) must be skipped, else None.

    Skips mirror DESIGN.md §8: encoder-only models have no decode step;
    full-attention models need a sliding-window variant for long_500k (all of
    ours implement it, so only encoder-only skips remain).
    """
    if model.encoder_only and shape.kind == "decode":
        return "encoder-only architecture has no autoregressive decode step"
    if shape.name == "long_500k" and not model.subquadratic:
        return "full quadratic attention cannot serve 524k context"
    return None
