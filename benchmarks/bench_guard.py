"""DEPRECATED shim — the CI perf-floor gate now lives in the campaign
layer as cell ``bench_guard`` (src/repro/experiments/cells/bench_guard.py).
The CLI (``--floor``/``--floor-scale``/``--write-floor``, exit 1 on any
regressed floor) is unchanged and delegates to the cells module:

    PYTHONPATH=src python -m benchmarks.bench_guard
    PYTHONPATH=src python -m repro.experiments.campaign paper --only bench_guard
"""

from __future__ import annotations

import sys

from repro.experiments.cells.bench_guard import (FLOOR_MARGINS,  # noqa: F401
                                                 FLOOR_PATH, check, main,
                                                 measure)


def run(**kwargs) -> None:
    """benchmarks.run entry point (no argv: never inherit the driver's)."""
    from repro.experiments.campaign import run_cell
    run_cell("bench_guard", params=kwargs or None, force=True)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
