"""Paper Fig. 8: training-time speed-up vs λ for hardsync / 1-softsync /
λ-softsync at μ = 128 and μ = 4 (calibrated runtime model).

Validated claims:
  * 1-softsync ≈ λ-softsync ≥ hardsync at μ = 128;
  * at μ = 4 the λ-softsync speed-up is subdued vs 1-softsync (PS traffic);
  * hardsync fares worst at scale (barrier stragglers).
"""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.core import tradeoff as to

LAMS = (1, 2, 4, 10, 18, 30)


def run() -> dict:
    hw = to.calibrate_to_baseline()
    out = {}
    for mu in (128, 4):
        base = to.training_time("base", "hardsync", mu, 1, hw)
        for proto, label in [("hardsync", "hardsync"),
                             ("softsync", "softsync1")]:
            for lam in LAMS:
                t = to.training_time("base", proto, mu, lam, hw)
                out[f"mu={mu}/{label}/lam={lam}"] = base / t
        # λ-softsync: the PS applies one update per gradient (λ× more
        # updates than 1-softsync) and each weight update stalls concurrent
        # pullWeights requests — the paper's μ=4/λ=30 runtime penalty.
        for lam in LAMS:
            wl = to.WorkloadModel()
            t = to.training_time("base", "softsync", mu, lam, hw, wl)
            t_svc = wl.model_bytes / hw.ps_service_bw + 2e-3
            penalty = 1.0 + (lam - 1) * t_svc / to.compute_time(mu, hw)
            out[f"mu={mu}/softsyncL/lam={lam}"] = base / (t * penalty)
    save_json("fig8_speedup", out)

    s128_1 = out["mu=128/softsync1/lam=30"]
    s128_L = out["mu=128/softsyncL/lam=30"]
    s128_h = out["mu=128/hardsync/lam=30"]
    emit("fig8/mu128/softsync1_speedup_30", f"{s128_1:.1f}", "")
    emit("fig8/mu128/softsync_beats_hardsync", s128_1 > s128_h,
         f"{s128_1:.1f}x vs {s128_h:.1f}x")
    s4_1 = out["mu=4/softsync1/lam=30"]
    s4_L = out["mu=4/softsyncL/lam=30"]
    emit("fig8/mu4/lambda_softsync_subdued", s4_L < s4_1,
         f"1-soft {s4_1:.1f}x vs L-soft {s4_L:.1f}x")
    return out


if __name__ == "__main__":
    run()
