# One function per paper table/figure. Prints ``name,value,derived`` CSV and
# writes JSON artifacts to benchmarks/results/.
#
#   PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,table2,...]
#
# Mapping (DESIGN.md section 11):
#   fig4   -> staleness_distribution   (<sigma> ~= n, sigma <= 2n)
#   fig5   -> lr_modulation            (alpha0/n rescues convergence)
#   fig6_7 -> tradeoff_curves          ((sigma, mu, lambda) error/time curves)
#   fig8   -> speedup                  (protocol speed-ups vs lambda)
#   table1 -> overlap                  (comm/compute overlap base/adv/adv*)
#   table2 -> mu_lambda                (mu*lambda = const => const error)
#   table3_4 -> summary                (best configs + ImageNet analog)
#   kernels -> kernel_bench            (kernel fallbacks + PS traffic model)

from __future__ import annotations

import argparse
import sys
import time


BENCHES = [
    ("fig4", "benchmarks.staleness_distribution"),
    ("fig5", "benchmarks.lr_modulation"),
    ("fig6_7", "benchmarks.tradeoff_curves"),
    ("fig8", "benchmarks.speedup"),
    ("table1", "benchmarks.overlap"),
    ("table2", "benchmarks.mu_lambda"),
    ("table3_4", "benchmarks.summary"),
    ("kernels", "benchmarks.kernel_bench"),
    ("sim_engine", "benchmarks.sim_engine_bench"),  # legacy loop vs compiled replay
    ("topology", "benchmarks.topology_scaling"),  # Rudra base/adv/adv* runtime curves
    ("elastic", "benchmarks.elastic_churn"),  # churn + backup-hardsync curves
    ("serve", "benchmarks.train_while_serve"),  # staleness-budget serving fleet
    ("distributed", "benchmarks.distributed_replay"),  # spmd replay on the 8-device emulated mesh
    ("bench_guard", "benchmarks.bench_guard"),    # CI perf floor gate
    ("baselines", "benchmarks.baselines"),   # paper sec-6 related work + sec-3.3 accrual
    ("ring", "benchmarks.ring_feasibility"),  # what-if max-feasible-D limit study (~5 min)
    ("cnn", "benchmarks.cnn"),               # Fig-5 on the paper's own CNN (~9 min)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced epochs for CI-speed runs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark ids")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,value,derived")
    t00 = time.time()
    for bid, module in BENCHES:
        if only and bid not in only:
            continue
        if args.quick and bid in ("cnn", "ring"):
            continue   # minutes-long cells; run explicitly or without --quick
        mod = __import__(module, fromlist=["run"])
        t0 = time.time()
        kwargs = {}
        if args.quick and bid in ("fig5", "fig6_7", "table2", "table3_4",
                                  "baselines"):
            kwargs = {"epochs": 3}
        if args.quick and bid == "fig4":
            kwargs = {"steps": 1000}
        if args.quick and bid == "sim_engine":
            kwargs = {"updates": 40}
        if args.quick and bid == "distributed":
            kwargs = {"updates": 32, "d": 1_000_000, "repeats": 2}
        if args.quick and bid == "serve":
            kwargs = {"epochs": 0.5, "requests": 256}
        mod.run(**kwargs)
        print(f"_meta/{bid}/seconds,{time.time() - t0:.1f},")
        sys.stdout.flush()
    print(f"_meta/total/seconds,{time.time() - t00:.1f},")


if __name__ == "__main__":
    main()
