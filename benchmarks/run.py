# DEPRECATED shim over the campaign CLI (DESIGN.md section 15).  The paper
# grid is now a content-addressed spec-graph:
#
#   PYTHONPATH=src python -m repro.experiments.campaign paper [--only CELL]
#       [--force] [--quick] [--dry-run]
#
# This wrapper keeps the old invocation working:
#
#   PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,table2,...]
#
# Differences from the legacy driver, inherited from the campaign layer:
#   * cells whose checked-in envelope already matches their content hash are
#     skipped (pass --force for the old always-re-run behavior);
#   * --quick writes to benchmarks/results/quick/ instead of clobbering the
#     checked-in full-size results (the legacy driver overwrote them).
#
# Old benchmark ids map 1:1 onto cell names (fig4, fig5, fig6_7, fig8,
# table1, table2, table3_4, kernels, sim_engine, topology, elastic, serve,
# distributed, bench_guard, baselines, ring, cnn).

from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser(
        description="deprecated shim over `python -m "
                    "repro.experiments.campaign paper`")
    ap.add_argument("--quick", action="store_true",
                    help="reduced epochs for CI-speed runs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of cell names")
    ap.add_argument("--force", action="store_true",
                    help="re-run even when the envelope is CURRENT")
    args = ap.parse_args()

    print("[benchmarks.run] deprecated: use `PYTHONPATH=src python -m "
          "repro.experiments.campaign paper` (see EXPERIMENTS.md)",
          file=sys.stderr)
    from repro.experiments.campaign import main as campaign_main
    argv = ["paper"]
    if args.only:
        argv += ["--only", args.only]
    if args.quick:
        argv += ["--quick"]
    if args.force:
        argv += ["--force"]
    return campaign_main(argv)


if __name__ == "__main__":
    sys.exit(main())
