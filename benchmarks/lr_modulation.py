"""Paper Fig. 5: dividing the learning rate by ⟨σ⟩ = n (Eq. 6) rescues
convergence for the n-softsync protocol; α₀ at n = λ diverges.

Reproduced on the teacher-classification task with λ = 30 learners, driven
through the experiment surface (``ExperimentSpec`` → ``run_sweep``,
DESIGN.md §5); the compiled-engine equivalence with the per-arrival oracle
is pinned by ``tests/test_trace_engine.py``.  Also measures footnote 3's
per-gradient α₀/σ_g modulation (suggested, never evaluated in the paper).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_results
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, run_sweep


def run(epochs: int = 12, base_lr: float = 2.0) -> dict:
    """base_lr intentionally aggressive: the paper's Fig. 5 point is that the
    UNMODULATED rate diverges at high staleness while α₀/n converges."""
    lam, mu = 30, 32
    grid = [(n, policy)
            for n in [4, lam]
            for policy in ["const", "staleness_inverse", "per_gradient"]]
    specs = []
    for n, policy in grid:
        spec = ExperimentSpec(
            run=RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                          minibatch=mu, base_lr=base_lr, lr_policy=policy,
                          optimizer="sgd", seed=5),
            problem="mlp_teacher", epochs=epochs, tag=f"n={n}/{policy}")
        # error-vs-updates curve at ~10 points (per_gradient runs final-only,
        # matching the paper's footnote-3 spot check).  eval_every must
        # divide steps: the trailing remainder segment would compile a
        # second scan program AND lose the final curve point (replay only
        # evals on whole eval_every multiples) — pick the nearest divisor.
        if policy != "per_gradient":
            steps = spec.resolved_steps()
            target = max(1, steps // 10)
            eval_every = min((d for d in range(1, steps + 1)
                              if steps % d == 0),
                             key=lambda d: abs(d - target))
            spec = spec.replace(eval_every=eval_every)
        specs.append(spec)
    results = run_sweep(specs)

    out = {}
    for res in results:
        final = res.metrics["test_error"]
        out[res.tag] = {
            "final_test_error": final,
            "trace": res.curve,
            "mean_staleness": res.staleness["mean"],
        }
        emit(f"fig5/{res.tag}/test_error",
             f"{final:.4f}" if np.isfinite(final) else "diverged", "")
    # claims
    for n in [4, lam]:
        e_mod = out[f"n={n}/staleness_inverse"]["final_test_error"]
        e_const = out[f"n={n}/const"]["final_test_error"]
        better = (not np.isfinite(e_const)) or e_mod <= e_const + 1e-6
        emit(f"fig5/n={n}/modulation_helps", better,
             f"alpha0/n:{e_mod:.3f} vs alpha0:{e_const:.3f}")
        # footnote 3 (beyond-paper evaluation): per-gradient α₀/σ_g
        e_pg = out[f"n={n}/per_gradient"]["final_test_error"]
        emit(f"fig5fn3/n={n}/per_gradient_vs_mean", f"{e_pg:.4f}",
             f"mean-mod:{e_mod:.4f} "
             f"{'BETTER' if e_pg < e_mod else 'comparable/worse'}")
    save_results("fig5_lr_modulation", records=results, derived=out)
    return out


if __name__ == "__main__":
    run()
