"""Paper Fig. 5: dividing the learning rate by ⟨σ⟩ = n (Eq. 6) rescues
convergence for the n-softsync protocol; α₀ at n = λ diverges.

Reproduced on the teacher-classification task with λ = 30 learners, on the
compiled trace/replay engine (``core/engine.py``; oracle-equivalence with
the legacy loop pinned by ``tests/test_trace_engine.py``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import MLPProblem, emit, save_json, updates_for_epochs
from repro.config import RunConfig
from repro.core.engine import simulate_compiled as simulate


def run(epochs: int = 12, base_lr: float = 2.0) -> dict:
    """base_lr intentionally aggressive: the paper's Fig. 5 point is that the
    UNMODULATED rate diverges at high staleness while α₀/n converges."""
    prob = MLPProblem()
    lam, mu = 30, 32
    out = {}
    for n in [4, lam]:
        for policy in ["const", "staleness_inverse"]:
            run_cfg = RunConfig(protocol="softsync", n_softsync=n,
                                n_learners=lam, minibatch=mu,
                                base_lr=base_lr, lr_policy=policy,
                                optimizer="sgd", seed=5)
            steps = updates_for_epochs(epochs, mu, run_cfg.
                                       gradients_per_update,
                                       prob.task.n_train)
            res = simulate(run_cfg, steps=steps, grad_fn=prob.grad_fn,
                           init_params=prob.init,
                           batch_fn=prob.batch_fn_for(mu),
                           eval_fn=prob.eval_fn,
                           eval_every=max(1, steps // 10))
            final = prob.test_error(res.params)
            key = f"n={n}/{policy}"
            out[key] = {
                "final_test_error": final,
                "trace": res.history,
                "mean_staleness": res.clock_log.mean_staleness(),
            }
            emit(f"fig5/{key}/test_error",
                 f"{final:.4f}" if np.isfinite(final) else "diverged", "")
    # claims
    for n in [4, lam]:
        e_mod = out[f"n={n}/staleness_inverse"]["final_test_error"]
        e_const = out[f"n={n}/const"]["final_test_error"]
        better = (not np.isfinite(e_const)) or e_mod <= e_const + 1e-6
        emit(f"fig5/n={n}/modulation_helps", better,
             f"alpha0/n:{e_mod:.3f} vs alpha0:{e_const:.3f}")

    # ---- footnote 3 (beyond-paper evaluation): per-gradient α₀/σ_g --------
    # The paper suggests modulating by each gradient's OWN staleness instead
    # of the average, and predicts it should help; it never measures it.
    for n in [4, lam]:
        run_cfg = RunConfig(protocol="softsync", n_softsync=n,
                            n_learners=lam, minibatch=mu, base_lr=base_lr,
                            lr_policy="per_gradient", optimizer="sgd",
                            seed=5)
        steps = updates_for_epochs(epochs, mu,
                                   run_cfg.gradients_per_update,
                                   prob.task.n_train)
        res = simulate(run_cfg, steps=steps, grad_fn=prob.grad_fn,
                       init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
        e_pg = prob.test_error(res.params)
        out[f"n={n}/per_gradient"] = {"final_test_error": e_pg}
        e_mod = out[f"n={n}/staleness_inverse"]["final_test_error"]
        emit(f"fig5fn3/n={n}/per_gradient_vs_mean", f"{e_pg:.4f}",
             f"mean-mod:{e_mod:.4f} "
             f"{'BETTER' if e_pg < e_mod else 'comparable/worse'}")
    save_json("fig5_lr_modulation", out)
    return out


if __name__ == "__main__":
    run()
