"""DEPRECATED shim — the train-while-serve benchmark now lives in the
campaign layer as cell ``serve``
(src/repro/experiments/cells/train_while_serve.py):

    PYTHONPATH=src python -m repro.experiments.campaign paper --only serve

``measure`` (the bench-guard serving-throughput probe) is re-exported for
existing importers; new code should import from the cells module.
"""

from __future__ import annotations

from repro.experiments.cells.train_while_serve import measure  # noqa: F401


def run(**kwargs) -> None:
    from repro.experiments.campaign import run_cell
    run_cell("serve", params=kwargs or None, force=True)


if __name__ == "__main__":
    run()
