"""Train-while-serve on the calibrated Table-1 workload (DESIGN.md §14):
serving accuracy × staleness budget × tail latency, under replica churn.

The tradeoff the publication subsystem exists to measure: a serving fleet
refreshed from the PS weight ring under a ``staleness`` budget B sees
weights at most B versions old, so

* tight B  → requests score near the live training accuracy, but every
  refresh blocks the replica for ``publish_cost_s`` — more refreshes,
  fatter latency tail;
* loose B  → few refreshes and a clean tail, but requests are answered by
  stale weights and the mean serving accuracy drops toward the curve from
  B updates ago.

Scenarios: staleness budgets B ∈ {1, 4, 16, 64} on a 2-replica fleet, the
``on_demand`` policy (freshest possible: every read pays the publication),
and a replica crash-restart window on the B = 4 fleet.  All on the paper's
Table-1 adversarial setting (1-softsync, λ = 16, μ = 4, 300 MB calibrated
runtime), multi-seed.  Training is bitwise-independent of the fleet
(pinned in ``tests/test_publication.py``), so every scenario shares one
accuracy trajectory per seed — the benchmark asserts that too.

Results land in ``benchmarks/results/train_while_serve.json`` (RunResult
records + derived claims), surfaced by ``benchmarks/summary.py``; the
``measure()`` cell feeds the ``serving_requests_per_s`` CI floor in
``benchmarks/bench_guard.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, save_results, updates_for_epochs
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, Sweep, run_sweep
from repro.experiments import run as run_spec
from repro.serve.fleet import FleetConfig
from repro.serve.publication import PublicationPolicy

LAM = 16
MU = 4
EPOCHS = 2.0
MODEL_MB = 300            # Table-1 adversarial model size
DURATION = f"calibrated:base:{MODEL_MB}mb"
SEEDS = (0, 1, 2)
BUDGETS = (1, 4, 16, 64)
REQUESTS = 1024           # per scenario cell (rate sized to the horizon)
REQUEST_SAMPLES = 32


def _steps(run_cfg: RunConfig, epochs: float) -> int:
    from repro.experiments import get_problem
    dataset = get_problem("mlp_teacher").dataset_size
    return updates_for_epochs(epochs, MU, run_cfg.gradients_per_update,
                              dataset, group_size=run_cfg.group_size)


def _fleet(horizon: float, requests: int, policy: PublicationPolicy,
           membership=()) -> FleetConfig:
    """Fleet sized to the calibrated horizon: traffic covers the whole run,
    a publication blocks ~H/640 (visible at B = 1 where refreshes are per
    update, negligible at B = 64), service times keep the queue subcritical
    so p99 reflects publication stalls, not saturation."""
    return FleetConfig(replicas=2, policy=policy,
                       request_rate=requests / horizon,
                       request_samples=REQUEST_SAMPLES,
                       publish_cost_s=horizon / 640.0,
                       service_base_s=2.5e-4 * horizon,
                       service_per_sample_s=1e-6 * horizon,
                       membership=membership)


def _stats(rows) -> dict:
    acc = [r.metrics["serving_accuracy"] for r in rows]
    errs = [r.metrics["test_error"] for r in rows]
    summaries = [r.runtime["serving"] for r in rows]
    return {
        "serving_accuracy_mean": float(np.mean(acc)),
        "serving_accuracy_std": float(np.std(acc)),
        "test_errors": [float(e) for e in errs],
        "staleness_mean": float(np.mean(
            [s["staleness_mean"] for s in summaries])),
        "staleness_max": int(max(s["staleness_max"] for s in summaries)),
        "latency_p50_s": float(np.mean(
            [s["latency_p50_s"] for s in summaries])),
        "latency_p99_s": float(np.mean(
            [s["latency_p99_s"] for s in summaries])),
        "refreshes_mean": float(np.mean(
            [s["n_refreshes"] for s in summaries])),
        "n_dropped": int(sum(s["n_dropped"] for s in summaries)),
    }


def run_bench(epochs: float = EPOCHS, requests: int = REQUESTS) -> dict:
    soft = RunConfig(protocol="softsync", n_softsync=1, n_learners=LAM,
                     minibatch=MU, base_lr=0.05,
                     lr_policy="staleness_inverse", optimizer="momentum")
    steps = _steps(soft, epochs)
    # horizon for traffic/churn sizing: a dry (measure-mode) schedule
    dry = run_spec(ExperimentSpec(run=soft, steps=steps, duration=DURATION))
    horizon = dry.runtime["simulated_time"]

    def spec(fleet: FleetConfig, tag: str) -> ExperimentSpec:
        return ExperimentSpec(run=soft.replace(serving=fleet),
                              problem="mlp_teacher", steps=steps,
                              duration=DURATION, tag=tag)

    churn = ((0.30 * horizon, 1, "crash"), (0.55 * horizon, 1, "join"))
    scenarios = {
        **{f"budget{b}": spec(_fleet(horizon, requests,
                                     PublicationPolicy(max_version_lag=b)),
                              f"budget{b}")
           for b in BUDGETS},
        "on_demand": spec(_fleet(horizon, requests,
                                 PublicationPolicy(kind="on_demand")),
                          "on_demand"),
        "budget4_churn": spec(_fleet(horizon, requests,
                                     PublicationPolicy(max_version_lag=4),
                                     membership=churn),
                              "budget4_churn"),
    }

    records, stats = [], {}
    for name, sp in scenarios.items():
        rows = run_sweep(Sweep.over(sp, seed=SEEDS))
        records.extend(rows)
        stats[name] = _stats(rows)
        emit(f"train_while_serve/{name}",
             f"acc={stats[name]['serving_accuracy_mean']:.4f}",
             f"stale={stats[name]['staleness_mean']:.1f} "
             f"p99={stats[name]['latency_p99_s']:.2f}s "
             f"refreshes={stats[name]['refreshes_mean']:.0f}")

    acc = {b: stats[f"budget{b}"]["serving_accuracy_mean"] for b in BUDGETS}
    p99 = {b: stats[f"budget{b}"]["latency_p99_s"] for b in BUDGETS}
    ref = {b: stats[f"budget{b}"]["refreshes_mean"] for b in BUDGETS}
    noise = max(max(stats[f"budget{b}"]["serving_accuracy_std"]
                    for b in BUDGETS), 1e-3)
    pairs = list(zip(BUDGETS, BUDGETS[1:]))
    claims = {
        # the accuracy-vs-budget tradeoff, monotone along the budget axis:
        # every tightening of B buys serving accuracy (within the seed
        # band), and the endpoints are separated beyond it
        "accuracy_monotone_in_budget":
            all(acc[a] >= acc[b] - noise for a, b in pairs)
            and acc[BUDGETS[0]] > acc[BUDGETS[-1]] + noise,
        # what freshness costs: tighter budgets refresh strictly more and
        # the publication stalls surface in the tail
        "refreshes_strictly_decreasing":
            all(ref[a] > ref[b] for a, b in pairs),
        "fresh_serving_pays_latency":
            p99[BUDGETS[0]] > p99[BUDGETS[-1]],
        # on_demand is the freshness ceiling: zero version lag, accuracy
        # at or above the tightest scheduled budget
        "on_demand_is_freshest":
            stats["on_demand"]["staleness_mean"] == 0.0
            and (stats["on_demand"]["serving_accuracy_mean"]
                 >= acc[BUDGETS[0]] - noise),
        # budgets hold under replica churn (the restart re-publishes before
        # serving again), and the surviving replica keeps the fleet up
        "budget_holds_under_churn":
            stats["budget4_churn"]["staleness_max"] <= 4
            and stats["budget4_churn"]["n_dropped"] == 0,
        # training is bitwise-independent of the fleet: one test-error
        # trajectory per seed across every scenario (exact equality)
        "training_unperturbed_by_serving":
            all(s["test_errors"] == stats["budget1"]["test_errors"]
                for s in stats.values()),
    }
    for k, v in claims.items():
        emit(f"train_while_serve/claims/{k}", v)

    derived = {
        "lambda": LAM, "mu": MU, "epochs": epochs, "model_mb": MODEL_MB,
        "seeds": list(SEEDS), "budgets": list(BUDGETS),
        "updates": steps, "horizon_s": horizon, "requests": requests,
        "scenarios": stats, "claims": claims, "noise_band": noise,
    }
    save_results("train_while_serve", records=records, derived=derived)
    return derived


def measure(updates: int = 48, requests: int = 1024,
            repeats: int = 3) -> dict:
    """The bench-guard cell: wall-clock throughput of the serving lane
    (snapshot capture in the scan + the chunked vmapped request
    evaluation), requests sized to dominate the tiny training replay.
    Absolute, so the CI floor carries a wide margin."""
    import time

    from repro.core.engine import replay
    from repro.core.trace import schedule
    from repro.experiments import get_problem

    prob = get_problem("mlp_teacher")
    base = RunConfig(protocol="softsync", n_softsync=1, n_learners=16,
                     minibatch=4, base_lr=0.05,
                     lr_policy="staleness_inverse", optimizer="momentum",
                     seed=17)
    horizon = schedule(base, updates).simulated_time
    cfg = base.replace(serving=FleetConfig(
        replicas=2, policy=PublicationPolicy(max_version_lag=4),
        request_rate=requests / horizon, request_samples=32))
    trace = schedule(cfg, updates)
    batches = prob.stage_requests(trace.serving, cfg.serving, seed=cfg.seed)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        sim = replay(trace, cfg, grad_fn=prob.grad_fn,
                     init_params=prob.init,
                     batch_fn=prob.batch_fn_for(cfg.minibatch),
                     serve_batches=batches,
                     serve_eval_fn=prob.request_metric)
        assert sim.serving.request_metric.shape[0] == trace.serving.n_requests
        best = min(best, time.perf_counter() - t0)
    n = trace.serving.n_requests
    return {"updates": updates, "requests": n, "seconds": best,
            "requests_per_s": n / best}


# benchmarks.run drives modules via their ``run`` attribute
run = run_bench

if __name__ == "__main__":
    run_bench()
