"""Paper Table 1: communication overlap for Rudra-base / -adv / -adv* in the
adversarial scenario (μ = 4, 300 MB model, ~60 learners).

Paper: base 11.52 %, adv 56.75 %, adv* 99.56 %.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.core import tradeoff as to


def run() -> dict:
    wl = to.WorkloadModel(model_bytes=300e6)
    out = {}
    paper = {"base": 0.1152, "adv": 0.5675, "adv*": 0.9956}
    for arch in ("base", "adv", "adv*"):
        o = to.communication_overlap(arch, 4, 60, wl=wl)
        out[arch] = {"overlap": o, "paper": paper[arch]}
        emit(f"table1/{arch}/overlap", f"{o:.4f}", f"paper:{paper[arch]}")
    ordered = out["base"]["overlap"] < out["adv"]["overlap"] \
        < out["adv*"]["overlap"]
    emit("table1/ordering_base<adv<adv*", ordered, "")
    emit("table1/adv*_near_full_overlap", out["adv*"]["overlap"] > 0.95, "")
    save_json("table1_overlap", out)
    return out


if __name__ == "__main__":
    run()
