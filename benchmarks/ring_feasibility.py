"""DEPRECATED shim — this benchmark now lives in the campaign layer as
cell ``ring`` (src/repro/experiments/cells/ring_feasibility.py):

    PYTHONPATH=src python -m repro.experiments.campaign paper --only ring

``run(**kwargs)`` is kept so old invocations keep working; it forces a
re-run of the cell (the legacy script always re-ran) with any kwargs
forwarded as cell params.  The campaign CLI adds content-addressed
caching, resume, and claim checks on top — prefer it.
"""

from __future__ import annotations


def run(**kwargs) -> None:
    from repro.experiments.campaign import run_cell
    run_cell("ring", params=kwargs or None, force=True)


if __name__ == "__main__":
    run()
