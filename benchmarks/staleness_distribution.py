"""Paper Fig. 4: average staleness ⟨σ⟩ per update and the σ distribution.

Validated claims:
  (a) 1-softsync / 2-softsync: ⟨σ⟩ stays ≈ 1 / 2; σ ∈ {0..2}/{0..4}.
  (b) λ-softsync (λ = 30): ⟨σ⟩ ≈ 30 and P(σ > 2n) < 1e-4.

Runs through the experiment surface in **measure mode** (DESIGN.md §5): an
``ExperimentSpec`` with ``problem=None`` executes the schedule pass alone
and the RunResult's ``staleness`` block carries the Fig.-4 statistics
(⟨σ⟩, σ extremes, P(σ > 2n), ring-buffer K, histogram, ⟨σ⟩-series head).
A second sweep exercises the beyond-paper duration models (two-speed
heterogeneous cluster and Pareto-tail stragglers, Dutta et al.) at fixed
(λ, n) — the scenario axis the legacy per-arrival loop was too slow for.
"""

from __future__ import annotations

from benchmarks.common import emit, save_results
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, Sweep, run_sweep


def run(steps: int = 4000) -> dict:
    lam = 30
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_learners=lam, minibatch=128,
                      seed=11),
        steps=steps)
    ns = [1, 2, 4, lam]
    results = run_sweep(Sweep.over(base, n_softsync=ns))
    out = {}
    for n, res in zip(ns, results):
        st = res.staleness
        row = {
            "n": n,
            "mean_staleness": st["mean"],
            "sigma_min": st["min"],
            "sigma_max": st["max"],
            "ring_buffer_K": st["ring_buffer_K"],
            "frac_exceeding_2n": st["frac_exceeding_2n"],
            "series_head": st["series_head"],
            "histogram": st["histogram"],
        }
        out[f"softsync_{n}"] = row
        claim = (abs(row["mean_staleness"] - n) <= max(0.6, 0.15 * n)
                 and row["frac_exceeding_2n"] < 1e-3)
        emit(f"fig4/softsync_n={n}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"claim<sigma>≈n:{'PASS' if claim else 'FAIL'}")
        emit(f"fig4/softsync_n={n}/frac_sigma>2n",
             f"{row['frac_exceeding_2n']:.5f}", "paper:<1e-4")

    # ---- beyond-paper: straggler scenarios at fixed (λ, n) -----------------
    n = 4
    scen = Sweep.over(
        base.replace(run=base.run.replace(n_softsync=n)),
        cases=[
            {"duration_model": "homogeneous", "tag": "homogeneous"},
            {"duration_model": "two_speed", "slow_fraction": 0.25,
             "slow_factor": 4.0, "tag": "two_speed"},
            {"duration_model": "pareto", "pareto_alpha": 1.5,
             "pareto_scale": 1.0, "tag": "pareto"},
        ])
    scen_results = run_sweep(scen)
    for res in scen_results:
        model = res.tag
        st = res.staleness
        row = {
            "mean_staleness": st["mean"],
            "sigma_max": st["max"],
            "frac_exceeding_2n": st["frac_exceeding_2n"],
            "simulated_time": res.runtime["simulated_time"],
        }
        out[f"scenario_{model}"] = row
        emit(f"fig4scenario/{model}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"sigma_max={row['sigma_max']:.0f} "
             f"time={row['simulated_time']:.0f}s")
    save_results("fig4_staleness", records=results + scen_results,
                 derived=out)
    return out


if __name__ == "__main__":
    run()
