"""Paper Fig. 4: average staleness ⟨σ⟩ per update and the σ distribution.

Validated claims:
  (a) 1-softsync / 2-softsync: ⟨σ⟩ stays ≈ 1 / 2; σ ∈ {0..2}/{0..4}.
  (b) λ-softsync (λ = 30): ⟨σ⟩ ≈ 30 and P(σ > 2n) < 1e-4.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.config import RunConfig
from repro.core.simulator import simulate_measure


def run(steps: int = 4000) -> dict:
    lam = 30
    out = {}
    for n in [1, 2, 4, lam]:
        cfg = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                        minibatch=128, seed=11)
        res = simulate_measure(cfg, steps=steps)
        log = res.clock_log
        series = log.average_staleness_series()
        vals = log.all_staleness_values()
        row = {
            "n": n,
            "mean_staleness": log.mean_staleness(),
            "sigma_min": float(vals.min()),
            "sigma_max": float(vals.max()),
            "frac_exceeding_2n": log.fraction_exceeding(2 * n),
            "series_head": series[:50].tolist(),
            "histogram": log.staleness_histogram().tolist(),
        }
        out[f"softsync_{n}"] = row
        claim = (abs(row["mean_staleness"] - n) <= max(0.6, 0.15 * n)
                 and row["frac_exceeding_2n"] < 1e-3)
        emit(f"fig4/softsync_n={n}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"claim<sigma>≈n:{'PASS' if claim else 'FAIL'}")
        emit(f"fig4/softsync_n={n}/frac_sigma>2n",
             f"{row['frac_exceeding_2n']:.5f}", "paper:<1e-4")
    save_json("fig4_staleness", out)
    return out


if __name__ == "__main__":
    run()
