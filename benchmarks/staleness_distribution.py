"""Paper Fig. 4: average staleness ⟨σ⟩ per update and the σ distribution.

Validated claims:
  (a) 1-softsync / 2-softsync: ⟨σ⟩ stays ≈ 1 / 2; σ ∈ {0..2}/{0..4}.
  (b) λ-softsync (λ = 30): ⟨σ⟩ ≈ 30 and P(σ > 2n) < 1e-4.

Runs on the schedule pass of the compiled simulator (``core/trace.py``) —
the trace's vector-clock matrix gives Fig.-4 statistics vectorized, and its
``max_staleness`` is the ring-buffer bound K−1 the replay engine would use.
A second sweep exercises the beyond-paper duration models (two-speed
heterogeneous cluster and Pareto-tail stragglers, Dutta et al.) at fixed
(λ, n) — the scenario axis the legacy per-arrival loop was too slow for.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.config import RunConfig
from repro.core.trace import schedule


def run(steps: int = 4000) -> dict:
    lam = 30
    out = {}
    for n in [1, 2, 4, lam]:
        cfg = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                        minibatch=128, seed=11)
        trace = schedule(cfg, steps)
        log = trace.clock_log()
        series = log.average_staleness_series()
        vals = log.all_staleness_values()
        row = {
            "n": n,
            "mean_staleness": log.mean_staleness(),
            "sigma_min": float(vals.min()),
            "sigma_max": float(vals.max()),
            "ring_buffer_K": trace.max_staleness + 1,
            "frac_exceeding_2n": log.fraction_exceeding(2 * n),
            "series_head": series[:50].tolist(),
            "histogram": log.staleness_histogram().tolist(),
        }
        out[f"softsync_{n}"] = row
        claim = (abs(row["mean_staleness"] - n) <= max(0.6, 0.15 * n)
                 and row["frac_exceeding_2n"] < 1e-3)
        emit(f"fig4/softsync_n={n}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"claim<sigma>≈n:{'PASS' if claim else 'FAIL'}")
        emit(f"fig4/softsync_n={n}/frac_sigma>2n",
             f"{row['frac_exceeding_2n']:.5f}", "paper:<1e-4")

    # ---- beyond-paper: straggler scenarios at fixed (λ, n) -----------------
    n = 4
    for model, kw in [
        ("homogeneous", {}),
        ("two_speed", dict(slow_fraction=0.25, slow_factor=4.0)),
        ("pareto", dict(pareto_alpha=1.5, pareto_scale=1.0)),
    ]:
        cfg = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                        minibatch=128, seed=11, duration_model=model, **kw)
        trace = schedule(cfg, steps)
        log = trace.clock_log()
        row = {
            "mean_staleness": log.mean_staleness(),
            "sigma_max": float(trace.max_staleness),
            "frac_exceeding_2n": log.fraction_exceeding(2 * n),
            "simulated_time": trace.simulated_time,
        }
        out[f"scenario_{model}"] = row
        emit(f"fig4scenario/{model}/mean_staleness",
             f"{row['mean_staleness']:.2f}",
             f"sigma_max={row['sigma_max']:.0f} "
             f"time={row['simulated_time']:.0f}s")
    save_json("fig4_staleness", out)
    return out


if __name__ == "__main__":
    run()
