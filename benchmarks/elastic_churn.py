"""Elastic clusters on the calibrated Table-1 workload (DESIGN.md §7):
accuracy/runtime curves for (no churn | 10% crash-restart | backup-b
hardsync, b ∈ {0, 1, 4}).

The Chen et al. ("Revisiting Distributed Synchronous SGD") story on the
simulator: at a FIXED update budget, backup-b hardsync commits the first
λ − b arrivals per round and cancels the stragglers, so every round ends
at the (λ−b)-th order statistic of the same per-round duration draws
instead of the max — runtime strictly below b = 0, while each update still
averages λ − b gradients, so the accuracy cost is negligible for small b
(the ordering the paper's §6 cites as the synchronous answer to staleness).
The crash-restart scenario runs the same workload through a 10%-of-λ
crash + restart timeline on 1-softsync: dropped in-flight pushes and a
re-pull on restart, with the n-softsync threshold tracking λ(t).

Every scenario runs on the calibrated ``base`` architecture cost model in
the paper's Table-1 adversarial communication setting (μ = 4, 300 MB
model), multi-seed; results land in ``benchmarks/results/elastic_churn.json``
(RunResult records + derived claims), surfaced by ``benchmarks/summary.py``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_results, updates_for_epochs
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, Sweep, run_sweep
from repro.experiments import run as run_spec
from repro.membership import MembershipTimeline

LAM = 16
MU = 4
EPOCHS = 2.0
MODEL_MB = 300            # Table-1 adversarial model size
DURATION = f"calibrated:base:{MODEL_MB}mb"
SEEDS = (0, 1, 2)
BACKUPS = (0, 1, 4)
CRASH_FRACTION = 0.10     # 10% of λ crash-restarts
EVAL_EVERY = 32


def _steps(run_cfg: RunConfig) -> int:
    from repro.experiments import get_problem
    dataset = get_problem("mlp_teacher").dataset_size
    return updates_for_epochs(EPOCHS, MU, run_cfg.gradients_per_update,
                              dataset, group_size=run_cfg.group_size)


def _spec(run_cfg: RunConfig, steps: int, tag: str) -> ExperimentSpec:
    return ExperimentSpec(run=run_cfg, problem="mlp_teacher", steps=steps,
                          duration=DURATION, eval_every=EVAL_EVERY, tag=tag)


def _crash_timeline(horizon: float) -> MembershipTimeline:
    """10% of λ crash a quarter of the way in, restart after 20% of the
    horizon (timed off a dry no-churn schedule so the window is in-run)."""
    n_crash = max(1, int(round(CRASH_FRACTION * LAM)))
    victims = range(n_crash)
    return MembershipTimeline.crash_restart(
        victims, crash_at=0.25 * horizon, restart_after=0.20 * horizon)


def _mean_std(rows):
    errs = [r.metrics["test_error"] for r in rows]
    times = [r.runtime["simulated_time"] for r in rows]
    return {"test_error_mean": float(np.mean(errs)),
            "test_error_std": float(np.std(errs)),
            "train_s_mean": float(np.mean(times)),
            "train_s_std": float(np.std(times)),
            "curve": rows[0].curve}


def run_bench() -> dict:
    soft = RunConfig(protocol="softsync", n_softsync=1, n_learners=LAM,
                     minibatch=MU, base_lr=0.05,
                     lr_policy="staleness_inverse", optimizer="momentum")
    soft_steps = _steps(soft)
    # horizon for the churn window: a dry (measure-mode) schedule
    dry = run_spec(ExperimentSpec(run=soft, steps=soft_steps,
                                  duration=DURATION))
    churn = _crash_timeline(dry.runtime["simulated_time"])

    hard = RunConfig(protocol="hardsync", n_learners=LAM, minibatch=MU,
                     base_lr=0.05, lr_policy="sqrt_scale",
                     optimizer="momentum")
    # FIXED update budget across b (Chen et al. compare per iteration):
    # the runtime axis then isolates the straggler cancellation
    hard_steps = _steps(hard)

    scenarios = {
        "none": Sweep.over(_spec(soft, soft_steps, "none"), seed=SEEDS),
        "crash_restart": Sweep.over(
            _spec(soft.replace(membership=churn), soft_steps,
                  "crash_restart"), seed=SEEDS),
        **{f"hardsync_b{b}": Sweep.over(
            _spec(hard.replace(backup=b), hard_steps, f"hardsync_b{b}"),
            seed=SEEDS)
           for b in BACKUPS},
    }

    records, stats = [], {}
    for name, sweep in scenarios.items():
        rows = run_sweep(sweep)
        records.extend(rows)
        stats[name] = _mean_std(rows)
        emit(f"elastic_churn/{name}",
             f"err={stats[name]['test_error_mean']:.4f}",
             f"train_s={stats[name]['train_s_mean']:.0f} "
             f"std={stats[name]['test_error_std']:.4f}")

    t = {b: stats[f"hardsync_b{b}"]["train_s_mean"] for b in BACKUPS}
    e = {b: stats[f"hardsync_b{b}"]["test_error_mean"] for b in BACKUPS}
    # seed-to-seed spread: b = 0 hardsync is deterministic given the data
    # hashing (its trace is seed-independent), so the band comes from the
    # scenarios with real schedule stochasticity (which learners commit)
    noise = 2.0 * max(stats["hardsync_b0"]["test_error_std"],
                      stats["hardsync_b1"]["test_error_std"],
                      stats["none"]["test_error_std"], 1e-3)
    claims = {
        # the Chen et al. ordering: every backup level strictly buys
        # runtime (same seed ⇒ same round draws, lower order statistic)
        "backup_runtime_strictly_decreasing":
            t[4] < t[1] < t[0],
        # ...and b = 1 already recovers a large share of the b = 4 win
        # (the straggler tail is in the top order statistic)
        "backup1_buys_most_of_the_gap":
            (t[0] - t[1]) >= 0.35 * (t[0] - t[4]),
        # negligible accuracy cost at small b: within the seed noise band
        "backup1_accuracy_within_noise":
            abs(e[1] - e[0]) <= noise,
        # crash-restart churn: the run completes and converges in the same
        # regime as the static cluster (the elastic schedule is not a
        # degenerate trace)
        "crash_restart_converges":
            (stats["crash_restart"]["test_error_mean"]
             <= stats["none"]["test_error_mean"] + 0.05),
    }
    for k, v in claims.items():
        emit(f"elastic_churn/claims/{k}", v)

    derived = {
        "lambda": LAM, "mu": MU, "epochs": EPOCHS, "model_mb": MODEL_MB,
        "seeds": list(SEEDS), "backups": list(BACKUPS),
        "updates": {"softsync": soft_steps, "hardsync": hard_steps},
        "churn_timeline": [dataclass_row(ev) for ev in churn.events],
        "scenarios": stats, "claims": claims,
        "noise_band": noise,
    }
    save_results("elastic_churn", records=records, derived=derived)
    return derived


def dataclass_row(ev):
    return {"t": ev.t, "learner": ev.learner, "kind": ev.kind}


# benchmarks.run drives modules via their ``run`` attribute
run = run_bench

if __name__ == "__main__":
    run_bench()
