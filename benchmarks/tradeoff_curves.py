"""Paper Figs. 6/7: (σ, μ, λ) tradeoff curves — test error vs training time
for hardsync / 1-softsync / λ-softsync over the (μ, λ) grid.

Error axis: the compiled trace/replay engine driven through the experiment
surface (``run_sweep``; protocol-faithful staleness, oracle equivalence in
``tests/test_trace_engine.py``); time axis: the calibrated Rudra-base
runtime model (core/tradeoff.py).  Validated qualitative claims:
  * error grows with μλ along every contour;
  * reducing μ at fixed λ = max restores most of the hardsync-error gap;
  * training time falls monotonically with λ.
"""

from __future__ import annotations

from benchmarks.common import emit, save_results
from repro.config import RunConfig
from repro.core import tradeoff as to
from repro.experiments import ExperimentSpec, get_problem, run_sweep


def run(epochs: int = 6, base_lr: float = 0.35,
        mus=(4, 16, 64, 128), lams=(1, 4, 10, 30)) -> dict:
    hw = to.calibrate_to_baseline()
    specs, meta = [], []
    for proto, nfn in [("hardsync", lambda lam: 1),
                       ("softsync1", lambda lam: 1),
                       ("softsyncL", lambda lam: lam)]:
        base = "hardsync" if proto == "hardsync" else "softsync"
        policy = "sqrt_scale" if base == "hardsync" else "staleness_inverse"
        for mu in mus:
            for lam in lams:
                if lam == 1 and proto != "hardsync":
                    continue
                specs.append(ExperimentSpec(
                    run=RunConfig(protocol=base, n_softsync=nfn(lam),
                                  n_learners=lam, minibatch=mu,
                                  base_lr=base_lr, lr_policy=policy,
                                  ref_batch=128, optimizer="sgd", seed=7),
                    problem="mlp_teacher", epochs=epochs,
                    tag=f"{proto}/mu={mu}/lam={lam}"))
                meta.append((proto, base, mu, lam))
    results = run_sweep(specs)

    out = {}
    wl = to.WorkloadModel(dataset_size=get_problem("mlp_teacher").dataset_size,
                          epochs=epochs)
    for (proto, base, mu, lam), res in zip(meta, results):
        t = to.training_time("base", base, mu, lam, hw, wl)
        out[res.tag] = {"test_error": res.metrics["test_error"],
                        "train_time_s": t, "mu_lambda": mu * lam}

    # ---- claims -----------------------------------------------------------
    # error grows with μλ (compare smallest vs largest product, hardsync)
    small = out["hardsync/mu=4/lam=1"]["test_error"]
    large = out["hardsync/mu=128/lam=30"]["test_error"]
    emit("fig6/error_grows_with_mu_lambda", large > small,
         f"{small:.3f}->{large:.3f}")
    # reducing μ at λ=30 restores error (softsync λ-protocol)
    e_big = out["softsyncL/mu=128/lam=30"]["test_error"]
    e_small = out["softsyncL/mu=4/lam=30"]["test_error"]
    emit("fig7/small_mu_restores_error", e_small < e_big,
         f"mu128:{e_big:.3f} mu4:{e_small:.3f}")
    # time monotone in λ
    t1 = out["hardsync/mu=128/lam=1"]["train_time_s"]
    t30 = out["hardsync/mu=128/lam=30"]["train_time_s"]
    emit("fig6/time_falls_with_lambda", t30 < t1, f"{t1:.0f}s->{t30:.0f}s")
    save_results("fig6_7_tradeoff", records=results, derived=out)
    return out


if __name__ == "__main__":
    run()
