"""Paper Figs. 6/7: (σ, μ, λ) tradeoff curves — test error vs training time
for hardsync / 1-softsync / λ-softsync over the (μ, λ) grid.

Error axis: SGD-mode event simulator on the teacher task (protocol-faithful
staleness); time axis: the calibrated Rudra-base runtime model
(core/tradeoff.py).  Validated qualitative claims:
  * error grows with μλ along every contour;
  * reducing μ at fixed λ = max restores most of the hardsync-error gap;
  * training time falls monotonically with λ.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import MLPProblem, emit, save_json, updates_for_epochs
from repro.config import RunConfig
from repro.core import tradeoff as to
from repro.core.simulator import simulate


def _error_for(prob: MLPProblem, protocol: str, n: int, mu: int, lam: int,
               epochs: int, base_lr: float) -> float:
    policy = "sqrt_scale" if protocol == "hardsync" else "staleness_inverse"
    cfg = RunConfig(protocol=protocol, n_softsync=n, n_learners=lam,
                    minibatch=mu, base_lr=base_lr, lr_policy=policy,
                    ref_batch=128, optimizer="sgd", seed=7)
    steps = updates_for_epochs(epochs, mu, cfg.gradients_per_update,
                               prob.task.n_train)
    res = simulate(cfg, steps=steps, grad_fn=prob.grad_fn,
                   init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
    return prob.test_error(res.params)


def run(epochs: int = 6, base_lr: float = 0.35,
        mus=(4, 16, 64, 128), lams=(1, 4, 10, 30)) -> dict:
    prob = MLPProblem()
    hw = to.calibrate_to_baseline()
    out = {}
    for proto, nfn in [("hardsync", lambda lam: 1),
                       ("softsync1", lambda lam: 1),
                       ("softsyncL", lambda lam: lam)]:
        base = "hardsync" if proto == "hardsync" else "softsync"
        for mu in mus:
            for lam in lams:
                if lam == 1 and proto != "hardsync":
                    continue
                err = _error_for(prob, base, nfn(lam), mu, lam, epochs,
                                 base_lr)
                t = to.training_time("base", base, mu, lam, hw,
                                     to.WorkloadModel(
                                         dataset_size=prob.task.n_train,
                                         epochs=epochs))
                out[f"{proto}/mu={mu}/lam={lam}"] = {
                    "test_error": err, "train_time_s": t,
                    "mu_lambda": mu * lam}
    save_json("fig6_7_tradeoff", out)

    # ---- claims -----------------------------------------------------------
    # error grows with μλ (compare smallest vs largest product, hardsync)
    small = out["hardsync/mu=4/lam=1"]["test_error"]
    large = out["hardsync/mu=128/lam=30"]["test_error"]
    emit("fig6/error_grows_with_mu_lambda", large > small,
         f"{small:.3f}->{large:.3f}")
    # reducing μ at λ=30 restores error (softsync λ-protocol)
    e_big = out["softsyncL/mu=128/lam=30"]["test_error"]
    e_small = out["softsyncL/mu=4/lam=30"]["test_error"]
    emit("fig7/small_mu_restores_error", e_small < e_big,
         f"mu128:{e_big:.3f} mu4:{e_small:.3f}")
    # time monotone in λ
    t1 = out["hardsync/mu=128/lam=1"]["train_time_s"]
    t30 = out["hardsync/mu=128/lam=30"]["train_time_s"]
    emit("fig6/time_falls_with_lambda", t30 < t1, f"{t1:.0f}s->{t30:.0f}s")
    return out


if __name__ == "__main__":
    run()
