"""Rudra-base vs adv vs adv* runtime-vs-learners curves (paper §3.2/3.3,
Table 1 / Fig. 8 story) on the topology-aware simulator (DESIGN.md §6).

For each architecture and λ ∈ LAMBDAS, a fixed two-epoch workload in the
paper's *adversarial* communication scenario (μ = 4, 300 MB model — the
Table-1 setting where aggregation topology separates the architectures;
the CIFAR CNN itself is ~350 kB and comm-invisible) is scheduled through
the calibrated per-minibatch cost model of that architecture
(``core/tradeoff.py``: flat-PS ingest serialization for base, PS-tree fanout
for adv, fully-threaded overlap for adv*) with the matching structural
topology from ``Topology.for_arch`` (sharded PS for adv, sharded PS +
learner groups + pull skew for adv*).  The trace's event clock IS the
runtime axis: ``simulated_time`` of the last update is the paper's
training-time number.

A small sharded+grouped *replay* cell rides along to time the engine's
topology path against the trivial path on identical step counts (the
compiled-engine overhead of the vmapped per-shard ring).

Results → ``benchmarks/results/topology_scaling.json`` (RunResult records
per (arch, λ) + derived curves/speedups); surfaced by
``benchmarks/summary.py``.
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit, save_results, updates_for_epochs
from repro.config import RunConfig
from repro.core.topology import RUDRA_ARCHS, Topology
from repro.experiments import ExperimentSpec
from repro.experiments import run as run_spec

LAMBDAS = (4, 16, 32, 60)
MU = 4
EPOCHS = 2.0
DATASET = 50_000          # the paper's CIFAR epoch (tradeoff.WorkloadModel)
MODEL_MB = 300            # Table-1 adversarial model size
PULL_JITTER = 0.02


def _spec_for(arch: str, lam: int) -> ExperimentSpec:
    topo = Topology.for_arch(arch, lam,
                             jitter=PULL_JITTER if arch == "adv*" else 0.0)
    run = RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                    minibatch=MU, shards=topo.shards, groups=topo.groups,
                    shard_pull_jitter=topo.pull_jitter, seed=29)
    # fixed total work: epochs·dataset samples at c·μ·gs samples per update
    steps = updates_for_epochs(EPOCHS, MU, run.gradients_per_update,
                               DATASET, group_size=run.group_size)
    return ExperimentSpec(run=run, steps=steps,
                          duration=f"calibrated:{arch}:{MODEL_MB}mb",
                          tag=f"{arch}/lambda={lam}")


def _engine_overhead_cell(updates: int = 40) -> dict:
    """Wall-clock of the sharded+grouped replay vs the trivial replay on
    the same step count (mlp_teacher, tiny shape) — the topology path's
    compiled-engine overhead."""
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=8,
                      minibatch=4, base_lr=0.05,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=17),
        problem="mlp_teacher", steps=updates)
    # shards only: identical trace shape (same c, same gradient count per
    # event), so the delta is purely the vmapped per-shard ring path
    star = base.replace(run=base.run.replace(shards=4,
                                             shard_pull_jitter=0.1))

    def _time(spec):
        run_spec(spec)                               # compile
        t0 = time.perf_counter()
        res = run_spec(spec)
        jnp.asarray(res.params["w1"]).block_until_ready()
        return time.perf_counter() - t0

    t_base, t_star = _time(base), _time(star)
    return {"updates": updates, "trivial_s": t_base, "topology_s": t_star,
            "overhead_x": t_star / t_base}


def run_bench() -> dict:
    records = []
    curves = {arch: {} for arch in RUDRA_ARCHS}
    for arch in RUDRA_ARCHS:
        for lam in LAMBDAS:
            spec = _spec_for(arch, lam)
            res = run_spec(spec)
            records.append(res)
            seconds = res.runtime["simulated_time"]
            curves[arch][lam] = seconds
            emit(f"topology_scaling/{arch}/lambda={lam}/train_s",
                 f"{seconds:.0f}",
                 f"updates={res.runtime['updates']} "
                 f"shards={spec.run.shards} groups={spec.run.groups} "
                 f"<sigma>={res.staleness['mean']:.2f}")
    speedup_vs_base = {
        arch: {lam: curves["base"][lam] / curves[arch][lam]
               for lam in LAMBDAS}
        for arch in RUDRA_ARCHS}
    lam0, lam1 = LAMBDAS[0], LAMBDAS[-1]
    claims = {
        # the paper's qualitative ordering at scale: base saturates on PS
        # ingest; the sharded tree and the threaded tree keep scaling
        "adv_faster_than_base_at_scale":
            curves["adv"][lam1] < curves["base"][lam1],
        "adv_star_fastest_at_scale":
            curves["adv*"][lam1] <= curves["adv"][lam1],
        # base's λ0→λ1 scaling falls well short of linear (ingest-bound)
        "base_scaling_saturates":
            curves["base"][lam0] / curves["base"][lam1] < 0.7 * lam1 / lam0,
    }
    overhead = _engine_overhead_cell()
    emit("topology_scaling/engine_overhead",
         f"{overhead['overhead_x']:.2f}x",
         f"trivial={overhead['trivial_s']:.3f}s "
         f"topology={overhead['topology_s']:.3f}s")
    derived = {"lambdas": list(LAMBDAS), "mu": MU, "epochs": EPOCHS,
               "train_seconds": curves, "speedup_vs_base": speedup_vs_base,
               "claims": claims, "engine_overhead_cell": overhead}
    save_results("topology_scaling", records=records, derived=derived)
    return derived


# benchmarks.run drives modules via their ``run`` attribute
run = run_bench

if __name__ == "__main__":
    run_bench()
