"""DEPRECATED shim — the SPMD replay benchmark now lives in the campaign
layer as cell ``distributed``
(src/repro/experiments/cells/distributed_replay.py):

    PYTHONPATH=src python -m repro.experiments.campaign paper --only distributed

``measure`` (the bench-guard shard-throughput probe, which spawns the
8-device emulated-mesh subprocess) is re-exported for existing importers;
new code should import from the cells module.
"""

from __future__ import annotations

from repro.experiments.cells.distributed_replay import measure  # noqa: F401


def run(**kwargs) -> None:
    from repro.experiments.campaign import run_cell
    run_cell("distributed", params=kwargs or None, force=True)


if __name__ == "__main__":
    run()
