"""Paper Table 2 / §5.3: μλ = constant ⇒ ≈ constant test error, largely
independent of staleness σ; error grows monotonically with the μλ product.

Configurations mirror the paper's table scaled to the teacher task (groups
μλ ≈ {128, 512, 4096} with σ ∈ {1, λ}), driven through the experiment
surface (``ExperimentSpec`` → ``run_sweep``, DESIGN.md §5).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_results
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, run_sweep


def run(epochs: int = 10, base_lr: float = 0.35) -> dict:
    groups = {
        128: [(1, 4, 32), (32, 4, 32), (8, 16, 8), (1, 128, 1)],
        512: [(1, 16, 32), (32, 16, 32), (8, 64, 8), (1, 128, 4)],
        4096: [(1, 128, 32), (32, 128, 32), (8, 256, 16)],
    }
    specs, slots = [], []
    for prod, cfgs in groups.items():
        for (n, mu, lam) in cfgs:
            specs.append(ExperimentSpec(
                run=RunConfig(protocol="softsync", n_softsync=n,
                              n_learners=lam, minibatch=mu, base_lr=base_lr,
                              lr_policy="staleness_inverse", optimizer="sgd",
                              seed=9),
                problem="mlp_teacher", epochs=epochs,
                tag=f"prod={prod}/n={n}/mu={mu}/lam={lam}"))
            slots.append((prod, n, mu, lam))
    results = run_sweep(specs)

    out = {}
    errs_by_prod = {prod: [] for prod in groups}
    for (prod, n, mu, lam), res in zip(slots, results):
        err, sig = res.metrics["test_error"], res.staleness["mean"]
        out[res.tag] = {"test_error": err, "measured_staleness": sig}
        errs_by_prod[prod].append(err)
        emit(f"table2/prod={prod}/sigma={n}/mu={mu}/lam={lam}",
             f"{err:.4f}", f"<sigma>={sig:.1f}")
    for prod, errs in errs_by_prod.items():
        spread = float(np.max(errs) - np.min(errs))
        out[f"prod={prod}/spread"] = spread
        emit(f"table2/prod={prod}/error_spread", f"{spread:.4f}",
             "claim:small-within-group")
    mean_small = float(np.mean(errs_by_prod[128]))
    mean_big = float(np.mean(errs_by_prod[4096]))
    emit("table2/error_grows_with_product", mean_big > mean_small,
         f"128:{mean_small:.3f} 4096:{mean_big:.3f}")
    save_results("table2_mu_lambda", records=results, derived=out)
    return out


if __name__ == "__main__":
    run()
