"""Paper Table 2 / §5.3: μλ = constant ⇒ ≈ constant test error, largely
independent of staleness σ; error grows monotonically with the μλ product.

Configurations mirror the paper's table scaled to the teacher task:
groups μλ ≈ {128, 512} with σ ∈ {1, λ} (1-softsync / λ-softsync).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import MLPProblem, emit, save_json, updates_for_epochs
from repro.config import RunConfig
from repro.core.simulator import simulate


def _error(prob, n, mu, lam, epochs, base_lr):
    cfg = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                    minibatch=mu, base_lr=base_lr,
                    lr_policy="staleness_inverse", optimizer="sgd", seed=9)
    steps = updates_for_epochs(epochs, mu, cfg.gradients_per_update,
                               prob.task.n_train)
    res = simulate(cfg, steps=steps, grad_fn=prob.grad_fn,
                   init_params=prob.init, batch_fn=prob.batch_fn_for(mu))
    return prob.test_error(res.params), res.clock_log.mean_staleness()


def run(epochs: int = 10, base_lr: float = 0.35) -> dict:
    prob = MLPProblem()
    groups = {
        128: [(1, 4, 32), (32, 4, 32), (8, 16, 8), (1, 128, 1)],
        512: [(1, 16, 32), (32, 16, 32), (8, 64, 8), (1, 128, 4)],
        4096: [(1, 128, 32), (32, 128, 32), (8, 256, 16)],
    }
    out = {}
    for prod, cfgs in groups.items():
        errs = []
        for (n, mu, lam) in cfgs:
            err, sig = _error(prob, n, mu, lam, epochs, base_lr)
            out[f"prod={prod}/n={n}/mu={mu}/lam={lam}"] = {
                "test_error": err, "measured_staleness": sig}
            errs.append(err)
            emit(f"table2/prod={prod}/sigma={n}/mu={mu}/lam={lam}",
                 f"{err:.4f}", f"<sigma>={sig:.1f}")
        spread = float(np.max(errs) - np.min(errs))
        out[f"prod={prod}/spread"] = spread
        emit(f"table2/prod={prod}/error_spread", f"{spread:.4f}",
             "claim:small-within-group")
    def group_mean(prod):
        return float(np.mean([v["test_error"] for k, v in out.items()
                              if k.startswith(f"prod={prod}/")
                              and isinstance(v, dict)]))
    mean_small, mean_big = group_mean(128), group_mean(4096)
    emit("table2/error_grows_with_product", mean_big > mean_small,
         f"128:{mean_small:.3f} 4096:{mean_big:.3f}")
    save_json("table2_mu_lambda", out)
    return out


if __name__ == "__main__":
    run()
