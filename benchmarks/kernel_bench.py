"""Kernel-level benchmark: wall-clock of the XLA fallback paths on CPU
(chunked vs naive attention, chunked vs recurrent SSD/WKV) and the fused
ps_update's analytic HBM-traffic saving — the quantity the TPU kernel buys.

Timings are real (CPU); the ps_update traffic model is derived (TPU target),
matching the paper's PS applyUpdate hot-spot analysis.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6   # µs


def run() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # --- attention: naive vs chunked (memory-bound difference) -------------
    from repro.models.attention import chunked_attention, naive_attention
    B, S, H, KV, D = 1, 1024, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    t_naive = _time(jax.jit(lambda q, k, v: naive_attention(
        q, k, v, causal=True)), q, k, v)
    t_chunk = _time(jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, q_chunk=256, kv_chunk=256)), q, k, v)
    out["attention"] = {"naive_us": t_naive, "chunked_us": t_chunk}
    emit("kernel/attention_naive", f"{t_naive:.0f}us", f"S={S}")
    emit("kernel/attention_chunked", f"{t_chunk:.0f}us",
         "peak-mem O(S*chunk) vs O(S^2)")

    # --- ssd: chunked vs recurrent ------------------------------------------
    from repro.kernels.ref import ssm_ref
    from repro.models.ssm import ssd_chunked
    Bt, Ss, Hs, P, N = 2, 2048, 4, 32, 32
    x = jax.random.normal(ks[3], (Bt, Ss, Hs, P)) * 0.3
    a = -jnp.abs(jax.random.normal(ks[4], (Bt, Ss, Hs))) * 0.1
    Bm = jax.random.normal(ks[5], (Bt, Ss, N)) * 0.3
    Cm = jax.random.normal(ks[6], (Bt, Ss, N)) * 0.3
    t_rec = _time(jax.jit(lambda *t: ssm_ref(*t)[0]), x, a, Bm, Cm)
    t_chk = _time(jax.jit(lambda *t: ssd_chunked(*t, chunk=128)[0]),
                  x, a, Bm, Cm)
    out["ssd"] = {"recurrent_us": t_rec, "chunked_us": t_chk,
                  "speedup": t_rec / t_chk}
    emit("kernel/ssd_recurrent", f"{t_rec:.0f}us", f"S={Ss}")
    emit("kernel/ssd_chunked", f"{t_chk:.0f}us",
         f"speedup={t_rec/t_chk:.1f}x")

    # --- ps_update fused traffic model --------------------------------------
    # Unfused PS applyUpdate: read W, read V, read each of c grads, write
    # partial sums (c-1 round trips), write V, write W
    #   = (2c + 3) * model_bytes   (sum materialized between each add)
    # Fused kernel: read W, V, c grads once; write W, V once
    #   = (c + 4) * model_bytes
    for c in (2, 4, 8, 15, 30):
        unfused = 2 * c + 3
        fused = c + 4
        out[f"ps_update_c={c}"] = {"unfused_passes": unfused,
                                   "fused_passes": fused,
                                   "traffic_reduction": unfused / fused}
        emit(f"kernel/ps_update_c={c}/traffic_reduction",
             f"{unfused/fused:.2f}x",
             f"{unfused}->{fused} model-size HBM passes")

    # interpret-mode correctness timing (not perf — CPU emulation)
    from repro.kernels import ops, ref as kref
    Dp = 1 << 16
    w = jax.random.normal(ks[7], (Dp,))
    vv = jnp.zeros((Dp,))
    g = jax.random.normal(ks[0], (4, Dp))
    coef = jnp.array([1.0, 0.5, 0.33, 0.25])
    w2, v2 = ops.ps_update(w, vv, g, coef, momentum=0.9, lr=0.1)
    w2r, v2r = kref.ps_update_ref(w, vv, g, coef, momentum=0.9, lr=0.1)
    ok = bool(jnp.allclose(w2, w2r, atol=1e-5))
    emit("kernel/ps_update_interpret_allclose", ok, "")
    out["ps_update_allclose"] = ok

    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
