"""Simulator engine throughput: legacy loop vs compiled replay vs the
batched sweep driver (DESIGN.md §4/§5).

Part 1 — per-run engines on the MLP stand-in at λ ∈ {8, 32, 128}, μ = 4
(the paper's small-minibatch sweet spot, Table 3), via the experiment
surface with ``engine="legacy"`` vs the default compiled trace/replay:

* ``1-softsync`` (c = λ) — the paper's Table-3 winner and the shape where
  the legacy loop hurts most: λ un-jitted ``grad_fn`` dispatches plus one
  host→device optimizer round-trip per update.
* ``(λ/4)-softsync`` (c = 4) — staleness-heavy: the replay ring buffer K
  grows to ~2n while per-update work stays fixed.
* ``λ-softsync`` (c = 1, Eq.-5 degenerate ≈ async) — maximal staleness:
  the ring buffer runs at its full K ≈ 2λ bound and the legacy loop pays
  one complete dispatch round-trip per single-gradient update.

Part 2 — the sweep headline: a 4-LR × 5-seed grid cell replayed as ONE
vmapped device program with one vectorized staging pass
(``run_sweep``/``core.engine.replay_batch``) vs the same grid executed as
sequential per-spec replays (``run_sweep(batch=False)`` — the hand-wired
pipeline every benchmark used before the experiment surface existed).

Timing protocol: per configuration both paths are warmed (jit + scan
compiles excluded — the sweep regime: one compile, many replays), then
timed best-of-N end-to-end through the public API on identical
RunConfig/seed grids (identical traces).  ``max_param_drift`` cross-checks
result equivalence on the benchmarked runs themselves.

Results → ``benchmarks/results/sim_engine_bench.json``; also surfaced by
``benchmarks/summary.py``.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_results
from repro.config import RunConfig
from repro.experiments import ExperimentSpec, Sweep, run_sweep
from repro.experiments import run as run_spec

LAMBDAS = (8, 32, 128)
MU = 4


def _wait(res):
    jnp.asarray(res.params["w1"]).block_until_ready()
    return res


def _best_of(fn, repeats: int = 5):
    # min over repeats: discards scheduler noise on a shared CPU
    times, res = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        times.append(time.perf_counter() - t0)
    return min(times), res


def _bench_one(cfg: RunConfig, updates: int, warm_updates: int = 4,
               repeats: int = 5) -> dict:
    spec = ExperimentSpec(run=cfg, problem="mlp_teacher", steps=updates)
    legacy_spec = spec.replace(engine="legacy")

    _wait(run_spec(legacy_spec.replace(steps=warm_updates)))  # legacy warmup
    t_legacy, legacy = _best_of(lambda: _wait(run_spec(legacy_spec)), repeats)

    t0 = time.perf_counter()
    _wait(run_spec(spec))                                   # scan compile
    t_compile = time.perf_counter() - t0
    t_replay, compiled = _best_of(lambda: _wait(run_spec(spec)), repeats)

    drift = float(jnp.max(jnp.abs(
        jnp.asarray(legacy.params["w2"]) -
        jnp.asarray(compiled.params["w2"]))))
    return {
        "lambda": cfg.n_learners,
        "n_softsync": cfg.n_softsync,
        "c": cfg.gradients_per_update,
        "ring_buffer_K": compiled.staleness["ring_buffer_K"],
        "updates": updates,
        "legacy_updates_per_s": updates / t_legacy,
        "compiled_updates_per_s": updates / t_replay,
        "speedup": t_legacy / t_replay,
        "compile_s": t_compile,
        "max_param_drift": drift,
    }


def _bench_sweep(updates: int = 60, lam: int = 32, mu: int = 1,
                 seeds: int = 5, repeats: int = 3) -> dict:
    """The batched-replay headline: 4 LRs × ``seeds`` seeds at 1-softsync
    (c = λ — the Table-3 winner shape) in the small-μ regime where per-slot
    staging dominates the hand-wired pipeline.  All grid points share one
    trace shape, so the whole cell is ONE vmapped scan."""
    base = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=1, n_learners=lam,
                      minibatch=mu, base_lr=0.05,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=17),
        problem="mlp_teacher", steps=updates)
    sweep = Sweep.over(base, base_lr=[0.02, 0.05, 0.1, 0.2],
                       seed=range(seeds))

    def _wait_all(results):
        for r in results:
            jnp.asarray(r.params["w1"]).block_until_ready()
        return results

    _wait_all(run_sweep(sweep))                             # warm both paths
    _wait_all(run_sweep(sweep, batch=False))
    t_batch, batched = _best_of(lambda: _wait_all(run_sweep(sweep)), repeats)
    t_seq, seq = _best_of(
        lambda: _wait_all(run_sweep(sweep, batch=False)), repeats)
    drift = max(
        float(jnp.max(jnp.abs(jnp.asarray(a.params["w2"]) -
                              jnp.asarray(b.params["w2"]))))
        for a, b in zip(batched, seq))
    return {
        "grid": f"4xlr * {seeds}xseed",
        "runs": 4 * seeds,
        "protocol_shape": f"1-softsync lam={lam} c={lam} mu={mu}",
        "updates_per_run": updates,
        "sequential_s": t_seq,
        "batched_s": t_batch,
        "speedup": t_seq / t_batch,
        "max_param_drift": drift,
    }


def run_bench(updates: int = 480) -> dict:
    out = {}
    for lam in LAMBDAS:
        for label, n in [("softsync_1", 1), ("softsync_quarter", lam // 4),
                         ("softsync_lambda", lam)]:
            cfg = RunConfig(protocol="softsync", n_softsync=n,
                            n_learners=lam, minibatch=MU, base_lr=0.05,
                            lr_policy="staleness_inverse",
                            optimizer="momentum", seed=17)
            row = _bench_one(cfg, updates)
            out[f"{label}_lambda_{lam}"] = row
            emit(f"sim_engine/{label}/lambda={lam}/updates_per_s",
                 f"legacy={row['legacy_updates_per_s']:.1f} "
                 f"compiled={row['compiled_updates_per_s']:.1f}",
                 f"speedup={row['speedup']:.1f}x c={row['c']} "
                 f"K={row['ring_buffer_K']} "
                 f"drift={row['max_param_drift']:.1e}")
    # scale the sweep cell's per-run budget with the engine rows' budget so
    # --quick stays quick
    sweep_row = _bench_sweep(updates=max(10, updates // 8))
    out["sweep_batched_vs_sequential"] = sweep_row
    emit("sim_engine/sweep_batched/4lr_x_5seed",
         f"sequential={sweep_row['sequential_s']:.2f}s "
         f"batched={sweep_row['batched_s']:.2f}s",
         f"speedup={sweep_row['speedup']:.1f}x "
         f"drift={sweep_row['max_param_drift']:.1e}")
    save_results("sim_engine_bench", derived=out)
    return out


# benchmarks.run drives modules via their ``run`` attribute
run = run_bench

if __name__ == "__main__":
    run_bench()
