"""DEPRECATED shim — the engine benchmark now lives in the campaign layer
as cell ``sim_engine`` (src/repro/experiments/cells/sim_engine_bench.py):

    PYTHONPATH=src python -m repro.experiments.campaign paper --only sim_engine

The single-config timing helpers (``_bench_one``/``_bench_sweep``/
``_bench_megakernel``/``_bench_whatif``) are re-exported for existing
importers; new code should import from the cells module.
"""

from __future__ import annotations

from repro.experiments.cells.sim_engine_bench import (  # noqa: F401
    _bench_megakernel, _bench_one, _bench_sweep, _bench_whatif)


def run(**kwargs) -> None:
    from repro.experiments.campaign import run_cell
    run_cell("sim_engine", params=kwargs or None, force=True)


if __name__ == "__main__":
    run()
