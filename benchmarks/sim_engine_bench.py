"""Legacy per-arrival loop vs compiled trace/replay engine (DESIGN.md §4).

Measures PS-simulation throughput (weight updates/sec) on the MLP stand-in
at λ ∈ {8, 32, 128}, μ = 4 (the paper's small-minibatch sweet spot,
Table 3), for two protocol shapes:

* ``1-softsync`` (c = λ) — the paper's Table-3 winner and the shape where
  the legacy loop hurts most: λ un-jitted ``grad_fn`` dispatches plus one
  host→device optimizer round-trip per update.
* ``(λ/4)-softsync`` (c = 4) — staleness-heavy: the replay ring buffer K
  grows to ~2n while per-update work stays fixed.
* ``λ-softsync`` (c = 1, Eq.-5 degenerate ≈ async) — the paper's maximal-
  staleness regime: the ring buffer runs at its full K ≈ 2λ bound and the
  legacy loop pays one complete dispatch round-trip per single-gradient
  update.

The compiled engine executes the whole trace as a single ``lax.scan`` with
the c gradients of an event vmapped and the apply fused over the flat
model (``optim.apply_event_flat``).

Timing protocol: per configuration, both engines are warmed (jit compiles
and the engine's one-time ``lax.scan`` compile are excluded — matching the
sweep regime: one compile, many scenario replays), then timed on identical
RunConfig/seed (identical traces).  ``max_param_drift`` cross-checks the
oracle equivalence on the benchmarked runs themselves.

Results → ``benchmarks/results/sim_engine_bench.json``; also surfaced by
``benchmarks/summary.py``.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MLPProblem, emit, save_json
from repro.config import RunConfig
from repro.core.engine import replay
from repro.core.simulator import simulate
from repro.core.trace import schedule

LAMBDAS = (8, 32, 128)
MU = 4


def _bench_one(prob, cfg: RunConfig, updates: int, warm_updates: int = 4,
               repeats: int = 5) -> dict:
    kw = dict(grad_fn=prob.grad_fn, init_params=prob.init,
              batch_fn=prob.batch_fn_for(MU))

    def wait(res):
        jnp.asarray(res.params["w1"]).block_until_ready()
        return res

    def best_of(fn):
        # min over repeats: discards scheduler noise on a shared CPU
        times, res = [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = wait(fn())
            times.append(time.perf_counter() - t0)
        return min(times), res

    wait(simulate(cfg, steps=warm_updates, **kw))          # legacy warmup
    t_legacy, legacy = best_of(lambda: simulate(cfg, steps=updates, **kw))

    trace = schedule(cfg, updates)
    t0 = time.perf_counter()
    wait(replay(trace, cfg, **kw))                         # scan compile
    t_compile = time.perf_counter() - t0
    t_replay, compiled = best_of(lambda: replay(trace, cfg, **kw))

    drift = float(jnp.max(jnp.abs(
        jnp.asarray(legacy.params["w2"]) -
        jnp.asarray(compiled.params["w2"]))))
    return {
        "lambda": cfg.n_learners,
        "n_softsync": cfg.n_softsync,
        "c": cfg.gradients_per_update,
        "ring_buffer_K": trace.max_staleness + 1,
        "updates": updates,
        "legacy_updates_per_s": updates / t_legacy,
        "compiled_updates_per_s": updates / t_replay,
        "speedup": t_legacy / t_replay,
        "compile_s": t_compile,
        "max_param_drift": drift,
    }


def run(updates: int = 480) -> dict:
    prob = MLPProblem()
    out = {}
    for lam in LAMBDAS:
        for label, n in [("softsync_1", 1), ("softsync_quarter", lam // 4),
                         ("softsync_lambda", lam)]:
            cfg = RunConfig(protocol="softsync", n_softsync=n,
                            n_learners=lam, minibatch=MU, base_lr=0.05,
                            lr_policy="staleness_inverse",
                            optimizer="momentum", seed=17)
            row = _bench_one(prob, cfg, updates)
            out[f"{label}_lambda_{lam}"] = row
            emit(f"sim_engine/{label}/lambda={lam}/updates_per_s",
                 f"legacy={row['legacy_updates_per_s']:.1f} "
                 f"compiled={row['compiled_updates_per_s']:.1f}",
                 f"speedup={row['speedup']:.1f}x c={row['c']} "
                 f"K={row['ring_buffer_K']} "
                 f"drift={row['max_param_drift']:.1e}")
    save_json("sim_engine_bench", out)
    return out


if __name__ == "__main__":
    run()
