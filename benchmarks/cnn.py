"""DEPRECATED shim — the paper-shape CNN benchmark now lives in the
campaign layer as cell ``cnn`` (src/repro/experiments/cells/cnn_fig5.py):

    PYTHONPATH=src python -m repro.experiments.campaign extended --only cnn

The CNN building blocks (``init_cnn``/``cnn_forward``/``cnn_loss``) and the
``ImageTeacher`` task are re-exported here for existing importers
(tests/test_cnn.py); new code should import from the cells module.
"""

from __future__ import annotations

from repro.experiments.cells.cnn_fig5 import (ImageTeacher,  # noqa: F401
                                              cnn_forward, cnn_loss,
                                              init_cnn)


def run(**kwargs) -> None:
    from repro.experiments.campaign import run_cell
    run_cell("cnn", params=kwargs or None, force=True)


if __name__ == "__main__":
    run()
