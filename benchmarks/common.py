"""Shared benchmark plumbing: results-envelope writers and CSV emit.

The MLP accuracy-axis problem and the epochs→updates conversion moved into
the experiment surface (``repro.experiments.problems``, DESIGN.md §5) — the
names are re-exported here for compatibility.  Results files all share the
RunResult envelope (``repro.experiments.result``): RunResult ``records``
plus free-form ``derived`` values (claim booleans, speedups, timings);
``python -m repro.experiments.validate benchmarks/results`` gates the
schema in CI.
"""

from __future__ import annotations

import json
import os

from repro.experiments import MLPProblem, updates_for_epochs  # noqa: F401
from repro.experiments import envelope

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_results(name: str, records=(), derived=None) -> str:
    """Write ``benchmarks/results/<name>.json`` in the shared envelope."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(envelope(name, records, derived), f, indent=1,
                  default=float)
    return path


def save_json(name: str, data) -> str:
    """Legacy writer: free-form benchmark output → records-less envelope."""
    return save_results(name, derived=data)


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")
