"""Shared benchmark plumbing: the small SGD problem used for accuracy-axis
experiments (CIFAR-scale stand-in, see DESIGN.md §8) and CSV/JSON helpers."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.data.synthetic import TeacherClassification

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_json(name: str, data) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


# ---------------------------------------------------------------------------
# MLP learner on the teacher-classification task (the paper's CNN stand-in)
# ---------------------------------------------------------------------------
class MLPProblem:
    """2-layer MLP trained on TeacherClassification — the accuracy-axis
    vehicle for Figs. 5-7 / Tables 2-4 (non-convex, overfits, LR-sensitive:
    the properties the paper's claims depend on)."""

    def __init__(self, hidden: int = 64, task: TeacherClassification = None,
                 seed: int = 0):
        self.task = task or TeacherClassification()
        self.hidden = hidden
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        nf, nc = self.task.n_features, self.task.n_classes
        self.init = {
            "w1": jax.random.normal(k1, (nf, hidden)) / np.sqrt(nf),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, nc)) / np.sqrt(hidden),
            "b2": jnp.zeros((nc,)),
        }
        self._grad = jax.jit(jax.grad(self.loss))
        self._test_err = jax.jit(self._test_err_impl)

    def loss(self, p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def _test_err_impl(self, p):
        x, y = self.task.x_test, self.task.y_test
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        pred = jnp.argmax(h @ p["w2"] + p["b2"], axis=-1)
        return 1.0 - jnp.mean((pred == y).astype(jnp.float32))

    def grad_fn(self, p, batch):
        return self._grad(p, batch)

    def batch_fn_for(self, mu: int, seed: int = 0) -> Callable:
        # returns host (numpy) arrays: the jitted grad_fn transfers them on
        # call, and the replay engine stages the whole trace's batches with
        # ONE device transfer per leaf instead of one per minibatch.
        def fn(learner: int, step: int):
            return self.task.minibatch(learner, step, mu, seed=seed)
        return fn

    def test_error(self, p) -> float:
        return float(self._test_err(p))

    def eval_fn(self, p) -> Dict[str, float]:
        return {"test_error": self.test_error(p)}


def updates_for_epochs(epochs: int, mu: int, lam: int,
                       dataset: int) -> int:
    """Weight updates s.t. total samples == epochs·dataset (softsync counts
    c·μ samples/update; hardsync λ·μ)."""
    return max(1, int(epochs * dataset / (mu * lam)))
