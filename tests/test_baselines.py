"""Related-work baselines (paper §6): SSP, EASGD, Downpour-accrual —
semantics tests against the event-queue machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core.baselines import (simulate_accrual, simulate_easgd,
                                  simulate_ssp)
from repro.core.simulator import _default_duration_sampler


def _lsq():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (8, 4))
    X = jax.random.normal(jax.random.PRNGKey(1), (256, 8))
    Y = X @ W

    def loss(p, b):
        x, y = b
        return jnp.mean((x @ p - y) ** 2)
    grad_fn = jax.jit(jax.grad(loss))

    def batch_fn(l, i):
        rng = np.random.default_rng(l * 7919 + i)
        idx = rng.integers(0, 256, size=8)
        return X[idx], Y[idx]
    return W, X, Y, grad_fn, batch_fn


@pytest.mark.slow   # long convergence loop; full lane
def test_ssp_converges_and_blocks_under_stragglers():
    W, X, Y, grad_fn, batch_fn = _lsq()
    run = RunConfig(protocol="async", n_learners=8, minibatch=8,
                    base_lr=0.4, lr_policy="staleness_inverse",
                    optimizer="sgd", seed=3)
    res = simulate_ssp(run, steps=1200, slack=3, grad_fn=grad_fn,
                       init_params=jnp.zeros((8, 4)), batch_fn=batch_fn)
    err = float(jnp.mean((X @ res.params - Y) ** 2))
    assert err < 0.05

    def straggler(rng, m):
        return _default_duration_sampler(rng, m) * \
            (20.0 if rng.integers(0, 8) == 0 else 1.0)
    res2 = simulate_ssp(run, steps=200, slack=2, grad_fn=grad_fn,
                        init_params=jnp.zeros((8, 4)), batch_fn=batch_fn,
                        duration_sampler=straggler)
    assert getattr(res2, "stalls", 0) > 0   # the SSP blocking cost is real
    assert np.isfinite(float(jnp.mean((X @ res2.params - Y) ** 2)))


@pytest.mark.slow   # long convergence loop; full lane
def test_easgd_center_converges():
    W, X, Y, grad_fn, batch_fn = _lsq()
    run = RunConfig(protocol="async", n_learners=8, minibatch=8,
                    base_lr=0.1, optimizer="sgd", seed=5)
    res = simulate_easgd(run, steps=2000, rho=0.3, grad_fn=grad_fn,
                         init_params=jnp.zeros((8, 4)), batch_fn=batch_fn)
    err = float(jnp.mean((X @ res.params - Y) ** 2))
    assert err < 0.1


def test_accrual_npush1_equals_plain_softsync():
    """npush = 1 degenerates to 1-softsync exactly (same arrival order)."""
    from repro.core.simulator import simulate
    W, X, Y, grad_fn, batch_fn = _lsq()
    run = RunConfig(protocol="softsync", n_softsync=1, n_learners=4,
                    minibatch=8, base_lr=0.05,
                    lr_policy="staleness_inverse", optimizer="sgd", seed=7)
    a = simulate_accrual(run, steps=50, npush=1, grad_fn=grad_fn,
                         init_params=jnp.zeros((8, 4)), batch_fn=batch_fn)
    b = simulate(run, steps=50, grad_fn=grad_fn,
                 init_params=jnp.zeros((8, 4)), batch_fn=batch_fn)
    np.testing.assert_allclose(np.asarray(a.params), np.asarray(b.params),
                               atol=1e-6)
