"""Pallas kernel validation: interpret-mode allclose vs pure-jnp oracles,
with hypothesis shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import ps_update as psu
from repro.kernels import ssm_scan as ssk
from repro.kernels import wkv6 as wk

SET = dict(deadline=None, max_examples=8, derandomize=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_matches_ref(causal, window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 192, 8, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 192, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 192, 4, 32), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=causal, window=window,
                             blk_q=64, blk_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5)


@settings(**SET)
@given(st.sampled_from([32, 48, 96]), st.sampled_from([1, 2]),
       st.sampled_from([(4, 4), (8, 2), (8, 8)]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_flash_attention_sweep(seq, batch, heads, dtype):
    H, KV = heads
    key = jax.random.PRNGKey(seq * 7 + batch)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (batch, seq, H, 16), dtype)
    k = jax.random.normal(ks[1], (batch, seq, KV, 16), dtype)
    v = jax.random.normal(ks[2], (batch, seq, KV, 16), dtype)
    out = fa.flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                             interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=atol)


def test_flash_attention_unaligned_seq():
    """Sequence not a multiple of the block size (padding path)."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 100, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 100, 4, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 100, 4, 16), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                             interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5)


# ---------------------------------------------------------------------------
# ps_update
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.sampled_from([1000, 4096, 5000]), st.integers(1, 6),
       st.sampled_from([0.0, 0.9]))
def test_ps_update_sweep(D, c, momentum):
    key = jax.random.PRNGKey(D + c)
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (D,), jnp.float32)
    v = jax.random.normal(ks[1], (D,), jnp.float32)
    g = jax.random.normal(ks[2], (c, D), jnp.float32)
    coef = jnp.abs(jax.random.normal(ks[3], (c,))) + 0.1
    w2, v2 = psu.ps_update_flat(w, v, g, coef, momentum=momentum, lr=0.05,
                                row_block=8, interpret=True)
    w2r, v2r = ref.ps_update_ref(w, v, g, coef, momentum=momentum, lr=0.05)
    np.testing.assert_allclose(w2, w2r, atol=1e-5)
    np.testing.assert_allclose(v2, v2r, atol=1e-5)


def test_ps_update_tree_matches_sequential_events():
    """The fused kernel reproduces the PS's staleness-weighted sumGradients
    (footnote 3) on a parameter pytree."""
    params = {"a": jnp.ones((300,)), "b": jnp.zeros((17, 8))}
    vel = jax.tree.map(jnp.zeros_like, params)
    grads = [jax.tree.map(lambda p: jnp.full_like(p, float(i + 1)), params)
             for i in range(3)]
    coef = jnp.array([1.0, 0.5, 0.25])
    p2, v2 = psu.ps_update_tree(params, vel, grads, coef, momentum=0.9,
                                lr=0.1, interpret=True)
    want_g = 1 * 1.0 + 2 * 0.5 + 3 * 0.25
    np.testing.assert_allclose(v2["a"], np.full(300, want_g), atol=1e-5)
    np.testing.assert_allclose(p2["a"], np.full(300, 1 - 0.1 * want_g),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.sampled_from([32, 96, 128]), st.sampled_from([8, 16]),
       st.sampled_from([16, 32]))
def test_ssm_scan_sweep(S, N, chunk):
    key = jax.random.PRNGKey(S + N)
    ks = jax.random.split(key, 4)
    Bt, H, P = 2, 3, 8
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (Bt, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (Bt, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    y, st_ = ssk.ssm_scan(x, a, Bm, Cm, chunk=chunk, interpret=True)
    yr, str_ = ref.ssm_ref(x, a, Bm, Cm)
    np.testing.assert_allclose(y, yr, atol=2e-3)
    np.testing.assert_allclose(st_, str_, atol=2e-3)


def test_ssm_chunked_jnp_matches_ref():
    """The XLA-fallback chunked SSD (models.ssm) against the recurrence."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    Bt, S, H, P, N = 2, 100, 3, 8, 16
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (Bt, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (Bt, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    for unroll in (False, True):
        y, st_ = ssd_chunked(x, a, Bm, Cm, chunk=32, unroll=unroll)
        yr, str_ = ref.ssm_ref(x, a, Bm, Cm)
        np.testing.assert_allclose(y, yr, atol=2e-3)
        np.testing.assert_allclose(st_, str_, atol=2e-3)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.sampled_from([16, 48, 64]), st.sampled_from([8, 16]),
       st.sampled_from([8, 16]))
def test_wkv6_sweep(S, P, chunk):
    key = jax.random.PRNGKey(S * 31 + P)
    ks = jax.random.split(key, 5)
    Bt, H = 2, 3
    r = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    k = jax.random.normal(ks[1], (Bt, S, H, P)) * 0.5
    v = jax.random.normal(ks[2], (Bt, S, H, P)) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], (Bt, S, H, P)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y, st_ = wk.wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, str_ = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, atol=2e-3)
    np.testing.assert_allclose(st_, str_, atol=2e-3)


def test_wkv_chunked_probe_matches_recurrent():
    """The unrolled chunked WKV (roofline probe path) vs the recurrence."""
    from repro.models.rwkv import wkv_chunked, wkv_recurrent
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    Bt, S, H, P = 2, 70, 2, 8
    r = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    k = jax.random.normal(ks[1], (Bt, S, H, P)) * 0.5
    v = jax.random.normal(ks[2], (Bt, S, H, P)) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], (Bt, S, H, P)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y1, s1 = wkv_chunked(r, k, v, w, u, chunk=16)
    y2, s2 = wkv_recurrent(r, k, v, w, u)
    np.testing.assert_allclose(y1, y2, atol=2e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-3)


# ---------------------------------------------------------------------------
# model-level: chunked attention == naive attention
# ---------------------------------------------------------------------------
def test_chunked_attention_equals_naive():
    from repro.models.attention import chunked_attention, naive_attention
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 80, 8, 16))
    k = jax.random.normal(ks[1], (2, 80, 2, 16))
    v = jax.random.normal(ks[2], (2, 80, 2, 16))
    for window in (0, 24):
        for unroll in (False, True):
            out = chunked_attention(q, k, v, causal=True, window=window,
                                    q_chunk=32, kv_chunk=32, unroll=unroll)
            want = naive_attention(q, k, v, causal=True, window=window)
            # bf16 probability×value matmul (§Perf A2) widens the tolerance
            np.testing.assert_allclose(out, want, atol=6e-3)
