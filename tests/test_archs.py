"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
variant of the same family (≤2-3 units, d_model ≤ 512, ≤4 experts) and runs
one forward/train step on CPU asserting output shapes + no NaNs; decode step
where the family supports it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, RunConfig, validate_pairing
from repro.configs import ARCH_IDS, get_config, get_smoke, \
    long_context_variant
from repro.core import init_opt_state, make_train_step
from repro.data.pipeline import make_batch_fn

pytestmark = pytest.mark.slow   # per-arch smoke sweep: the heavy lane
from repro.models import (count_params, init_caches, init_model, model_loss,
                          model_forward)
from repro.serve.engine import serve_step

RUN = RunConfig(protocol="softsync", n_softsync=2, n_learners=4, minibatch=2,
                base_lr=0.01, lr_policy="staleness_inverse",
                optimizer="momentum", attn_q_chunk=32, attn_kv_chunk=32)
B, S = 4, 64


def _batch(cfg):
    return jax.tree.map(jnp.asarray, make_batch_fn(cfg, B, S, seed=0)(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.d_model <= 512 and cfg.n_units <= 3
    assert cfg.n_experts <= 4
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    step = jax.jit(make_train_step(
        RUN, lambda p, b, sample_weights=None: model_loss(
            cfg, RUN, p, b, sample_weights=sample_weights)))
    p2, opt, metrics = step(params, init_opt_state(RUN, params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: model_forward(cfg, RUN, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode (DESIGN.md §8)")
    params = init_model(cfg, jax.random.PRNGKey(0))
    caches = init_caches(cfg, B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, t, pos, c: serve_step(cfg, RUN, p, t, pos, c))
    nxt, caches = step(params, tok, jnp.int32(0), caches)
    assert nxt.shape == (B, 1)
    nxt2, _ = step(params, nxt, jnp.int32(1), caches)
    assert nxt2.shape == (B, 1)


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (spot checks per arch)."""
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = get_config("qwen3-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = get_config("qwen2-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 1536, 12, 2, 8960, 151936)
    assert c.qkv_bias
    c = get_config("starcoder2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    c = get_config("internvl2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2048, 16, 8, 8192, 92553)
    assert c.frontend == "vision"
    c = get_config("hubert-xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (48, 1280, 16, 5120, 504)
    assert c.encoder_only and c.frontend == "audio"
    c = get_config("rwkv6-7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 14336, 65536)
    assert c.attention_free
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.ssm_state) == (81, 3584, 32, 32, 14336, 32000, 64)
    assert c.effective_layers == 81
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab_size,
            c.n_experts, c.top_k) == (48, 5120, 40, 8, 202048, 128, 1)
    c = get_config("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == \
        (35, 7168, 56, 8, 4864, 32000, 128, 2)


def test_pairing_skips():
    hub = get_config("hubert-xlarge")
    assert validate_pairing(hub, INPUT_SHAPES["decode_32k"]) is not None
    assert validate_pairing(hub, INPUT_SHAPES["long_500k"]) is not None
    assert validate_pairing(hub, INPUT_SHAPES["train_4k"]) is None
    dense = get_config("qwen3-14b")
    assert validate_pairing(dense, INPUT_SHAPES["long_500k"]) is not None
    assert validate_pairing(long_context_variant(dense),
                            INPUT_SHAPES["long_500k"]) is None
    ssm = get_config("rwkv6-7b")
    assert validate_pairing(ssm, INPUT_SHAPES["long_500k"]) is None


def test_param_count_estimates_match_pytree():
    """Analytic param_count (used by roofline MODEL_FLOPS) tracks the real
    pytree within 10% on the reduced configs."""
    for arch in ARCH_IDS:
        cfg = get_smoke(arch)
        real = count_params(init_model(cfg, jax.random.PRNGKey(0)))
        est = cfg.param_count()
        assert abs(est - real) / real < 0.35, (arch, est, real)
