"""Data pipeline determinism/prefetch + checkpoint roundtrip + sharding
policy unit tests (pure functions — no devices needed)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.config import ModelConfig
from repro.configs import get_smoke, ARCH_IDS
from repro.data.pipeline import (PrefetchIterator, make_batch_fn,
                                 shard_batch_for_learner)
from repro.data.synthetic import TeacherClassification, lm_token_stream
from repro.models import init_model


def test_lm_stream_deterministic_and_learnable():
    b1 = lm_token_stream(64, 4, 16, seed=3, step=5)
    b2 = lm_token_stream(64, 4, 16, seed=3, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are the next-token shift of the underlying chain
    assert b1["labels"].shape == (4, 16)
    b3 = lm_token_stream(64, 4, 16, seed=3, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_teacher_task_balanced_and_deterministic():
    t1 = TeacherClassification(n_train=512, n_test=128)
    t2 = TeacherClassification(n_train=512, n_test=128)
    np.testing.assert_array_equal(t1.y_train, t2.y_train)
    # non-degenerate: at least half the classes appear
    assert len(np.unique(t1.y_train)) >= 5
    x1, y1 = t1.minibatch(3, 7, 16)
    x2, y2 = t1.minibatch(3, 7, 16)
    np.testing.assert_array_equal(x1, x2)
    # distinct (learner, step) draw distinct batches
    assert not np.array_equal(y1, t1.minibatch(3, 8, 16)[1])
    # arbitrarily large seeds wrap into the 64-bit hash (no OverflowError)
    xb, yb = t1.minibatch(3, 7, 16, seed=2 ** 63)
    assert xb.shape == (16, t1.n_features)


def test_prefetch_iterator_yields_all():
    fn = lambda step: {"x": np.full((2,), step)}
    got = [b["x"][0] for b in PrefetchIterator(fn, steps=5, to_device=False)]
    assert [int(g) for g in got] == [0, 1, 2, 3, 4]


def test_shard_batch_for_learner():
    batch = {"x": np.arange(12).reshape(12, 1)}
    s = shard_batch_for_learner(batch, learner=2, n_learners=4)
    np.testing.assert_array_equal(s["x"][:, 0], [6, 7, 8])


@pytest.mark.parametrize("arch", ["internvl2_2b", "hubert_xlarge",
                                  "qwen2_1_5b"])
def test_batch_fn_layouts(arch):
    cfg = get_smoke(arch)
    b = make_batch_fn(cfg, 2, 32)(0)
    assert b["labels"].shape == (2, 32)
    if cfg.frontend == "vision":
        assert b["patches"].shape == (2, cfg.n_prefix_embeds, cfg.d_model)
        assert b["tokens"].shape == (2, 32 - cfg.n_prefix_embeds)
        # loss is masked on the prefix
        assert b["loss_mask"][:, :cfg.n_prefix_embeds].sum() == 0
    elif cfg.frontend == "audio":
        assert b["frames"].shape == (2, 32, cfg.d_model)
    else:
        assert b["tokens"].shape == (2, 32)


@pytest.mark.slow   # full bf16 state roundtrip; full lane
def test_checkpoint_roundtrip_bf16():
    cfg = get_smoke("qwen2_1_5b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, params, step=17)
        restored, step = load_checkpoint(path, params)
        assert step == 17
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-6)


# ---------------------------------------------------------------------------
# sharding policy (pure spec logic — uses an abstract mesh via jax.sharding)
# ---------------------------------------------------------------------------
def test_parallelism_mode_per_arch():
    from repro.configs import get_config
    from repro.launch.sharding import parallelism_mode
    expect = {
        "llama3_405b": "head", "internvl2_2b": "head",
        "hubert_xlarge": "head", "zamba2_7b": "head", "rwkv6_7b": "head",
        "qwen2_1_5b": "seq", "qwen3_14b": "seq", "starcoder2_7b": "seq",
        "arctic_480b": "seq", "llama4_maverick_400b_a17b": "seq",
    }
    for arch, mode in expect.items():
        assert parallelism_mode(get_config(arch), 16) == mode, arch


def test_microbatch_defaults_scale_with_model():
    from repro.configs import get_config
    from repro.config import INPUT_SHAPES
    from repro.launch.sharding import default_microbatches
    tr = INPUT_SHAPES["train_4k"]
    assert default_microbatches(get_config("llama3_405b"), tr) == 16
    assert default_microbatches(get_config("qwen2_1_5b"), tr) == 1
    assert default_microbatches(get_config("llama3_405b"),
                                INPUT_SHAPES["decode_32k"]) == 1
