"""Train-while-serve publication semantics (DESIGN.md §14).

The exactly-testable contract of ``repro.serve``:

* a publication is a ring-row read — the weights version v's snapshot, bit
  for bit the trained weights at v (prefix-replay comparison);
* a ``staleness`` policy's budget is never exceeded at any request (the
  refresh-before-request tie rule makes this exact, not probabilistic);
* attaching a fleet never perturbs training: the arrival schedule AND the
  replayed parameters are bitwise-identical to a no-serving run, on every
  ring impl, under learner churn and replica churn alike;
* every guardrail (spmd, sharded stock ring, batched replay, the legacy
  oracle, missing serve hooks) errors actionably instead of silently
  degrading.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core.engine import replay, replay_batch
from repro.core.simulator import simulate
from repro.core.trace import schedule, schedule_cached
from repro.experiments import (ExperimentSpec, Sweep, envelope, run,
                               run_sweep, validate_record)
from repro.experiments.problems import MLPProblem
from repro.membership import MembershipTimeline
from repro.serve.fleet import FleetConfig, ServingResult
from repro.serve.publication import PublicationPolicy, schedule_serving

MU = 16


def _run(policy=None, serving=True, **kw):
    fleet = None
    if serving:
        fleet = FleetConfig(replicas=2,
                            policy=policy or PublicationPolicy(),
                            request_rate=2.0, request_samples=8)
    base = dict(protocol="softsync", n_learners=4, n_softsync=2,
                minibatch=MU, lr_policy="staleness_inverse",
                optimizer="momentum", serving=fleet)
    base.update(kw)
    return RunConfig(**base)


@pytest.fixture(scope="module")
def prob():
    return MLPProblem()


def _replay(trace, cfg, prob, **kw):
    serve_kw = {}
    if trace.serving is not None:
        serve_kw = dict(
            serve_batches=prob.stage_requests(trace.serving, cfg.serving,
                                              seed=cfg.seed),
            serve_eval_fn=prob.request_metric)
    return replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
                  batch_fn=prob.batch_fn_for(cfg.minibatch),
                  **serve_kw, **kw)


def _tree_equal(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------
def test_policy_validation():
    with pytest.raises(ValueError, match="unknown publication kind"):
        PublicationPolicy(kind="sometimes")
    with pytest.raises(ValueError, match="every must be >= 1"):
        PublicationPolicy(kind="every_n", every=0)
    with pytest.raises(ValueError, match="max_version_lag"):
        PublicationPolicy(max_version_lag=-1)
    with pytest.raises(ValueError, match="max_time_lag"):
        PublicationPolicy(kind="time", max_time_lag=0.0)


def test_fleet_validation():
    with pytest.raises(ValueError, match="replicas must be >= 1"):
        FleetConfig(replicas=0)
    with pytest.raises(ValueError, match="request_rate"):
        FleetConfig(request_rate=0.0)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        FleetConfig(diurnal_amplitude=1.5)
    with pytest.raises(ValueError, match="policy must be a"):
        FleetConfig(policy="every_n")
    # replica churn rides MembershipTimeline, validated against replicas
    with pytest.raises(ValueError, match="learner 5"):
        FleetConfig(replicas=2,
                    membership=MembershipTimeline(((1.0, 5, "crash"),)))
    # raw event tuples normalize, like RunConfig.membership
    fleet = FleetConfig(replicas=2, membership=((1.0, 0, "crash"),
                                                (2.0, 0, "join")))
    assert isinstance(fleet.membership, MembershipTimeline)
    assert "churn" not in str(FleetConfig())  # compact sweep-fragment tag
    assert "crash" in str(fleet)


def test_runconfig_serving_guardrails():
    with pytest.raises(ValueError, match="FleetConfig"):
        RunConfig(serving="fleet")
    with pytest.raises(ValueError, match="placement='spmd'"):
        _run(placement="spmd")
    with pytest.raises(ValueError, match="stock sharded"):
        _run(shards=2, ring_impl="stock")
    _run(shards=2)          # fused sharded ring serves fine


# ---------------------------------------------------------------------------
# schedule_serving semantics
# ---------------------------------------------------------------------------
def test_arrival_schedule_bitwise_unchanged_by_serving():
    cfg = _run()
    t_on = schedule(cfg, 30)
    t_off = schedule(cfg.replace(serving=None), 30)
    assert t_on.serving is not None and t_off.serving is None
    for field in ("learner", "pulled_ts", "mb_index", "event_time", "lrs"):
        np.testing.assert_array_equal(getattr(t_on, field),
                                      getattr(t_off, field))


@pytest.mark.parametrize("budget", [0, 1, 3])
def test_staleness_budget_never_exceeded(budget):
    cfg = _run(PublicationPolicy(kind="staleness", max_version_lag=budget))
    sv = schedule(cfg, 40).serving
    assert sv.n_requests > 0
    assert int(sv.staleness[sv.served].max(initial=0)) <= budget


def test_every_n_version_lag_bound():
    cfg = _run(PublicationPolicy(kind="every_n", every=5))
    sv = schedule(cfg, 40).serving
    assert int(sv.staleness[sv.served].max(initial=0)) <= 4


def test_on_demand_reads_are_fresh():
    cfg = _run(PublicationPolicy(kind="on_demand"))
    sv = schedule(cfg, 40).serving
    assert sv.n_requests > 0
    assert (sv.staleness[sv.served] == 0).all()
    v_now = schedule(cfg, 40).version_at(sv.request_time)
    np.testing.assert_array_equal(sv.version[sv.served], v_now[sv.served])


def test_time_budget_bounds_seconds_lag():
    cfg = _run(PublicationPolicy(kind="time", max_time_lag=3.0))
    sv = schedule(cfg, 40).serving
    assert sv.n_requests > 0
    assert float(sv.staleness_s[sv.served].max(initial=0.0)) <= 3.0


def test_tighter_budget_means_more_refreshes():
    refreshes = [schedule(_run(PublicationPolicy(max_version_lag=b)),
                          40).serving.n_refreshes
                 for b in (1, 4, 16)]
    assert refreshes[0] > refreshes[1] > refreshes[2]


def test_version_at_tie_rule():
    cfg = _run()
    trace = schedule(cfg, 10)
    t0 = float(trace.event_time[0])
    # an event applies before a same-instant read; strictly-before reads
    # still see the old version
    assert int(trace.version_at(t0)) == 1
    assert int(trace.version_at(np.nextafter(t0, 0.0))) == 0
    assert int(trace.version_at(0.0)) == 0
    assert int(trace.version_at(float(trace.event_time[-1]))) == 10


def test_diurnal_traffic_and_caps():
    flat = FleetConfig(request_rate=4.0)
    diurnal = dataclasses.replace(flat, diurnal_amplitude=0.9)
    trace = schedule(_run(serving=False), 40)
    sv_flat = schedule_serving(trace, flat, seed=0)
    sv_diur = schedule_serving(trace, diurnal, seed=0)
    assert sv_flat.n_requests > 0 and sv_diur.n_requests > 0
    # thinning only removes arrivals relative to the homogeneous envelope
    assert sv_diur.n_requests <= schedule_serving(
        trace, dataclasses.replace(flat, request_rate=4.0 * 1.9),
        seed=0).n_requests
    capped = schedule_serving(
        trace, dataclasses.replace(flat, max_requests=3), seed=0)
    assert capped.n_requests == 3 and capped.truncated


def test_replica_churn_drops_requests_only_while_fleet_dead():
    trace = schedule(_run(serving=False), 40)
    horizon = trace.simulated_time
    lo, hi = 0.25 * horizon, 0.5 * horizon
    fleet = FleetConfig(replicas=1, request_rate=8.0,
                        membership=((lo, 0, "crash"), (hi, 0, "join")))
    sv = schedule_serving(trace, fleet, seed=0)
    dead = (sv.request_time >= lo) & (sv.request_time < hi)
    assert dead.any() and (~dead).any()
    assert (sv.replica[dead] == -1).all()
    assert (sv.replica[~dead] == 0).all()
    # the restart re-publishes before serving again: budget still holds
    after = sv.served & (sv.request_time >= hi)
    assert int(sv.staleness[after].max(initial=0)) <= fleet.policy.max_version_lag


# ---------------------------------------------------------------------------
# the replay serving lane
# ---------------------------------------------------------------------------
def test_published_row_bitwise_equals_trained_weights(prob):
    """The tentpole contract: the snapshot serving version v is bit-for-bit
    the trained weights after v updates — checked by replaying each prefix
    of the (serving-free twin of the) trace and comparing a raw weight
    component exported through serve_eval_fn."""
    cfg = _run(PublicationPolicy(kind="every_n", every=1),
               protocol="async", ring_impl="stock")
    steps = 10
    trace = schedule(cfg, steps)
    sv = trace.serving
    assert sv.n_requests > 0
    sim = replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
                 batch_fn=prob.batch_fn_for(cfg.minibatch),
                 serve_batches=prob.stage_requests(sv, cfg.serving),
                 serve_eval_fn=lambda p, b: p["w1"][0, 0])
    got = sim.serving.request_metric

    bare = cfg.replace(serving=None)
    by_version = {0: float(np.asarray(prob.init["w1"])[0, 0])}
    for i in np.flatnonzero(sv.served):
        v = int(sv.version[i])
        if v not in by_version:
            prefix = schedule(bare, v)   # same rng: the first v rows
            np.testing.assert_array_equal(prefix.pulled_ts,
                                          trace.pulled_ts[:v])
            psim = replay(prefix, bare, grad_fn=prob.grad_fn,
                          init_params=prob.init,
                          batch_fn=prob.batch_fn_for(cfg.minibatch))
            by_version[v] = float(np.asarray(psim.params["w1"])[0, 0])
        assert got[i] == np.float32(by_version[v]), (i, v)


@pytest.mark.parametrize("impl", ["stock", "fused"])
def test_serving_leaves_training_bitwise_unchanged(impl, prob):
    cfg = _run(ring_impl=impl)
    sim = _replay(schedule(cfg, 24), cfg, prob)
    bare = cfg.replace(serving=None)
    sim0 = _replay(schedule(bare, 24), bare, prob)
    assert _tree_equal(sim.params, sim0.params)
    assert isinstance(sim.serving, ServingResult) and sim0.serving is None


def test_serving_with_learner_churn_bitwise_pin(prob):
    """Replica crash/restart AND learner churn mid-trace leave the training
    replay bitwise-unchanged vs the same churny run without serving."""
    fleet = FleetConfig(replicas=2, request_rate=2.0, request_samples=8,
                        membership=((2.0, 1, "crash"), (6.0, 1, "join")))
    cfg = _run(serving=False,
               membership=MembershipTimeline.crash_restart([1], 3.0, 8.0))
    cfg = cfg.replace(serving=fleet)
    sim = _replay(schedule(cfg, 24), cfg, prob)
    bare = cfg.replace(serving=None)
    sim0 = _replay(schedule(bare, 24), bare, prob)
    assert _tree_equal(sim.params, sim0.params)
    assert sim.serving.summary()["n_served"] > 0


def test_bf16_ring_publishes_quantized_snapshots(prob):
    """Tolerance policy (§14): with a bf16 ring the published snapshot is
    the quantized row — error-feedback residue excluded — so a served
    weight component equals the prefix-replayed fp32 weights rounded
    through bf16."""
    cfg = _run(PublicationPolicy(kind="every_n", every=1),
               protocol="async", ring_dtype="bf16")
    trace = schedule(cfg, 8)
    sv = trace.serving
    sim = replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
                 batch_fn=prob.batch_fn_for(cfg.minibatch),
                 serve_batches=prob.stage_requests(sv, cfg.serving),
                 serve_eval_fn=lambda p, b: p["w1"][0, 0])
    import jax.numpy as jnp
    bare = cfg.replace(serving=None)
    for i in np.flatnonzero(sv.served)[:3]:
        v = int(sv.version[i])
        want = (np.asarray(prob.init["w1"])[0, 0] if v == 0 else
                np.asarray(_replay(schedule(bare, v), bare, prob)
                           .params["w1"])[0, 0])
        want_q = np.float32(jnp.asarray(want).astype(jnp.bfloat16)
                            .astype(jnp.float32))
        assert sim.serving.request_metric[i] == want_q, (i, v)


def test_serving_metrics_flow_through_driver():
    spec = ExperimentSpec(run=_run(), problem="mlp_teacher", steps=20)
    res = run(spec)
    for key in ("serving_accuracy", "serving_staleness_mean",
                "serving_latency_p99_s"):
        assert key in res.metrics
    summary = res.runtime["serving"]
    assert summary["n_requests"] == summary["n_served"] + summary["n_dropped"]
    assert 0.0 <= res.metrics["serving_accuracy"] <= 1.0
    # record JSON roundtrip, serving config echoed
    rec = json.loads(json.dumps(res.record()))
    validate_record(rec)
    assert rec["spec"]["run"]["serving"]["replicas"] == 2


def test_sweep_serving_axis_runs_sequential():
    spec = ExperimentSpec(run=_run(), problem="mlp_teacher", steps=16)
    fleets = [None] + [
        FleetConfig(replicas=2, request_rate=2.0, request_samples=8,
                    policy=PublicationPolicy(max_version_lag=b))
        for b in (1, 8)]
    grid = list(Sweep.over(spec, serving=fleets))
    assert len(grid) == 3
    with pytest.warns(RuntimeWarning, match="serving lane"):
        results = run_sweep(grid)
    assert "serving_accuracy" not in results[0].metrics
    assert all("serving_accuracy" in r.metrics for r in results[1:])
    assert results[1].runtime["replay_path"] == "sequential"
    env = envelope("t", records=[r.record() for r in results])
    json.dumps(env)   # sweep fragments + records all JSON-serializable


def test_schedule_cached_keys_on_fleet():
    schedule_cached.cache_clear()
    cfg = _run()
    t1 = schedule_cached(cfg, 10)
    assert schedule_cached(cfg, 10) is t1
    t2 = schedule_cached(
        cfg.replace(serving=dataclasses.replace(
            cfg.serving, request_rate=9.0)), 10)
    assert t2 is not t1
    assert t2.serving.n_requests != t1.serving.n_requests


# ---------------------------------------------------------------------------
# guardrails
# ---------------------------------------------------------------------------
def test_replay_requires_serve_hooks(prob):
    cfg = _run()
    trace = schedule(cfg, 10)
    with pytest.raises(ValueError, match="serve_batches"):
        replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
               batch_fn=prob.batch_fn_for(cfg.minibatch))
    bare = cfg.replace(serving=None)
    with pytest.raises(ValueError, match="no serving lane"):
        replay(schedule(bare, 10), bare, grad_fn=prob.grad_fn,
               init_params=prob.init, batch_fn=prob.batch_fn_for(MU),
               serve_eval_fn=prob.request_metric)
    # trace/run serving mismatch is caught before any compile
    with pytest.raises(ValueError, match="serving lane"):
        replay(trace, bare, grad_fn=prob.grad_fn, init_params=prob.init,
               batch_fn=prob.batch_fn_for(MU))


def test_replay_batch_rejects_serving_traces(prob):
    cfg = _run()
    traces = [schedule(cfg.replace(seed=s), 10) for s in (0, 1)]
    with pytest.raises(ValueError, match="batched replay does not support "
                                         "serving"):
        replay_batch(traces, [cfg.replace(seed=s) for s in (0, 1)],
                     grad_fn=prob.grad_fn, init_params=prob.init,
                     batch_fns=[prob.batch_fn_for(MU)] * 2)


def test_spmd_replay_rejects_serving_traces(prob):
    cfg = _run()
    trace = schedule(cfg, 10)
    with pytest.raises(ValueError, match="placement='spmd'"):
        replay(trace, cfg, grad_fn=prob.grad_fn, init_params=prob.init,
               batch_fn=prob.batch_fn_for(MU), placement="spmd",
               serve_batches=prob.stage_requests(trace.serving, cfg.serving),
               serve_eval_fn=prob.request_metric)


def test_legacy_and_oracle_reject_serving(prob):
    with pytest.raises(ValueError, match="legacy"):
        ExperimentSpec(run=_run(), problem="mlp_teacher", steps=10,
                       engine="legacy")
    with pytest.raises(ValueError, match="oracle has no serving lane"):
        simulate(_run(), steps=5, grad_fn=prob.grad_fn,
                 init_params=prob.init, batch_fn=prob.batch_fn_for(MU))


def test_driver_errors_on_problem_without_serve_hooks():
    spec = ExperimentSpec(run=_run(optimizer="momentum"),
                          problem="quadratic_whatif",
                          problem_args={"d": 64}, steps=10)
    with pytest.raises(ValueError, match="serving hooks"):
        run(spec)
