"""Paper §5.5 optional features: warm-starting softsync from hardsync, and
AdaGrad as the softsync stabilizer (the paper's ImageNet recipe)."""

import numpy as np
import pytest

from repro.config import ModelConfig, RunConfig
from repro.train.loop import train

CFG = ModelConfig(name="w", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


@pytest.mark.slow   # two-phase training run; full lane
def test_warmstart_runs_and_learns():
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=4,
                    minibatch=2, base_lr=0.02, lr_policy="staleness_inverse",
                    optimizer="momentum", attn_q_chunk=32, attn_kv_chunk=32)
    res = train(CFG, run, steps=40, batch=8, seq=32, eval_every=20,
                warmstart_steps=10)
    assert np.isfinite(res.history[-1]["ce"])
    assert res.history[-1]["ce"] < 5.0   # below ~uniform after warm+train


def test_adagrad_softsync_stable():
    """The paper uses AdaGrad for 1-softsync ImageNet stability; the adaptive
    denominator must keep high-staleness training finite at an LR where it
    matters."""
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=4,
                    minibatch=2, base_lr=0.05, lr_policy="staleness_inverse",
                    optimizer="adagrad", attn_q_chunk=32, attn_kv_chunk=32)
    res = train(CFG, run, steps=40, batch=8, seq=32, eval_every=20)
    assert np.isfinite(res.history[-1]["ce"])
    # AdaGrad's shrinking step keeps it stable (finite, below uniform ln 64);
    # convergence speed is not the claim here
    assert res.history[-1]["ce"] < np.log(64)
