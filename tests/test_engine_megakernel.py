"""Replay megakernel + compressed weight ring (DESIGN.md §12).

Equivalence contract, pinned here:

* **Event level** (jit vs jit): the Pallas megakernel ``ring_apply`` /
  ``ring_apply_whatif`` (interpret mode on CPU) is BITWISE its fused jnp
  twin — and with an fp32 ring the twin is bitwise the flat
  ``apply_event_flat`` reference.
* **Engine level**: the fused scan body equals the stock pytree body
  bitwise on the trivial topology (the casts are no-ops); the Pallas body
  equals the fused body bitwise for stateless/adagrad cells and to fp32
  accumulation tolerance on momentum cells (XLA forms FMAs differently
  per compiled program at some ring depths — ~1 ulp/event).
* **Sharded**: fused ≡ pallas bitwise; vs the stock sharded body the
  combine einsum is phrased on (S, c, Dp) operands, which XLA lowers with
  different rounding, so agreement is fp32-tolerance, not bitwise.
* **bf16 ring**: the fp32 master chain (bf16 row + error-feedback
  residue) reconstructs the exact fp32 weights per event; end-to-end
  drift vs an fp32 ring stays within the documented tolerance because
  only *gradient evaluation points* are quantized.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core import replay, schedule
from repro.core.engine import _materialize_batches, replay_batch
from repro.core.trace import schedule_cached
from repro.kernels import replay_ring
from repro.membership import MembershipTimeline
from repro.optim import UpdateSpec
from repro.optim.backends import (apply_event_flat, apply_event_ring,
                                  apply_event_ring_whatif)


def _bw(a, b):
    """Bitwise array equality (NaN-free data)."""
    assert np.array_equal(np.asarray(a), np.asarray(b)), (
        f"max |diff| = {np.max(np.abs(np.asarray(a) - np.asarray(b)))}")


# ---------------------------------------------------------------------------
# shared tiny problem (linear regression, deterministic batches)
# ---------------------------------------------------------------------------
KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (6, 3))
X = jax.random.normal(jax.random.PRNGKey(1), (64, 6))
Y = X @ W_TRUE


def _loss(p, b):
    x, y = b
    return jnp.mean((x @ p["w"] - y) ** 2)


GRAD_FN = jax.jit(jax.grad(_loss))
INIT = {"w": jnp.zeros((6, 3))}


def _batch_fn(l, i):
    rng = np.random.default_rng(l * 9973 + i)
    idx = rng.integers(0, 64, size=8)
    return X[idx], Y[idx]


def _run(cfg, steps=24, **kw):
    trace = schedule(cfg, steps)
    return replay(trace, cfg, grad_fn=GRAD_FN, init_params=INIT,
                  batch_fn=_batch_fn, **kw)


# ---------------------------------------------------------------------------
# event level: megakernel ≡ fused twin ≡ flat reference, bitwise
# ---------------------------------------------------------------------------
def _event_operands(optimizer, ring_dtype, seed=3, K=5, c=4, width=700):
    spec = UpdateSpec(optimizer=optimizer)
    Dp = replay_ring.padded_width(width)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    ring32 = jax.random.normal(ks[0], (K, Dp), jnp.float32)
    s = None if optimizer == "sgd" else jnp.zeros((Dp,))
    g = jax.random.normal(ks[1], (c, Dp)) * 0.1
    coef = jnp.abs(jax.random.normal(ks[2], (c,))) + 0.1
    lrs = jnp.full((c,), 0.05)
    if ring_dtype == "bf16":
        ring = ring32.astype(jnp.bfloat16)
        res = ring32[2] - ring[2].astype(jnp.float32)
    else:
        ring, res = ring32, None
    return spec, ring, ring32, s, res, g, coef, lrs


@pytest.mark.parametrize("ring_dtype", ["fp32", "bf16"])
@pytest.mark.parametrize("mode", ["combine", "sequential"])
@pytest.mark.parametrize("optimizer", ["sgd", "momentum", "adagrad"])
def test_event_megakernel_bitwise_vs_twin(optimizer, mode, ring_dtype):
    spec, ring, _, s, res, g, coef, lrs = _event_operands(
        optimizer, ring_dtype)
    idx = jnp.array([2, 3], jnp.int32)

    mega = jax.jit(functools.partial(
        replay_ring.ring_apply, spec=spec, mode=mode, interpret=True))
    twin = jax.jit(functools.partial(
        apply_event_ring, spec, prev=2, slot=3, mode=mode))
    rm, sm, resm = mega(ring, s, res, g, coef, lrs, idx)
    rt, st, rest = twin(ring=ring, s=s, res=res, g=g, coef=coef, lrs=lrs)
    _bw(rm, rt)
    if s is not None:
        _bw(sm, st)
    if res is not None:
        _bw(resm, rest)


@pytest.mark.parametrize("mode", ["combine"])
@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
def test_event_fp32_megakernel_bitwise_vs_flat_reference(optimizer, mode):
    """With an fp32 ring the megakernel event IS the stock chain: gather
    row, ``apply_event_flat``, ``.at[slot].set`` — bitwise in combine mode
    (the engine's mode everywhere).  Sequential mode re-associates the
    per-slot FMA chain differently across the two program phrasings, so
    its bitwise pin lives in the twin test above instead."""
    spec, ring, _, s, res, g, coef, lrs = _event_operands(optimizer, "fp32")
    idx = jnp.array([2, 3], jnp.int32)

    @jax.jit
    def stock(ring, s):
        w, s2 = apply_event_flat(spec, ring[2], s, g, coef, lrs, mode)
        return ring.at[3].set(w), s2

    @jax.jit
    def mega(ring, s):
        r2, s2, _ = replay_ring.ring_apply(ring, s, None, g, coef, lrs,
                                           idx, spec=spec, mode=mode,
                                           interpret=True)
        return r2, s2

    rs, ss = stock(ring, s)
    rm, sm = mega(ring, s)
    _bw(rm, rs)
    if s is not None:
        _bw(sm, ss)


@pytest.mark.parametrize("ring_dtype", ["fp32", "bf16"])
@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
def test_event_whatif_megakernel_bitwise_vs_twin(optimizer, ring_dtype):
    spec, ring, ring32, s, res, g, coef, lrs = _event_operands(
        optimizer, ring_dtype, c=3)
    Dp = ring.shape[1]
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    a = jnp.abs(jax.random.normal(ks[0], (Dp,))) + 0.5
    wstar = jax.random.normal(ks[1], (Dp,))
    ts = jnp.array([1, 2, 4], jnp.int32)
    idx = jnp.concatenate([jnp.array([2, 3], jnp.int32), ts])

    mega = jax.jit(functools.partial(
        replay_ring.ring_apply_whatif, spec=spec, interpret=True))
    twin = jax.jit(functools.partial(
        apply_event_ring_whatif, spec, ts=ts, prev=2, slot=3))
    rm, sm, resm = mega(ring, s, res, a, wstar, coef, lrs, idx)
    rt, st, rest = twin(ring=ring, s=s, res=res, a=a, wstar=wstar,
                        coef=coef, lrs=lrs)
    _bw(rm, rt)
    if s is not None:
        _bw(sm, st)
    if res is not None:
        _bw(resm, rest)


def test_event_bf16_master_chain_exact():
    """bf16 row + error-feedback residue reconstructs the EXACT fp32
    weights the fp32-ring event produced — compression never touches the
    master chain, only where gradients get evaluated."""
    spec, ring_bf, ring32, s, res, g, coef, lrs = _event_operands(
        "momentum", "bf16")
    idx = jnp.array([2, 3], jnp.int32)
    r32, s32, _ = jax.jit(functools.partial(
        replay_ring.ring_apply, spec=spec, interpret=True))(
            ring32, s, None, g, coef, lrs, idx)
    rbf, sbf, resb = jax.jit(functools.partial(
        replay_ring.ring_apply, spec=spec, interpret=True))(
            ring_bf, s, res, g, coef, lrs, idx)
    master = rbf[3].astype(jnp.float32) + resb
    _bw(master, r32[3])


# ---------------------------------------------------------------------------
# error-feedback residue: |res| is bounded by bf16 rounding of the master
# ---------------------------------------------------------------------------
def _residue_bound_holds(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * (
        10.0 ** (seed % 7 - 3))
    q = w.astype(jnp.bfloat16)
    res = np.asarray(w - q.astype(jnp.float32))
    # round-to-nearest bf16: |w - q(w)| <= 2^-8 ulp-scale |w| (+ denormal
    # floor); the EF residue is exactly this quantization error
    bound = np.abs(np.asarray(w)) * 2.0 ** -8 + 1e-38
    return bool(np.all(np.abs(res) <= bound))


@pytest.mark.parametrize("seed", range(12))
def test_ef_residue_bounded(seed):
    assert _residue_bound_holds(seed)


def test_ef_residue_bounded_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=30, derandomize=True)
    @given(st.integers(0, 2 ** 20))
    def prop(seed):
        assert _residue_bound_holds(seed)
    prop()


# ---------------------------------------------------------------------------
# dispatch branch: the CPU fallback and the counters
# ---------------------------------------------------------------------------
def test_dispatch_counters_and_interpret_default():
    spec, ring, _, s, res, g, coef, lrs = _event_operands("sgd", "fp32")
    before = replay_ring.pallas_dispatches
    replay_ring.ring_apply(ring, s, res, g, coef, lrs,
                           jnp.array([2, 3], jnp.int32), spec=spec)
    assert replay_ring.pallas_dispatches == before + 1
    # off-accelerator the kernel auto-selects interpret mode (CPU CI)
    expect = jax.default_backend() != "tpu"
    assert replay_ring.default_interpret() is expect
    assert replay_ring.last_interpret is expect


def test_engine_pallas_path_dispatches_kernel():
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=4,
                    minibatch=8, base_lr=0.05, optimizer="sgd", seed=3,
                    ring_impl="pallas")
    before = replay_ring.pallas_dispatches
    _run(cfg, steps=6)
    assert replay_ring.pallas_dispatches > before


# ---------------------------------------------------------------------------
# engine level: fused ≡ stock bitwise on the trivial topology
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("protocol,n", [("async", 1), ("softsync", 2),
                                        ("hardsync", 1)])
@pytest.mark.parametrize("optimizer", ["sgd", "momentum", "adagrad"])
def test_engine_fused_bitwise_vs_stock(protocol, n, optimizer):
    kw = dict(protocol=protocol, n_softsync=n, n_learners=8, minibatch=8,
              base_lr=0.05, lr_policy="staleness_inverse",
              optimizer=optimizer, seed=11)
    fused = _run(RunConfig(ring_impl="fused", **kw))
    stock = _run(RunConfig(ring_impl="stock", **kw))
    _bw(fused.params["w"], stock.params["w"])


def test_engine_fused_bitwise_vs_stock_elastic_mask():
    """Masked (elastic) replay: cancelled slots zero out identically in
    both scan bodies."""
    churn = MembershipTimeline(((1.0, 3, "crash"), (2.5, 3, "join"),
                                (4.0, 6, "leave")))
    kw = dict(protocol="softsync", n_softsync=2, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer="momentum", seed=13, membership=churn)
    fused = _run(RunConfig(ring_impl="fused", **kw))
    stock = _run(RunConfig(ring_impl="stock", **kw))
    _bw(fused.params["w"], stock.params["w"])


def test_engine_fused_bitwise_vs_stock_grouped():
    kw = dict(protocol="softsync", n_softsync=2, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer="momentum", seed=5, groups=4)
    fused = _run(RunConfig(ring_impl="fused", **kw))
    stock = _run(RunConfig(ring_impl="stock", **kw))
    _bw(fused.params["w"], stock.params["w"])


# ---------------------------------------------------------------------------
# engine level: pallas vs fused
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("optimizer", ["sgd", "adagrad"])
def test_engine_pallas_bitwise_vs_fused(optimizer):
    kw = dict(protocol="softsync", n_softsync=2, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer=optimizer, seed=7)
    pallas = _run(RunConfig(ring_impl="pallas", **kw))
    fused = _run(RunConfig(ring_impl="fused", **kw))
    _bw(pallas.params["w"], fused.params["w"])


def test_engine_pallas_vs_fused_momentum_tolerance():
    """Momentum cells drift ~1 ulp/event between the two compiled
    programs (XLA forms the v-update FMA differently at some ring
    depths); the event-level test above is bitwise, so pin the
    engine-level agreement at fp32 accumulation tolerance."""
    kw = dict(protocol="softsync", n_softsync=4, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer="momentum", seed=7)
    pallas = _run(RunConfig(ring_impl="pallas", **kw))
    fused = _run(RunConfig(ring_impl="fused", **kw))
    np.testing.assert_allclose(np.asarray(pallas.params["w"]),
                               np.asarray(fused.params["w"]),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded topology
# ---------------------------------------------------------------------------
def test_engine_sharded_fused_bitwise_vs_pallas_and_tol_vs_stock():
    kw = dict(protocol="softsync", n_softsync=2, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer="momentum", seed=19, shards=2)
    fused = _run(RunConfig(ring_impl="fused", **kw))
    pallas = _run(RunConfig(ring_impl="pallas", **kw))
    stock = _run(RunConfig(ring_impl="stock", **kw))
    _bw(fused.params["w"], pallas.params["w"])
    # stock shard body phrases the combine einsum on (S, c, Dp) operands —
    # XLA lowers that with different rounding (~1 ulp/event), so the
    # cross-body contract is fp32 tolerance, not bitwise (DESIGN.md §12)
    np.testing.assert_allclose(np.asarray(fused.params["w"]),
                               np.asarray(stock.params["w"]),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# bf16 compressed ring, engine level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ring_impl", ["fused", "pallas"])
def test_engine_bf16_ring_tolerance(ring_impl):
    """End-to-end bf16-ring drift vs the fp32 ring: gradients get
    evaluated at quantized snapshots, nothing else changes — documented
    tolerance ~1e-3 on O(1) weights over 24 steps."""
    kw = dict(protocol="softsync", n_softsync=2, n_learners=8, minibatch=8,
              base_lr=0.05, optimizer="momentum", seed=23)
    bf = _run(RunConfig(ring_impl=ring_impl, ring_dtype="bf16", **kw))
    fp = _run(RunConfig(ring_impl=ring_impl, ring_dtype="fp32", **kw))
    np.testing.assert_allclose(np.asarray(bf.params["w"]),
                               np.asarray(fp.params["w"]),
                               rtol=0, atol=5e-3)
    drift = np.max(np.abs(np.asarray(bf.params["w"]) -
                          np.asarray(fp.params["w"])))
    assert drift > 0.0          # the ring really was quantized


# ---------------------------------------------------------------------------
# what-if replay (in-kernel closed-form gradients)
# ---------------------------------------------------------------------------
def _whatif_operands(d=600, seed=0):
    i = jnp.arange(d, dtype=jnp.float32)
    a = 0.5 + (i % 100.0) / 100.0
    wstar = jnp.sin(0.01 * i)
    return a, wstar


def _whatif_run(cfg, steps=24, impl=None):
    a, wstar = _whatif_operands()
    cfg = cfg if impl is None else cfg.replace(ring_impl=impl)
    trace = schedule(cfg, steps)
    init = {"w": jnp.zeros((a.shape[0],), jnp.float32)}
    if cfg.ring_impl == "stock":
        def grad_fn(p, b):
            return {"w": a * (p["w"] - wstar)}
        return replay(trace, cfg, grad_fn=grad_fn, init_params=init,
                      batch_fn=lambda l, i: np.zeros((1,), np.float32))
    return replay(trace, cfg, init_params=init,
                  flat_grad=("quadratic", a, wstar))


def test_whatif_pallas_bitwise_vs_fused():
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=1, base_lr=0.02, optimizer="momentum",
                    seed=29)
    _bw(_whatif_run(cfg, impl="pallas").params["w"],
        _whatif_run(cfg, impl="fused").params["w"])


def test_whatif_matches_staged_stock():
    """The in-kernel closed-form gradients equal the staged twin to fp32
    accumulation tolerance (the streamed fori accumulation orders the
    c-sum differently from the einsum)."""
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=1, base_lr=0.02, optimizer="momentum",
                    seed=29)
    whatif = _whatif_run(cfg, steps=64, impl="fused")
    stock = _whatif_run(cfg, steps=64, impl="stock")
    np.testing.assert_allclose(np.asarray(whatif.params["w"]),
                               np.asarray(stock.params["w"]),
                               rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# staged batches, batched replay, config plumbing
# ---------------------------------------------------------------------------
def test_replay_batches_equals_batch_fn():
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=8, base_lr=0.05, optimizer="momentum",
                    seed=31)
    trace = schedule(cfg, 16)
    staged = _materialize_batches(trace, _batch_fn)
    via_fn = replay(trace, cfg, grad_fn=GRAD_FN, init_params=INIT,
                    batch_fn=_batch_fn)
    via_staged = replay(trace, cfg, grad_fn=GRAD_FN, init_params=INIT,
                        batches=staged)
    _bw(via_fn.params["w"], via_staged.params["w"])


def test_replay_batch_fused_matches_singles():
    cfgs = [RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                      minibatch=8, base_lr=0.05, optimizer="momentum",
                      seed=s, ring_impl="fused") for s in (41, 43)]
    traces = [schedule(c, 16) for c in cfgs]
    batch = replay_batch(traces, cfgs, grad_fn=GRAD_FN, init_params=INIT,
                         batch_fns=[_batch_fn, _batch_fn])
    singles = [replay(t, c, grad_fn=GRAD_FN, init_params=INIT,
                      batch_fn=_batch_fn) for t, c in zip(traces, cfgs)]
    for b, s in zip(batch, singles):
        np.testing.assert_allclose(np.asarray(b.params["w"]),
                                   np.asarray(s.params["w"]),
                                   rtol=0, atol=1e-6)


def test_replay_batch_rejects_mixed_ring_config():
    cfgs = [RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                      minibatch=8, seed=41, ring_impl="fused"),
            RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                      minibatch=8, seed=43, ring_impl="stock")]
    traces = [schedule(c, 8) for c in cfgs]
    with pytest.raises(ValueError, match="ring"):
        replay_batch(traces, cfgs, grad_fn=GRAD_FN, init_params=INIT,
                     batch_fns=[_batch_fn, _batch_fn])


@pytest.mark.parametrize("bad", [dict(ring_dtype="fp16"),
                                 dict(ring_impl="xla"),
                                 dict(ring_dtype="bf16", ring_impl="stock"),
                                 dict(ring_dtype="bf16", optimizer="adamw")])
def test_ring_config_validation(bad):
    with pytest.raises(ValueError):
        RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                  minibatch=8, **bad)


def test_schedule_cached_identity_and_shape_key():
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=8, seed=47)
    t1 = schedule_cached(cfg, 16)
    t2 = schedule_cached(cfg, 16)
    assert t1 is t2                       # one trace object per (run, steps)
    assert schedule_cached(cfg, 17) is not t1
    assert schedule_cached(cfg.replace(seed=48), 16) is not t1
    # the cache must agree with a fresh schedule
    fresh = schedule(cfg, 16)
    np.testing.assert_array_equal(t1.pulled_ts, fresh.pulled_ts)
    np.testing.assert_array_equal(t1.learner, fresh.learner)
