"""Property-based tests (hypothesis) for the topology subsystem: the
shard-partition invariance of the fused event apply over ANY valid shard
boundary (the kernel update is elementwise, so sharding the buffer is pure
layout), and group/pusher accounting invariants of the schedule pass."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.config import RunConfig
from repro.core import Topology, schedule
from repro.optim import flatten

SET = dict(deadline=None, max_examples=20, derandomize=True)


def _boundaries(draw, dim):
    """Random ordered cut points → list of [lo, hi) covering [0, dim)."""
    n_cuts = draw(st.integers(0, min(6, dim - 1)))
    cuts = sorted(draw(st.sets(st.integers(1, dim - 1),
                               min_size=n_cuts, max_size=n_cuts)))
    edges = [0] + cuts + [dim]
    return list(zip(edges[:-1], edges[1:]))


@settings(**SET)
@given(st.data(),
       st.sampled_from(["sgd", "momentum", "adagrad"]),
       st.sampled_from(["combine", "sequential"]))
def test_any_shard_boundary_partitions_apply_event(data, optimizer, mode):
    """apply_event_flat over ANY contiguous partition of the flat buffer
    equals the unsharded update exactly (per-element ops are identical)."""
    dim = data.draw(st.integers(2, 40))
    c = data.draw(st.integers(1, 4))
    bounds = _boundaries(data.draw, dim)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    spec = optim.UpdateSpec(optimizer=optimizer,
                            momentum=data.draw(st.floats(0.0, 0.99)))
    w = jnp.asarray(rng.normal(size=dim), jnp.float32)
    s = (None if optimizer == "sgd"
         else jnp.asarray(rng.random(dim), jnp.float32))
    g = jnp.asarray(rng.normal(size=(c, dim)), jnp.float32)
    coef = jnp.full((c,), 1.0 / c, jnp.float32)
    lrs = jnp.asarray(rng.uniform(0.01, 0.5, size=c), jnp.float32)
    w_full, s_full = optim.apply_event_flat(spec, w, s, g, coef, lrs, mode)
    parts = [optim.apply_event_flat(
                 spec, w[lo:hi], None if s is None else s[lo:hi],
                 g[:, lo:hi], coef, lrs, mode)
             for lo, hi in bounds]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p[0]) for p in parts]),
        np.asarray(w_full))
    if s is not None:
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p[1]) for p in parts]),
            np.asarray(s_full))


@settings(**SET)
@given(st.data(), st.sampled_from(["sgd", "momentum", "adagrad"]))
def test_equal_width_shard_pack_roundtrip_and_apply(data, optimizer):
    """shard_pack/shard_unpack invert, and the vmapped sharded apply
    reproduces the flat apply on the equal-width layout for any (D, S)."""
    dim = data.draw(st.integers(1, 33))
    shards = data.draw(st.integers(1, 8))
    c = data.draw(st.integers(1, 3))
    dp = Topology(shards=shards).padded_width(dim)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    spec = optim.UpdateSpec(optimizer=optimizer)
    w = jnp.asarray(rng.normal(size=dim), jnp.float32)
    s = (None if optimizer == "sgd"
         else jnp.asarray(rng.random(dim), jnp.float32))
    g = jnp.asarray(rng.normal(size=(c, dim)), jnp.float32)
    coef = jnp.full((c,), 1.0 / c, jnp.float32)
    lrs = jnp.asarray(rng.uniform(0.01, 0.5, size=c), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(flatten.shard_unpack(flatten.shard_pack(w, shards, dp),
                                        dim)),
        np.asarray(w))
    ws, ss = optim.apply_event_sharded(
        spec, flatten.shard_pack(w, shards, dp),
        None if s is None else flatten.shard_pack(s, shards, dp),
        flatten.shard_pack_grads(g, shards, dp), coef, lrs, "combine")
    w_full, _ = optim.apply_event_flat(spec, w, s, g, coef, lrs, "combine")
    np.testing.assert_allclose(
        np.asarray(flatten.shard_unpack(ws, dim)), np.asarray(w_full),
        atol=1e-6, rtol=1e-6)


@settings(deadline=None, max_examples=12, derandomize=True)
@given(st.integers(2, 24), st.data())
def test_grouped_schedule_invariants(lam, data):
    """For any G | λ: P = G pushers, σ ≥ 0, minibatch accounting counts
    every member gradient, and member blocks tile [0, λ)."""
    divisors = [g for g in range(1, lam + 1) if lam % g == 0]
    groups = data.draw(st.sampled_from(divisors))
    n = data.draw(st.integers(1, max(1, groups)))
    run = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                    groups=groups, minibatch=8, seed=lam * 31 + groups)
    tr = schedule(run, 60)
    gs = lam // groups
    assert tr.group_size == gs
    assert tr.c == max(1, groups // n)
    assert tr.minibatches == 60 * tr.c * gs
    assert (tr.staleness >= 0).all()
    assert int(tr.learner.max()) < groups
    mem = tr.member_learners()
    if gs == 1:
        assert mem is None
    else:
        assert mem.shape == (60, tr.c, gs)
        assert set(np.unique(mem)) <= set(range(lam))
