"""The compiled trace/replay engine (DESIGN.md §4) against its oracle, the
legacy per-arrival loop: numerical equivalence on identical traces, the
ring-buffer staleness bound, Fig.-4 statistics off the trace path, and the
heterogeneous/straggler duration models."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core import replay, schedule, simulate
from repro.experiments.driver import execute
from repro.core.trace import as_learner_sampler, make_duration_sampler


# ---------------------------------------------------------------------------
# shared toy problem: tiny linear regression, deterministic batches
# ---------------------------------------------------------------------------
KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (6, 3))
X = jax.random.normal(jax.random.PRNGKey(1), (64, 6))
Y = X @ W_TRUE


def _loss(p, b):
    x, y = b
    return jnp.mean((x @ p - y) ** 2)


GRAD_FN = jax.jit(jax.grad(_loss))


def _batch_fn(l, i):
    rng = np.random.default_rng(l * 9973 + i)
    idx = rng.integers(0, 64, size=8)
    return X[idx], Y[idx]


def _clocks_matrix(log):
    return np.array([r.gradient_timestamps for r in log.records])


# ---------------------------------------------------------------------------
# oracle equivalence: the acceptance grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lam", [4, 8])
@pytest.mark.parametrize("protocol,n", [("async", 1), ("softsync", 2),
                                        ("hardsync", 1)])
@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
@pytest.mark.parametrize("lr_policy", ["staleness_inverse", "per_gradient"])
def test_replay_equals_legacy_loop(lam, protocol, n, optimizer, lr_policy):
    run = RunConfig(protocol=protocol, n_softsync=n, n_learners=lam,
                    minibatch=8, base_lr=0.05, lr_policy=lr_policy,
                    optimizer=optimizer, seed=7 + lam)
    kw = dict(steps=25, grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
              batch_fn=_batch_fn)
    legacy = simulate(run, **kw)
    compiled = execute(run, **kw)
    np.testing.assert_allclose(np.asarray(compiled.params),
                               np.asarray(legacy.params),
                               atol=1e-5, rtol=1e-5)
    # identical arrival order: vector clocks match exactly
    np.testing.assert_array_equal(_clocks_matrix(compiled.clock_log),
                                  _clocks_matrix(legacy.clock_log))
    assert compiled.simulated_time == pytest.approx(legacy.simulated_time)
    assert compiled.updates == legacy.updates


def test_replay_equals_legacy_scalar_and_per_gradient_history():
    """Eval histories line up (same update indices, times, and metrics)."""
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=8,
                    minibatch=8, base_lr=0.05, lr_policy="staleness_inverse",
                    optimizer="momentum", seed=11)
    eval_fn = lambda p: {"err": float(jnp.mean((X @ p - Y) ** 2))}
    kw = dict(steps=40, grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
              batch_fn=_batch_fn, eval_fn=eval_fn, eval_every=10)
    legacy = simulate(run, **kw)
    compiled = execute(run, **kw)
    assert len(compiled.history) == len(legacy.history) == 4
    for a, b in zip(compiled.history, legacy.history):
        assert a["update"] == b["update"]
        assert a["time"] == pytest.approx(b["time"])
        assert a["err"] == pytest.approx(b["err"], rel=1e-4, abs=1e-6)


def test_schedule_matches_measure_mode():
    """The schedule pass IS measure mode: same clocks, time, minibatches."""
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                    minibatch=16, seed=5)
    tr = schedule(run, 300)
    res = simulate(run, steps=300)
    np.testing.assert_array_equal(tr.pulled_ts,
                                  _clocks_matrix(res.clock_log))
    assert tr.simulated_time == pytest.approx(res.simulated_time)
    assert tr.minibatches == res.minibatches


# ---------------------------------------------------------------------------
# Fig.-4 statistics and the ring-buffer bound, trace-native
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 4, 30])
def test_trace_fig4_statistics(n):
    run = RunConfig(protocol="softsync", n_softsync=n, n_learners=30,
                    minibatch=128, seed=3)
    tr = schedule(run, 1500)
    log = tr.clock_log()
    assert abs(log.mean_staleness() - n) < max(1.0, 0.25 * n)
    assert log.fraction_exceeding(2 * n) < 1e-3
    # the ring-buffer size the replay engine derives is the 2n bound + slack
    assert tr.max_staleness <= 2 * n + 2
    assert (tr.staleness >= 0).all()


def test_hardsync_trace_zero_staleness_and_k1():
    run = RunConfig(protocol="hardsync", n_learners=10, minibatch=32)
    tr = schedule(run, 40)
    assert tr.max_staleness == 0          # replay keeps a single snapshot
    assert tr.clock_log().mean_staleness() == 0.0
    assert tr.c == 10 and tr.minibatches == 400


# ---------------------------------------------------------------------------
# duration models: two-speed heterogeneous cluster + Pareto stragglers
# ---------------------------------------------------------------------------
def test_two_speed_cluster_starves_slow_learners():
    lam = 8
    run = RunConfig(protocol="async", n_learners=lam, minibatch=16,
                    duration_model="two_speed", slow_fraction=0.25,
                    slow_factor=4.0, seed=2)
    tr = schedule(run, 400)
    counts = np.bincount(tr.learner.reshape(-1), minlength=lam)
    n_slow = 2                                    # 0.25 · 8
    assert counts[:n_slow].max() < counts[n_slow:].min()
    # slow learners hold weights ~4× longer ⇒ their gradients are staler
    sig = tr.staleness
    slow_sig = sig[np.isin(tr.learner, np.arange(n_slow))].mean()
    fast_sig = sig[~np.isin(tr.learner, np.arange(n_slow))].mean()
    assert slow_sig > fast_sig


def test_pareto_stragglers_heavier_tail_than_homogeneous():
    base = dict(protocol="softsync", n_softsync=4, n_learners=16,
                minibatch=16, seed=9)
    homo = schedule(RunConfig(**base), 400)
    par = schedule(RunConfig(duration_model="pareto", pareto_alpha=1.5,
                             pareto_scale=1.0, **base), 400)
    # heavy tail stretches the simulated clock and the staleness extremes
    assert par.simulated_time > homo.simulated_time
    assert par.staleness.max() >= homo.staleness.max()


def test_legacy_two_arg_sampler_accepted():
    run = RunConfig(protocol="softsync", n_softsync=2, n_learners=4,
                    minibatch=8, seed=1)
    tr = schedule(run, 50, duration_sampler=lambda rng, mu: 1.0)
    assert tr.simulated_time > 0
    s3 = as_learner_sampler(make_duration_sampler(run))
    assert s3(np.random.default_rng(0), 8, 0) > 0


# ---------------------------------------------------------------------------
# replay plumbing details
# ---------------------------------------------------------------------------
def test_replay_on_prescheduled_trace_with_hw_sampler():
    """schedule() and replay() compose explicitly, with the runtime axis
    read off the trace (core/tradeoff.minibatch_duration_sampler)."""
    from repro.core import tradeoff as to
    run = RunConfig(protocol="softsync", n_softsync=2, n_learners=4,
                    minibatch=8, base_lr=0.05, optimizer="sgd", seed=0)
    sampler = to.minibatch_duration_sampler("base", run.n_learners)
    tr = schedule(run, 30, duration_sampler=sampler)
    res = replay(tr, run, grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
                 batch_fn=_batch_fn)
    axis = to.runtime_axis(tr)
    assert axis.shape == (30,) and (np.diff(axis) >= 0).all()
    assert res.simulated_time == pytest.approx(float(axis[-1]))
    assert np.isfinite(np.asarray(res.params)).all()


def test_replay_rejects_mismatched_config():
    """A trace is only valid for the RunConfig that scheduled it."""
    run = RunConfig(protocol="softsync", n_softsync=2, n_learners=4,
                    minibatch=8, base_lr=0.05, optimizer="sgd", seed=0)
    tr = schedule(run, 10)
    kw = dict(grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
              batch_fn=_batch_fn)
    with pytest.raises(ValueError):                  # different c/λ
        replay(tr, run.replace(n_learners=8), **kw)
    with pytest.raises(ValueError):                  # silent-LR-sweep hazard
        replay(tr, run.replace(base_lr=0.5), **kw)
    with pytest.raises(ValueError):                  # policy/mode mismatch
        replay(tr, run.replace(lr_policy="per_gradient"), **kw)


def test_replay_learns_on_mlp_problem():
    """End-to-end sanity: compiled engine actually trains (error drops)."""
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=8,
                    minibatch=8, base_lr=0.1, lr_policy="staleness_inverse",
                    optimizer="momentum", seed=4)
    res = execute(run, steps=400, grad_fn=GRAD_FN,
                            init_params=jnp.zeros((6, 3)),
                            batch_fn=_batch_fn)
    err = float(jnp.mean((X @ res.params - Y) ** 2))
    err0 = float(jnp.mean(Y ** 2))
    assert err < 0.1 * err0
