"""Cross-validation between the two implementations of the paper's
protocols: the event-driven simulator (host PS, faithful arrival semantics)
and the SPMD distributed engines must agree wherever their semantics
coincide (hardsync: exactly; round-based softsync: per the documented
round-structure)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core import init_opt_state, make_train_step, simulate


def _problem():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (6, 3))
    X = jax.random.normal(jax.random.PRNGKey(1), (32, 6))
    return W, X, X @ W


def test_hardsync_simulator_equals_engine():
    """One hardsync update with λ learners == one engine step on the same
    global batch (Eq. 3 is a mean either way)."""
    W, X, Y = _problem()
    lam, mu = 4, 8
    run = RunConfig(protocol="hardsync", n_learners=lam, minibatch=mu,
                    base_lr=0.1, lr_policy="const", optimizer="sgd", seed=0)

    def loss(p, b):
        x, y = b
        return jnp.mean((x @ p - y) ** 2)
    grad_fn = jax.jit(jax.grad(loss))

    def batch_fn(l, step):
        return X[l * mu:(l + 1) * mu], Y[l * mu:(l + 1) * mu]

    sim = simulate(run, steps=1, grad_fn=grad_fn,
                   init_params=jnp.zeros((6, 3)), batch_fn=batch_fn)

    def eng_loss(p, b, sample_weights=None):
        per = jnp.mean((b["x"] @ p - b["y"]) ** 2, axis=-1)
        if sample_weights is not None:
            per = per * sample_weights
        return jnp.mean(per), {"loss": jnp.mean(per)}
    step = jax.jit(make_train_step(run, eng_loss))
    p_eng, _, _ = step(jnp.zeros((6, 3)), init_opt_state(run, run and
                                                         jnp.zeros((6, 3))),
                       {"x": X, "y": Y})
    np.testing.assert_allclose(np.asarray(sim.params), np.asarray(p_eng),
                               atol=1e-6)


def test_momentum_hardsync_cross_validation():
    W, X, Y = _problem()
    lam, mu = 4, 8
    run = RunConfig(protocol="hardsync", n_learners=lam, minibatch=mu,
                    base_lr=0.05, lr_policy="const", optimizer="momentum",
                    momentum=0.9, seed=0)

    def loss(p, b):
        x, y = b
        return jnp.mean((x @ p - y) ** 2)
    grad_fn = jax.jit(jax.grad(loss))

    def batch_fn(l, step):
        # same data each "round" across both implementations
        return X[l * mu:(l + 1) * mu], Y[l * mu:(l + 1) * mu]

    sim = simulate(run, steps=3, grad_fn=grad_fn,
                   init_params=jnp.zeros((6, 3)), batch_fn=batch_fn)

    def eng_loss(p, b, sample_weights=None):
        per = jnp.mean((b["x"] @ p - b["y"]) ** 2, axis=-1)
        return jnp.mean(per), {"loss": jnp.mean(per)}
    step = jax.jit(make_train_step(run, eng_loss))
    p = jnp.zeros((6, 3))
    opt = init_opt_state(run, p)
    for _ in range(3):
        p, opt, _ = step(p, opt, {"x": X, "y": Y})
    np.testing.assert_allclose(np.asarray(sim.params), np.asarray(p),
                               atol=1e-5)


def test_round_softsync_staleness_differs_from_pipelined_as_documented():
    """DESIGN.md §2: the SPMD round engine has ⟨σ⟩ = (n−1)/2; the pipelined
    simulator has ⟨σ⟩ ≈ n.  Both are staleness-bounded; the LR policy uses
    each engine's own measurement.  Verify the documented relationship."""
    from repro.core import simulate
    from repro.core.distributed import round_event_lrs
    n, lam = 8, 16
    run = RunConfig(protocol="softsync", n_softsync=n, n_learners=lam,
                    minibatch=4, base_lr=1.0, lr_policy="staleness_inverse",
                    seed=2)
    sim_sigma = simulate(run, steps=600).clock_log.mean_staleness()
    assert abs(sim_sigma - n) < 0.25 * n + 1          # pipelined: ≈ n
    lrs = round_event_lrs(run, n)
    assert np.allclose(lrs, 1.0 / ((n - 1) / 2))      # round: ⟨σ⟩=(n−1)/2
