"""Unified staleness-aware optimizer subsystem (repro.optim, DESIGN.md §3).

Backend-equivalence sweeps (reference / jit / pallas) across optimizer ×
mode × c with per-gradient staleness coefficients, dtype round-trips (bf16
params, fp32 accumulators), flat-buffer padding at odd sizes, and the two
regression tests from the applyUpdate unification: per-gradient LRs with
momentum (seed bug: silently fell back to plain SGD) and the fused softsync
engine's velocity carry (seed bug: dropped v0_coef)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.config import RunConfig
from repro.core import init_opt_state, make_train_step, simulate
from repro.core.lr_policies import make_lr_policy
from repro.core.protocols import ParameterServerState
from repro.optim import UpdateSpec, apply_update, init_state


def _mixed_tree(key, sizes=((300,), (17, 8), (4, 4, 4)), dtype=jnp.float32):
    ks = jax.random.split(key, len(sizes))
    return {f"p{i}": jax.random.normal(k, s, dtype)
            for i, (k, s) in enumerate(zip(ks, sizes))}


def _grads(key, like, c):
    return [jax.tree.map(
        lambda p, k=k: jax.random.normal(k, p.shape, p.dtype), like)
        for k in jax.random.split(key, c)]


# ---------------------------------------------------------------------------
# backend equivalence: optimizer × mode × c vs the jnp reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("optimizer", ["sgd", "momentum", "adagrad", "adamw"])
@pytest.mark.parametrize("mode", ["combine", "sequential"])
@pytest.mark.parametrize("c", [1, 3, 5])
def test_backend_equivalence(optimizer, mode, c):
    spec = UpdateSpec(optimizer=optimizer)
    params = _mixed_tree(jax.random.PRNGKey(c))
    grads = _grads(jax.random.PRNGKey(100 + c), params, c)
    # non-uniform per-gradient staleness coefficients + per-event LRs
    coef = jnp.asarray([1.0 / (i + 1) for i in range(c)]) / c
    lrs = jnp.asarray([0.1 / max(1.0, float(i)) for i in range(c)])
    outs = {}
    for backend in ("reference", "jit", "pallas"):
        p, s = apply_update(spec, params, init_state(spec, params),
                            grads, coef, lrs, mode=mode, backend=backend)
        # second call exercises state carry (and jit-cache reuse)
        p, s = apply_update(spec, p, s, grads, coef, lrs, mode=mode,
                            backend=backend)
        outs[backend] = (p, s)
    ref_p, ref_s = outs["reference"]
    for backend in ("jit", "pallas"):
        p, s = outs[backend]
        for k in params:
            np.testing.assert_allclose(np.asarray(p[k]),
                                       np.asarray(ref_p[k]), atol=1e-5,
                                       err_msg=f"{backend}:{k}")
        for sk, sv in ref_s.items():
            got = jax.tree.leaves(s[sk])
            want = jax.tree.leaves(sv)
            for a, b in zip(got, want):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, err_msg=f"{backend}:{sk}")


def test_adamw_pallas_falls_back_to_jit():
    """adamw has no kernel path; the pallas backend must transparently use
    the pytree path instead of crashing."""
    spec = UpdateSpec(optimizer="adamw")
    assert not spec.kernel_supported
    params = _mixed_tree(jax.random.PRNGKey(0))
    grads = _grads(jax.random.PRNGKey(1), params, 2)
    coef = jnp.asarray([0.5, 0.5])
    lrs = jnp.asarray([0.1, 0.1])
    p1, _ = apply_update(spec, params, init_state(spec, params), grads,
                         coef, lrs, backend="pallas")
    p2, _ = apply_update(spec, params, init_state(spec, params), grads,
                         coef, lrs, backend="jit")
    np.testing.assert_allclose(np.asarray(p1["p0"]), np.asarray(p2["p0"]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# dtype round-trip: bf16 params, fp32 accumulators
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("optimizer", ["momentum", "adagrad"])
@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_bf16_params_fp32_accumulators(optimizer, backend):
    spec = UpdateSpec(optimizer=optimizer)
    params = _mixed_tree(jax.random.PRNGKey(2), dtype=jnp.bfloat16)
    state = init_state(spec, params)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(state))
    grads = _grads(jax.random.PRNGKey(3), params, 3)
    coef = jnp.asarray([0.5, 0.3, 0.2])
    lrs = jnp.full((3,), 0.1)
    p, s = apply_update(spec, params, state, grads, coef, lrs,
                        backend=backend)
    p, s = apply_update(spec, p, s, grads, coef, lrs, backend=backend)
    # dtypes preserved through the flat-buffer round trip
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(p))
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(s))
    # values track the reference within bf16 resolution (state: fp32-tight
    # modulo the bf16-rounded params feeding event 2)
    rp, rs = apply_update(spec, params, init_state(spec, params), grads,
                          coef, lrs, backend="reference")
    rp, rs = apply_update(spec, rp, rs, grads, coef, lrs,
                          backend="reference")
    np.testing.assert_allclose(
        np.asarray(p["p0"], np.float32), np.asarray(rp["p0"], np.float32),
        atol=3e-2)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(s)[0]), np.asarray(jax.tree.leaves(rs)[0]),
        atol=1e-4)


def test_flat_buffer_padding_odd_sizes():
    """Leaf sizes chosen so the concatenated buffer needs lane + row-block
    padding; the pallas path must still bit-match the reference."""
    spec = UpdateSpec(optimizer="momentum")
    sizes = ((7,), (13, 5), (1,), (3, 3, 3), (127,))
    params = _mixed_tree(jax.random.PRNGKey(4), sizes=sizes)
    grads = _grads(jax.random.PRNGKey(5), params, 4)
    coef = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    lrs = jnp.full((4,), 0.05)
    for mode in ("combine", "sequential"):
        rp, rs = apply_update(spec, params, init_state(spec, params), grads,
                              coef, lrs, mode=mode, backend="reference")
        pp, ps = apply_update(spec, params, init_state(spec, params), grads,
                              coef, lrs, mode=mode, backend="pallas")
        for k in params:
            np.testing.assert_allclose(np.asarray(pp[k]), np.asarray(rp[k]),
                                       atol=1e-6, err_msg=f"{mode}:{k}")
            np.testing.assert_allclose(
                np.asarray(ps["velocity"][k]), np.asarray(rs["velocity"][k]),
                atol=1e-6)


def test_sequential_fold_matches_bruteforce_affine():
    """sequential_fold's full affine form (θ coefficients + v0 carry +
    velocity decay/gain) vs a brute-force momentum unroll."""
    rng = np.random.default_rng(0)
    for c, m in [(1, 0.9), (4, 0.9), (6, 0.5), (3, 0.0)]:
        lrs = rng.uniform(0.01, 0.2, size=c)
        fold = optim.sequential_fold(lrs, m)
        g = rng.normal(size=(c, 5))
        v0 = rng.normal(size=5)
        theta, v = np.zeros(5), v0.copy()
        for j in range(c):
            v = m * v + g[j]
            theta -= lrs[j] * v
        np.testing.assert_allclose(
            theta, -(fold.theta_coef @ g) - fold.v0_coef * v0, atol=1e-12)
        # velocity after the round: v' = m^c·v0 + Σ m^{c−1−i} g_i
        want_v = fold.v_decay * v0 + sum(
            m ** (c - 1 - i) * g[i] for i in range(c))
        np.testing.assert_allclose(v, want_v, atol=1e-12)
        # v_gain is the equal-gradients collapse of the second term
        np.testing.assert_allclose(
            fold.v_gain, sum(m ** (c - 1 - i) for i in range(c)), atol=1e-12)


# ---------------------------------------------------------------------------
# regression: per-gradient LRs + momentum (seed bug: bypassed the optimizer)
# ---------------------------------------------------------------------------
def test_ps_per_gradient_momentum_matches_sequential_events_oracle():
    """footnote 3 with momentum: the PS's fused update must equal applying
    the c gradients one-by-one (v ← m·v + G_i/c ; θ ← θ − α_i·v) with each
    gradient's own modulated LR, in arrival order."""
    base_lr, m, c = 0.2, 0.9, 3
    run = RunConfig(protocol="softsync", n_softsync=2, n_learners=6,
                    base_lr=base_lr, lr_policy="per_gradient",
                    optimizer="momentum", momentum=m)
    policy = make_lr_policy(run)
    params = {"w": jnp.ones((5, 4)), "b": jnp.zeros((7,))}
    ps = ParameterServerState(params, c=c, optimizer="momentum", momentum=m)
    rng = np.random.default_rng(0)
    pushes = []   # (grad, grad_timestamp), staleness varies across updates
    ts_pattern = [[0, 0, 0], [0, 1, 0], [0, 2, 1]]
    for upd, stamps in enumerate(ts_pattern):
        for t in stamps:
            g = jax.tree.map(
                lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32),
                params)
            pushes.append((g, t))
    for g, t in pushes:
        ps.push_gradient(g, t, policy)
    assert ps.timestamp == len(ts_pattern)

    # oracle: per-event momentum with α_i = α₀ / max(1, σ_i)
    theta = jax.tree.map(lambda p: np.asarray(p, np.float64), params)
    vel = jax.tree.map(lambda p: np.zeros(p.shape), params)
    for upd in range(len(ts_pattern)):
        batch = pushes[upd * c:(upd + 1) * c]
        alphas = policy(upd, [t for _, t in batch])
        for (g, _), a in zip(batch, alphas):
            vel = jax.tree.map(
                lambda v, gg: m * v + np.asarray(gg, np.float64) / c, vel, g)
            theta = jax.tree.map(lambda p, v: p - a * v, theta, vel)
    assert len(set(np.round(
        policy(2, [t for _, t in pushes[6:9]]), 6))) > 1   # LRs really vary
    for k in params:
        np.testing.assert_allclose(np.asarray(ps.params[k]), theta[k],
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(ps.velocity[k]), vel[k],
                                   atol=1e-5)


@pytest.mark.parametrize("optimizer", ["momentum", "adagrad"])
def test_ps_backends_agree(optimizer):
    """The same arrival sequence produces the same weights under every
    optim backend (per-gradient staleness LRs included)."""
    run = RunConfig(protocol="softsync", n_softsync=2, n_learners=4,
                    base_lr=0.1, lr_policy="per_gradient",
                    optimizer=optimizer)
    policy = make_lr_policy(run)
    params = {"w": jnp.ones((9, 3))}
    rng = np.random.default_rng(1)
    grads = [jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), params)
        for _ in range(6)]
    results = []
    for backend in ("reference", "jit", "pallas"):
        ps = ParameterServerState(params, c=2, optimizer=optimizer,
                                  backend=backend)
        for i, g in enumerate(grads):
            ps.push_gradient(g, max(0, i // 2 - 1), policy)
        results.append(np.asarray(ps.params["w"]))
    for r in results[1:]:
        np.testing.assert_allclose(r, results[0], atol=1e-5)


# ---------------------------------------------------------------------------
# regression: fused softsync engine velocity carry (seed bug: dropped v0_coef)
# ---------------------------------------------------------------------------
def test_fused_equals_sequential_momentum_multiround():
    """With identical per-group data the group-mean gradients coincide, so
    the fused engine's affine round fold must reproduce the sequential
    engine EXACTLY across rounds.  The seed engine diverged from round 2 on
    (wrong velocity decay, dropped θ carry)."""
    n, mu = 4, 8
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (6, 3))
    Xg = jax.random.normal(jax.random.PRNGKey(1), (mu, 6))
    Yg = Xg @ W
    batch = {"x": jnp.tile(Xg, (n, 1)), "y": jnp.tile(Yg, (n, 1))}

    def loss(p, b, sample_weights=None):
        per = jnp.mean((b["x"] @ p - b["y"]) ** 2, axis=-1)
        if sample_weights is not None:
            per = per * sample_weights
        return jnp.mean(per), {"loss": jnp.mean(per)}

    for lrp in ("const", "per_gradient", "staleness_inverse"):
        run = RunConfig(protocol="softsync", n_softsync=n, n_learners=8,
                        minibatch=mu, base_lr=0.05, lr_policy=lrp,
                        optimizer="momentum", momentum=0.9)
        seq = jax.jit(make_train_step(run, loss, engine="sequential"))
        fus = jax.jit(make_train_step(run, loss, engine="fused"))
        p1 = p2 = jnp.zeros((6, 3))
        o1 = init_opt_state(run, p1)
        o2 = init_opt_state(run, p2)
        for r in range(3):
            p1, o1, _ = seq(p1, o1, batch)
            p2, o2, _ = fus(p2, o2, batch)
            np.testing.assert_allclose(
                np.asarray(p1), np.asarray(p2), atol=1e-5,
                err_msg=f"{lrp} round {r}")
        np.testing.assert_allclose(np.asarray(o1["velocity"]),
                                   np.asarray(o2["velocity"]), atol=1e-5,
                                   err_msg=lrp)


# ---------------------------------------------------------------------------
# the simulator's sgd-mode hot path really fires the fused kernel
# ---------------------------------------------------------------------------
def test_simulator_sgd_hot_path_dispatches_pallas():
    before = optim.backends.pallas_dispatches
    run = RunConfig(protocol="softsync", n_softsync=4, n_learners=4,
                    minibatch=4, base_lr=0.1, lr_policy="staleness_inverse",
                    optimizer="momentum", seed=0)

    def loss(p, b):
        x, y = b
        return jnp.mean((x @ p - y) ** 2)
    grad_fn = jax.jit(jax.grad(loss))
    X = np.asarray(np.random.default_rng(0).normal(size=(64, 6)), np.float32)
    Wt = np.asarray(np.random.default_rng(1).normal(size=(6, 2)), np.float32)

    def batch_fn(l, i):
        idx = np.random.default_rng(l * 997 + i).integers(0, 64, size=4)
        return jnp.asarray(X[idx]), jnp.asarray(X[idx] @ Wt)

    res = simulate(run, steps=10, grad_fn=grad_fn,
                   init_params=jnp.zeros((6, 2)), batch_fn=batch_fn)
    assert res.updates == 10
    assert optim.backends.pallas_dispatches >= before + 10
