"""SPMD distributed replay (DESIGN.md §13): the emulated device mesh,
placement planning, the shard_mapped replay's equivalence pins against
single-device replay, the schedule-cache key audit, and the sharding-policy
PartitionSpec rules.

Device-dependent tests skip below their device count; the `multi-device` CI
lane runs the whole suite under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Tolerance policy (measured on CPU, pinned here):

* what-if spmd replay and ppermute-vs-all_gather assembly are **bitwise**;
* staged-gradient spmd paths track single-device replay to ~1 ulp/event
  (measured 6e-8..1.2e-7 after ~24 steps; XLA fuses the combine/update
  chain differently inside the shard_map body, and L > 1 psum partial-sum
  order) — pinned with atol=1e-5.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RunConfig
from repro.core import replay
from repro.core.engine import replay_batch
from repro.core.trace import (_REPLAY_ONLY_FIELDS, _schedule_key,
                              PlacementPlan, placement_plan, schedule_cached)
from repro.experiments.problems import QuadraticProblem
from repro.launch import mesh as mesh_lib
from repro.membership import MembershipTimeline
from repro.serve.fleet import FleetConfig

DEV = jax.device_count()


# ---------------------------------------------------------------------------
# shared toy problems
# ---------------------------------------------------------------------------
KEY = jax.random.PRNGKey(0)
W_TRUE = jax.random.normal(KEY, (6, 3))
X = jax.random.normal(jax.random.PRNGKey(1), (64, 6))
Y = X @ W_TRUE


def _loss(p, b):
    x, y = b
    return jnp.mean((x @ p - y) ** 2)


GRAD_FN = jax.jit(jax.grad(_loss))


def _batch_fn(l, i):
    rng = np.random.default_rng(l * 9973 + i)
    idx = rng.integers(0, 64, size=8)
    return X[idx], Y[idx]


def _cfg(**kw):
    base = dict(protocol="softsync", n_softsync=4, n_learners=16,
                minibatch=8, base_lr=0.05, lr_policy="staleness_inverse",
                optimizer="momentum", seed=7)
    base.update(kw)
    return RunConfig(**base)


def _replay_pair(cfg, steps=24, **kw):
    """(single, spmd) results for the SAME trace object."""
    trace = schedule_cached(cfg, steps)
    common = dict(grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
                  batch_fn=_batch_fn, **kw)
    single = replay(trace, cfg, **common)
    spmd = replay(trace, cfg, placement="spmd", **common)
    return single, spmd


def _assert_close(a, b, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=0)


# ---------------------------------------------------------------------------
# mesh bring-up: ensure_host_devices / debug meshes (satellite 1)
# ---------------------------------------------------------------------------
def test_ensure_host_devices_validates_n():
    with pytest.raises(ValueError, match="at least 1"):
        mesh_lib.ensure_host_devices(0)


def test_ensure_host_devices_noop_when_satisfied():
    assert mesh_lib.ensure_host_devices(1) == DEV
    assert mesh_lib.ensure_host_devices(DEV) == DEV


def test_ensure_host_devices_clear_error_after_init():
    """jax is live (DEV above) — asking for more devices than exist must
    raise the actionable error, not silently edit a dead env var."""
    before = os.environ.get("XLA_FLAGS")
    with pytest.raises(RuntimeError, match="ensure_host_devices"):
        mesh_lib.ensure_host_devices(4096)
    assert os.environ.get("XLA_FLAGS") == before


@pytest.mark.skipif(DEV >= 4, reason="needs a device-starved host")
def test_make_debug_mesh_names_the_fix():
    """The old failure was XLA's opaque mesh-shape error; now the message
    says how to launch."""
    with pytest.raises(RuntimeError, match="ensure_host_devices"):
        mesh_lib.make_debug_mesh()


@pytest.mark.skipif(DEV < 4, reason="needs >=4 emulated devices")
def test_debug_mesh_axes():
    m = mesh_lib.make_debug_mesh(2, 2)
    assert m.axis_names == ("data", "model")
    assert mesh_lib.data_axes(m) == ("data",)
    assert mesh_lib.n_learners(m) == 2
    assert mesh_lib.n_chips(m) == 4


@pytest.mark.skipif(DEV < 4, reason="needs >=4 emulated devices")
def test_sim_mesh_axes():
    m = mesh_lib.make_sim_mesh(2, 2)
    assert m.axis_names == mesh_lib.SIM_AXES == ("ps", "learner")
    # the sim mesh has no 'data'/'pod' axes: it is not a learner mesh
    assert mesh_lib.data_axes(m) == ()
    assert mesh_lib.n_chips(m) == 4


# ---------------------------------------------------------------------------
# placement planning
# ---------------------------------------------------------------------------
def test_placement_plan_auto_learners():
    cfg = _cfg(shards=4)                      # c = 16/4 = 4 slots
    trace = schedule_cached(cfg, 12)
    plan = placement_plan(trace, cfg, device_count=8)
    assert (plan.shards, plan.learners) == (4, 2)   # largest divisor of 4
    assert plan.devices == 8 and plan.slot_block == 2
    assert placement_plan(trace, cfg, device_count=4).learners == 1
    assert "4ps" in plan.describe()


def test_placement_plan_explicit_learners():
    cfg = _cfg(shards=2, placement="spmd", spmd_learners=2)
    trace = schedule_cached(cfg, 12)
    plan = placement_plan(trace, cfg, device_count=4)
    assert (plan.shards, plan.learners) == (2, 2)
    with pytest.raises(RuntimeError, match="spmd_learners"):
        placement_plan(trace, cfg, device_count=2)  # 2ps×2l needs 4


def test_placement_plan_device_shortfall_names_the_fix():
    cfg = _cfg(shards=4)
    trace = schedule_cached(cfg, 12)
    with pytest.raises(RuntimeError, match="ensure_host_devices"):
        placement_plan(trace, cfg, device_count=2)


def test_spmd_config_validation():
    with pytest.raises(ValueError, match="kernel-supported"):
        _cfg(placement="spmd", optimizer="adamw")
    with pytest.raises(ValueError, match="spmd"):
        _cfg(spmd_learners=2)                 # needs placement="spmd"
    with pytest.raises(ValueError, match="divide"):
        _cfg(placement="spmd", spmd_learners=3)   # c = 4
    with pytest.raises(ValueError, match="placement"):
        _cfg(placement="bogus")


def test_replay_rejects_unknown_placement_and_assembly():
    cfg = _cfg()
    trace = schedule_cached(cfg, 8)
    kw = dict(grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
              batch_fn=_batch_fn)
    with pytest.raises(ValueError, match="placement"):
        replay(trace, cfg, placement="multihost", **kw)
    with pytest.raises(ValueError, match="spmd_assembly"):
        replay(trace, cfg, placement="spmd", spmd_assembly="bogus", **kw)


def test_replay_batch_rejects_spmd_lanes():
    cfg = _cfg(placement="spmd")
    trace = schedule_cached(cfg, 8)
    with pytest.raises(ValueError, match="single-placement"):
        replay_batch([trace], [cfg], grad_fn=GRAD_FN,
                     init_params=jnp.zeros((6, 3)), batch_fns=[_batch_fn])


# ---------------------------------------------------------------------------
# equivalence pins: 1×1 mesh (always run — any device count)
# ---------------------------------------------------------------------------
def test_spmd_matches_single_combine_1x1():
    single, spmd = _replay_pair(_cfg())
    _assert_close(spmd.params, single.params)
    assert spmd.updates == single.updates
    assert spmd.simulated_time == pytest.approx(single.simulated_time)


def test_spmd_matches_single_sequential_1x1():
    single, spmd = _replay_pair(_cfg(lr_policy="per_gradient"))
    _assert_close(spmd.params, single.params)


def test_spmd_whatif_bitwise_1x1():
    prob = QuadraticProblem(d=64, seed=3)
    cfg = _cfg()
    trace = schedule_cached(cfg, 24)
    kw = dict(grad_fn=prob.grad_fn, init_params=prob.init,
              batch_fn=prob.batch_fn_for(cfg.minibatch),
              flat_grad=prob.flat_grad)
    single = replay(trace, cfg, **kw)
    spmd = replay(trace, cfg, placement="spmd", **kw)
    np.testing.assert_array_equal(np.asarray(spmd.params["w"]),
                                  np.asarray(single.params["w"]))


def test_spmd_eval_history_1x1():
    eval_fn = lambda p: {"err": float(jnp.mean((X @ p - Y) ** 2))}
    single, spmd = _replay_pair(_cfg(), steps=20, eval_fn=eval_fn,
                                eval_every=5)
    assert len(spmd.history) == len(single.history) == 4
    for a, b in zip(spmd.history, single.history):
        assert a["update"] == b["update"]
        assert a["time"] == pytest.approx(b["time"])
        assert a["err"] == pytest.approx(b["err"], abs=1e-5)


# ---------------------------------------------------------------------------
# equivalence pins: the 8-device emulated cluster (the CI multi-device lane)
# ---------------------------------------------------------------------------
need8 = pytest.mark.skipif(DEV < 8, reason="needs 8 emulated devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")


@need8
@pytest.mark.parametrize("shards", [2, 4])
def test_spmd_matches_single_sharded(shards):
    single, spmd = _replay_pair(_cfg(shards=shards))
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_matches_single_explicit_learners():
    # force the full 4ps×2learner mesh (c = 4 → slot_block 2): the psum
    # combine path, not just the L=1 full-width einsum
    single, spmd = _replay_pair(_cfg(shards=4, placement="spmd",
                                     spmd_learners=2))
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_matches_single_groups():
    single, spmd = _replay_pair(_cfg(n_softsync=2, groups=8))
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_matches_single_elastic_masked():
    churn = MembershipTimeline(((2.0, 0, "crash"), (3.5, 0, "join"),
                                (4.0, 1, "leave")))
    cfg = _cfg(n_softsync=2, n_learners=8, shards=2, membership=churn)
    trace = schedule_cached(cfg, 24)
    assert trace.valid is not None            # the masked path actually ran
    single, spmd = _replay_pair(cfg)
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_matches_single_bf16_ring():
    single, spmd = _replay_pair(_cfg(shards=4, ring_dtype="bf16"))
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_matches_single_pallas_ring():
    single, spmd = _replay_pair(_cfg(shards=4, ring_impl="pallas"),
                                steps=16)
    _assert_close(spmd.params, single.params)


@need8
def test_spmd_sequential_sharded():
    single, spmd = _replay_pair(_cfg(shards=2,
                                     lr_policy="per_gradient"))
    _assert_close(spmd.params, single.params)


@need8
def test_ppermute_assembly_bitwise():
    cfg = _cfg(shards=4)
    trace = schedule_cached(cfg, 24)
    kw = dict(grad_fn=GRAD_FN, init_params=jnp.zeros((6, 3)),
              batch_fn=_batch_fn)
    ag = replay(trace, cfg, placement="spmd", **kw)
    pp = replay(trace, cfg, placement="spmd", spmd_assembly="ppermute",
                **kw)
    np.testing.assert_array_equal(np.asarray(pp.params),
                                  np.asarray(ag.params))


@need8
def test_spmd_whatif_sharded():
    """What-if gradients are shard-local (no collectives), but at S > 1
    the single-device comparison point is the *staged* sharded replay —
    a different gradient code path — so this pin is ~1 ulp (measured
    3e-8), not bitwise; the same-path bitwise pin is the S=1 test above."""
    prob = QuadraticProblem(d=64, seed=3)
    cfg = _cfg(shards=4)
    trace = schedule_cached(cfg, 24)
    kw = dict(grad_fn=prob.grad_fn, init_params=prob.init,
              batch_fn=prob.batch_fn_for(cfg.minibatch),
              flat_grad=prob.flat_grad)
    single = replay(trace, cfg, **kw)
    spmd = replay(trace, cfg, placement="spmd", **kw)
    _assert_close(spmd.params["w"], single.params["w"])


# ---------------------------------------------------------------------------
# schedule_cached key audit (satellite 2)
# ---------------------------------------------------------------------------
# one entry PER RunConfig FIELD: the override dict that flips it to a valid
# non-default value (companion fields satisfy __post_init__ and are applied
# to both sides of the comparison, so only the audited field differs).
_CHURN = MembershipTimeline(((1.0, 0, "leave"),))
_FIELD_FLIPS = {
    "protocol": {"protocol": "async"},
    "n_softsync": {"protocol": "softsync", "n_softsync": 2},
    "n_learners": {"n_learners": 2},
    "minibatch": {"minibatch": 64},
    "base_lr": {"base_lr": 0.01},
    "ref_batch": {"ref_batch": 64},
    "lr_policy": {"lr_policy": "staleness_inverse"},
    "momentum": {"momentum": 0.5},
    "optimizer": {"optimizer": "adagrad"},
    "weight_decay": {"weight_decay": 0.01},
    "warmstart_epochs": {"warmstart_epochs": 1},
    "seed": {"seed": 1},
    "duration_model": {"duration_model": "two_speed"},
    "slow_fraction": {"slow_fraction": 0.5},
    "slow_factor": {"slow_factor": 2.0},
    "pareto_alpha": {"pareto_alpha": 2.0},
    "pareto_scale": {"pareto_scale": 1.0},
    "shards": {"shards": 2},
    "groups": {"n_learners": 4, "groups": 2},
    "shard_pull_jitter": {"shard_pull_jitter": 0.5},
    "ring_dtype": {"ring_dtype": "bf16"},
    "ring_impl": {"ring_impl": "fused"},
    "placement": {"placement": "spmd"},
    "spmd_learners": {"n_learners": 2, "placement": "spmd",
                      "spmd_learners": 2},
    "membership": {"n_learners": 4, "membership": _CHURN},
    "backup": {"n_learners": 4, "backup": 1},
    "num_microbatches": {"num_microbatches": 2},
    "remat": {"remat": False},
    "fsdp": {"fsdp": True},
    "use_pallas": {"use_pallas": True},
    "attn_impl": {"attn_impl": "naive"},
    "attn_q_chunk": {"attn_q_chunk": 512},
    "attn_kv_chunk": {"attn_kv_chunk": 512},
    "unroll": {"unroll": True},
    "residual_spec": {"residual_spec": (("data",), None)},
    # schedule-relevant: the serving lane resolves inside schedule() (the
    # ServingTrace rides the arrival trace), so fleets key distinct traces
    "serving": {"serving": FleetConfig(replicas=1)},
}


def test_schedule_cached_field_audit():
    """Every RunConfig field must be triaged: replay-only fields (and ONLY
    those) canonicalize out of the schedule-cache key.  Adding a field
    without classifying it — here and, if replay-only, in
    ``trace._REPLAY_ONLY_FIELDS`` — fails the coverage assert."""
    names = {f.name for f in dataclasses.fields(RunConfig)}
    assert names == set(_FIELD_FLIPS), (
        "new RunConfig field(s) need a flip entry + schedule/replay triage: "
        f"{names ^ set(_FIELD_FLIPS)}")
    assert set(_REPLAY_ONLY_FIELDS) <= names

    for name, flip in _FIELD_FLIPS.items():
        companions = {k: v for k, v in flip.items() if k != name}
        base = RunConfig(**companions)
        flipped = RunConfig(**flip)
        assert getattr(flipped, name) != getattr(base, name), name
        same_key = _schedule_key(flipped) == _schedule_key(base)
        assert same_key == (name in _REPLAY_ONLY_FIELDS), (
            f"{name}: schedule-cache key {'ignores' if same_key else 'keys'}"
            f" this field, but _REPLAY_ONLY_FIELDS says the opposite")


def test_schedule_cached_shares_and_splits_entries():
    """The regression this audit guards: replay-only flips share ONE cached
    trace; membership/backup (schedule-relevant) key distinct traces."""
    schedule_cached.cache_clear()
    base = _cfg()
    t0 = schedule_cached(base, 10)
    assert schedule_cached(base.replace(ring_impl="fused"), 10) is t0
    assert schedule_cached(base.replace(ring_dtype="bf16"), 10) is t0
    assert schedule_cached(base.replace(placement="spmd"), 10) is t0
    churn = MembershipTimeline(((1.0, 0, "leave"),))
    assert schedule_cached(base.replace(membership=churn), 10) is not t0
    hard = RunConfig(protocol="hardsync", n_learners=4, seed=7)
    assert schedule_cached(hard, 10) is not \
        schedule_cached(hard.replace(backup=1), 10)
    assert schedule_cached.cache_info().currsize == 4


# ---------------------------------------------------------------------------
# sharding-policy PartitionSpecs (satellite 3)
# ---------------------------------------------------------------------------
def _toy_params_shape():
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return {
        "embed": sds(64, 8),                  # (V, M)
        "head": sds(8, 64),                   # (M, V)
        "final_norm": sds(8,),
        "units": {
            "attn": {"w_q": sds(3, 8, 4, 2),  # (U, M, H, dh)
                     "b_q": sds(3, 4, 2)},    # (U, H, dh)
            "mlp": {"w_gate": sds(3, 8, 16),
                    "w_down": sds(3, 16, 8)},
        },
    }


@pytest.mark.skipif(DEV < 4, reason="needs a 2x2 debug mesh")
def test_param_shardings_head_mode_2x2():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_shardings
    mesh = mesh_lib.make_debug_mesh(2, 2)
    sh = param_shardings(_toy_params_shape(), mesh, fsdp=False, mode="head")
    assert sh["embed"].spec == P("model", None)
    assert sh["head"].spec == P(None, "model")
    assert sh["final_norm"].spec == P(None)
    assert sh["units"]["attn"]["w_q"].spec == P(None, None, "model", None)
    assert sh["units"]["attn"]["b_q"].spec == P(None, "model", None)
    assert sh["units"]["mlp"]["w_gate"].spec == P(None, None, "model")
    assert sh["units"]["mlp"]["w_down"].spec == P(None, "model", None)
    # the layer-stack axis (dim 0 of units leaves) is never sharded
    for leaf in jax.tree.leaves(sh["units"]):
        assert leaf.spec[0] is None


@pytest.mark.skipif(DEV < 4, reason="needs a 2x2 debug mesh")
def test_param_shardings_fsdp_2x2():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_shardings
    mesh = mesh_lib.make_debug_mesh(2, 2)
    sh = param_shardings(_toy_params_shape(), mesh, fsdp=True, mode="head")
    # FSDP shards the largest still-replicated dim over the data axis
    assert sh["units"]["attn"]["w_q"].spec == P(None, "data", "model", None)
    # seq mode + fsdp_wide: weights replicated over model get ZeRO-3
    # sharding over (data, model) jointly
    sh = param_shardings(_toy_params_shape(), mesh, fsdp=True, mode="seq",
                         fsdp_wide=True)
    assert sh["units"]["attn"]["w_q"].spec == \
        P(None, ("data", "model"), None, None)
    # embed already uses "model", so it gets plain (non-wide) data-sharding
    assert sh["embed"].spec == P("model", "data")


@pytest.mark.skipif(DEV < 4, reason="needs a 2x2 debug mesh")
def test_batch_spec_for_2x2():
    from repro.configs import get_config
    from repro.launch.sharding import batch_spec_for
    mesh = mesh_lib.make_debug_mesh(2, 2)
    cfg = get_config("qwen2_1_5b")
    bspec, sspec = batch_spec_for(cfg, mesh, "seq", batch=8, seq=64)
    assert bspec == "data" and sspec == "model"
    bspec, sspec = batch_spec_for(cfg, mesh, "head", batch=3, seq=64)
    assert bspec is None and sspec is None


def test_parallelism_mode_thresholds():
    """head/seq selection is a divisibility rule on the model axis; FSDP
    thresholds depend on the selected mode (5e10 head / 5e9 seq)."""
    from repro.configs import get_config
    from repro.launch.sharding import parallelism_mode
    q2 = get_config("qwen2_1_5b")             # 12 heads
    assert parallelism_mode(q2, 16) == "seq"  # 12 % 16 != 0
    assert parallelism_mode(q2, 2) == "head"  # 12 % 2 == 0
    sc = get_config("starcoder2_7b")          # 36 heads
    assert parallelism_mode(sc, 8) == "seq"
    assert parallelism_mode(sc, 4) == "head"


@pytest.mark.skipif(DEV < 8, reason="needs a 1x8 debug mesh")
def test_needs_fsdp_mode_dependent_threshold():
    from repro.configs import get_config
    from repro.launch.sharding import needs_fsdp, parallelism_mode
    mesh = mesh_lib.make_debug_mesh(1, 8)     # model axis = 8
    sc = get_config("starcoder2_7b")          # seq at ms=8: 7B > 5e9
    assert parallelism_mode(sc, 8) == "seq" and needs_fsdp(sc, mesh)
    q3 = get_config("qwen3_14b")              # head at ms=8: 14B < 5e10
    assert parallelism_mode(q3, 8) == "head" and not needs_fsdp(q3, mesh)
    assert needs_fsdp(get_config("llama3_405b"), mesh)   # 405B > 5e10
