"""The campaign layer (DESIGN.md §15): content-addressed spec hashing, the
cell registry/DAG, envelope status + resume + force semantics on the tiny
``smoke`` campaign, the legacy-envelope migration pins, and the validate
staleness gate.

The field audit is the load-bearing test: ``spec_hash`` is a cache key, so
a config field it silently ignores means stale results get served as
CURRENT.  Every field of ``ExperimentSpec`` / ``RunConfig`` /
``FleetConfig`` must appear in the flip tables below; adding a field
without triaging it here fails the coverage assert.
"""

import copy
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.config import RunConfig
from repro.experiments import campaign, registry, validate
from repro.experiments.result import SCHEMA_VERSION
from repro.experiments.spec import ExperimentSpec
from repro.experiments.spec_hash import (canonical_echo, content_hash,
                                         spec_hash, spec_hash_from_echo)
from repro.membership import MembershipTimeline
from repro.serve.fleet import FleetConfig
from repro.serve.publication import PublicationPolicy

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "results")

# populate the registry before any test monkeypatches _CELLS — the lazy
# loader only ever imports the cells package once
registry._load_cells()


# ---------------------------------------------------------------------------
# spec-hash field audit (every field flips the hash)
# ---------------------------------------------------------------------------
# field -> replace() kwargs that change ONLY that field's meaning.  Where a
# flip needs companion fields to pass __post_init__ validation (e.g.
# spmd_learners needs placement="spmd"), the companions are listed under
# "extra": the test compares base+extra against base+extra+flip so the
# audited field is the only difference.
_RUN_FLIPS = {
    "protocol": {"protocol": "softsync"},
    "n_softsync": {"n_softsync": 2, "extra": {"protocol": "softsync",
                                              "n_learners": 4}},
    "n_learners": {"n_learners": 3},
    "minibatch": {"minibatch": 64},
    "base_lr": {"base_lr": 0.25},
    "ref_batch": {"ref_batch": 256},
    "lr_policy": {"lr_policy": "staleness_inverse"},
    "momentum": {"momentum": 0.8},
    "optimizer": {"optimizer": "adagrad"},
    "weight_decay": {"weight_decay": 0.1},
    "warmstart_epochs": {"warmstart_epochs": 1},
    "seed": {"seed": 7},
    "duration_model": {"duration_model": "two_speed"},
    "slow_fraction": {"slow_fraction": 0.5},
    "slow_factor": {"slow_factor": 8.0},
    "pareto_alpha": {"pareto_alpha": 3.0},
    "pareto_scale": {"pareto_scale": 0.25},
    "shards": {"shards": 2},
    "groups": {"groups": 1},
    "shard_pull_jitter": {"shard_pull_jitter": 0.5},
    "ring_dtype": {"ring_dtype": "bf16"},
    "ring_impl": {"ring_impl": "fused"},
    "placement": {"placement": "spmd"},
    "spmd_learners": {"spmd_learners": 1, "extra": {"placement": "spmd"}},
    "membership": {"membership": MembershipTimeline(((1.0, 0, "crash"),))},
    "backup": {"backup": 1, "extra": {"n_learners": 4}},
    "num_microbatches": {"num_microbatches": 2},
    "remat": {"remat": False},
    "fsdp": {"fsdp": True},
    "use_pallas": {"use_pallas": True},
    "attn_impl": {"attn_impl": "naive"},
    "attn_q_chunk": {"attn_q_chunk": 512},
    "attn_kv_chunk": {"attn_kv_chunk": 512},
    "unroll": {"unroll": True},
    "residual_spec": {"residual_spec": ("data", None)},
    "serving": {"serving": FleetConfig()},
}

_FLEET_FLIPS = {
    "replicas": {"replicas": 3},
    "policy": {"policy": PublicationPolicy(kind="on_demand")},
    "request_rate": {"request_rate": 8.0},
    "request_samples": {"request_samples": 64},
    "diurnal_amplitude": {"diurnal_amplitude": 0.5},
    "diurnal_period": {"diurnal_period": 100.0},
    "service_base_s": {"service_base_s": 0.04},
    "service_per_sample_s": {"service_per_sample_s": 1e-3},
    "publish_cost_s": {"publish_cost_s": 0.1},
    "max_requests": {"max_requests": 1000},
    "membership": {"membership": MembershipTimeline(((1.0, 0, "crash"),))},
}

# ExperimentSpec's own fields; "run" is audited by _RUN_FLIPS.
_SPEC_FLIPS = {
    "run": {"run": RunConfig(seed=99)},
    "problem": {"problem": "mlp_teacher"},
    "problem_args": {"problem_args": (("hidden", 8),),
                     "extra": {"problem": "mlp_teacher"}},
    "steps": {"steps": 200},
    "epochs": {"epochs": 2.0, "steps": None,
               "extra": {"problem": "mlp_teacher", "epochs": 1.0,
                         "steps": None}},
    "duration": {"duration": "calibrated:base:300mb"},
    "eval_every": {"eval_every": 10},
    "engine": {"engine": "measure"},
    "tag": {"tag": "flipped"},
}

_BASE_SPEC = ExperimentSpec(run=RunConfig(), steps=100)


def _flip_hashes(flips, apply):
    """(base_hash, flipped_hash) per field via the flip table."""
    out = {}
    for field, flip in flips.items():
        flip = dict(flip)
        extra = flip.pop("extra", {})
        out[field] = (apply(extra), apply({**extra, **flip}))
    return out


def test_every_runconfig_field_flips_spec_hash():
    def apply(kw):
        return spec_hash(_BASE_SPEC.replace(run=RunConfig(**kw)))
    for field, (h0, h1) in _flip_hashes(_RUN_FLIPS, apply).items():
        assert h0 != h1, f"RunConfig.{field} does not reach spec_hash"


def test_every_fleetconfig_field_flips_spec_hash():
    def apply(kw):
        return spec_hash(_BASE_SPEC.replace(
            run=RunConfig(serving=FleetConfig(**kw))))
    for field, (h0, h1) in _flip_hashes(_FLEET_FLIPS, apply).items():
        assert h0 != h1, f"FleetConfig.{field} does not reach spec_hash"


def test_every_spec_field_flips_spec_hash():
    def apply(kw):
        base = {"run": RunConfig(), "steps": 100}
        base.update(kw)
        return spec_hash(ExperimentSpec(**base))
    for field, (h0, h1) in _flip_hashes(_SPEC_FLIPS, apply).items():
        assert h0 != h1, f"ExperimentSpec.{field} does not reach spec_hash"


@pytest.mark.parametrize("cls,table", [
    (RunConfig, _RUN_FLIPS),
    (FleetConfig, _FLEET_FLIPS),
    (ExperimentSpec, _SPEC_FLIPS),
])
def test_flip_tables_cover_every_field(cls, table):
    # a new config field MUST be triaged here: either give it a flip (it
    # feeds the content address) or consciously exclude it with a comment
    # in this test (it is representation only).  Nothing is excluded today.
    fields = {f.name for f in dataclasses.fields(cls)}
    missing = fields - set(table)
    assert not missing, (
        f"untriaged {cls.__name__} fields {sorted(missing)}: add them to "
        f"the flip table in tests/test_campaign.py (or explicitly exclude "
        f"them here) so spec_hash coverage stays total")
    unknown = set(table) - fields
    assert not unknown, f"flip table names unknown fields {sorted(unknown)}"


# ---------------------------------------------------------------------------
# spec-hash invariances (representation must NOT flip the hash)
# ---------------------------------------------------------------------------
def test_hash_invariant_to_dict_ordering():
    echo = _BASE_SPEC.replace(run=RunConfig(protocol="softsync",
                                            n_softsync=2,
                                            n_learners=4)).echo()
    shuffled = {k: echo[k] for k in reversed(list(echo))}
    shuffled["run"] = {k: echo["run"][k] for k in reversed(list(echo["run"]))}
    assert spec_hash_from_echo(echo) == spec_hash_from_echo(shuffled)


def test_hash_invariant_to_json_roundtrip():
    spec = ExperimentSpec(
        run=RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                      serving=FleetConfig(replicas=3)),
        problem="mlp_teacher", epochs=2.0, eval_every=50, tag="rt")
    echo = json.loads(json.dumps(spec.echo(), default=float))
    assert spec_hash(spec) == spec_hash_from_echo(echo)


def test_hash_invariant_to_float_formatting():
    a = ExperimentSpec(run=RunConfig(), problem="mlp_teacher", epochs=6.0)
    echo = a.echo()
    echo["epochs"] = 6          # int vs 6.0: same epoch budget
    assert spec_hash(a) == spec_hash_from_echo(echo)
    echo["run"]["momentum"] = 0.9 + 0.0   # still the default -> pruned
    assert spec_hash(a) == spec_hash_from_echo(echo)


def test_hash_invariant_to_default_materialization():
    # a record written before a field existed (field absent) must hash like
    # one written after (field present at its default)
    spec = ExperimentSpec(run=RunConfig(n_learners=4), steps=50)
    echo = spec.echo()
    trimmed = copy.deepcopy(echo)
    del trimmed["run"]["ref_batch"]       # pretend ref_batch predates echo
    del trimmed["eval_every"]
    assert spec_hash_from_echo(echo) == spec_hash_from_echo(trimmed)


def test_default_serving_fleet_is_not_pruned_to_none():
    # serving=FleetConfig() is a different experiment than serving=None
    # even though every FleetConfig field is at its default
    plain = ExperimentSpec(run=RunConfig(), steps=50)
    served = ExperimentSpec(run=RunConfig(serving=FleetConfig()), steps=50)
    assert spec_hash(plain) != spec_hash(served)
    assert canonical_echo(served.echo())["run"]["serving"] == {}


def test_measure_mode_and_problem_versions_reach_hash():
    measured = ExperimentSpec(run=RunConfig(), steps=100)
    trained = ExperimentSpec(run=RunConfig(), problem="mlp_teacher",
                             steps=100)
    assert spec_hash(measured) != spec_hash(trained)
    assert content_hash({"a": 1}) != content_hash({"a": 2})


# ---------------------------------------------------------------------------
# registry / DAG
# ---------------------------------------------------------------------------
def test_registry_rejects_duplicate_name_and_result(monkeypatch):
    monkeypatch.setattr(registry, "_CELLS", dict(registry._CELLS))
    cell = registry.Cell(name="dup_test", result="dup_test_result",
                         compute=lambda: ([], {}))
    registry.register_cell(cell)
    with pytest.raises(ValueError, match="dup_test"):
        registry.register_cell(registry.Cell(
            name="dup_test", result="other", compute=lambda: ([], {})))
    with pytest.raises(ValueError, match="dup_test_result"):
        registry.register_cell(registry.Cell(
            name="dup_test2", result="dup_test_result",
            compute=lambda: ([], {})))


def test_resolve_order_is_topological_and_detects_cycles(monkeypatch):
    monkeypatch.setattr(registry, "_CELLS", dict(registry._CELLS))
    for name, deps in [("t_a", ()), ("t_b", ("t_a",)), ("t_c", ("t_b",))]:
        registry.register_cell(registry.Cell(
            name=name, result=f"{name}_res", deps=deps,
            compute=lambda: ([], {}), campaigns=("t_camp",)))
    order = registry.resolve_order(["t_c"])
    assert order == ["t_a", "t_b", "t_c"]

    registry._CELLS["t_a"] = dataclasses.replace(
        registry._CELLS["t_a"], deps=("t_c",))
    with pytest.raises(ValueError, match="[Cc]ycle"):
        registry.resolve_order(["t_c"])


def test_paper_campaign_topology():
    cells = registry.cells_in("paper")
    seen = set()
    for cell in cells:
        for dep in cell.deps:
            assert dep in seen, (f"{cell.name} scheduled before its "
                                 f"dependency {dep}")
        seen.add(cell.name)
    # the summary cell consumes four other cells' envelopes; it must close
    # the paper campaign's DAG
    assert cells[-1].name == "table3_4"


def test_cell_hash_changes_with_params_and_version():
    cell = registry.get_cell("fig4")
    assert registry.cell_hash(cell) != registry.cell_hash(
        cell, {"steps": 123})
    bumped = dataclasses.replace(cell, version=cell.version + 1)
    assert registry.cell_hash(cell) != registry.cell_hash(bumped)


# ---------------------------------------------------------------------------
# execute / cache / resume / force on the smoke campaign
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_run(tmp_path_factory):
    """One executed smoke campaign in a temp results dir (module-scoped:
    the execution itself is the expensive part)."""
    rd = str(tmp_path_factory.mktemp("smoke_results"))
    ledger = campaign.run_campaign("smoke", quick=True, results_dir=rd,
                                   out=open(os.devnull, "w"))
    return rd, ledger


def test_smoke_campaign_executes_and_claims_pass(smoke_run):
    rd, ledger = smoke_run
    assert ledger["executed"] == 3 and ledger["cached"] == 0
    assert ledger["failed_claims"] == 0
    for name in ("smoke_grid", "smoke_measure", "smoke_report"):
        assert os.path.exists(os.path.join(rd, f"{name}.json"))


def test_second_pass_is_all_cache_hits(smoke_run):
    rd, _ = smoke_run
    ledger = campaign.run_campaign("smoke", quick=True, results_dir=rd,
                                   out=open(os.devnull, "w"))
    assert ledger["executed"] == 0 and ledger["cached"] == 3
    # cache hits must not re-run anything: the whole pass is file reads
    assert ledger["total_seconds"] < 5.0


def test_force_reexecutes_current_cells(smoke_run):
    rd, _ = smoke_run
    ledger = campaign.run_campaign("smoke", only=("smoke_measure",),
                                   force=True, quick=True, results_dir=rd,
                                   out=open(os.devnull, "w"))
    assert ledger["cells"]["smoke_measure"]["action"] == "executed"


def test_partial_sweep_resumes_reusing_cached_records(smoke_run):
    rd, _ = smoke_run
    cell = registry.get_cell("smoke_grid")
    path = registry.results_path(cell, rd)
    with open(path) as f:
        full = json.load(f)
    assert len(full["records"]) == 4    # 2 LRs x 2 seeds

    # truncate to a strict subset -> PARTIAL -> resume completes the grid
    partial = copy.deepcopy(full)
    partial["records"] = partial["records"][:2]
    partial["campaign"]["partial"] = True
    with open(path, "w") as f:
        json.dump(partial, f, indent=1, default=float)
    status, _ = campaign.cell_status(cell, None, True, rd)
    assert status == "PARTIAL"

    campaign.execute_cell(cell, quick=True, results_dir=rd)
    with open(path) as f:
        resumed = json.load(f)
    assert [r["spec_hash"] for r in resumed["records"]] == \
        [r["spec_hash"] for r in full["records"]]
    # the two surviving records ride through verbatim, not re-executed
    assert resumed["records"][:2] == partial["records"][:2]
    status, _ = campaign.cell_status(cell, None, True, rd)
    assert status == "CURRENT"


def test_stale_on_foreign_records(smoke_run):
    rd, _ = smoke_run
    cell = registry.get_cell("smoke_grid")
    path = registry.results_path(cell, rd)
    with open(path) as f:
        data = json.load(f)
    broken = copy.deepcopy(data)
    broken["records"][0]["spec_hash"] = "0" * 16
    with open(path, "w") as f:
        json.dump(broken, f, indent=1, default=float)
    try:
        status, _ = campaign.cell_status(cell, None, True, rd)
        assert status == "STALE"
    finally:
        with open(path, "w") as f:
            json.dump(data, f, indent=1, default=float)


def test_run_cell_returns_derived(smoke_run):
    rd, _ = smoke_run
    derived = campaign.run_cell("smoke_grid", force=False, quick=True,
                                results_dir=rd)
    assert np.isfinite(derived["mean_test_error"])
    assert derived["claims"]["all_errors_finite"] is True


def test_cli_dry_run_and_status_json(smoke_run, tmp_path):
    rd, _ = smoke_run
    status_json = str(tmp_path / "status.json")
    rc = campaign.main(["smoke", "--dry-run", "--quick",
                        "--results-dir", rd, "--status-json", status_json])
    assert rc == 0
    with open(status_json) as f:
        ledger = json.load(f)
    assert ledger["cached"] == 3 and ledger["executed"] == 0


# ---------------------------------------------------------------------------
# migration pins: checked-in envelopes vs the registry
# ---------------------------------------------------------------------------
_SPEC_CELLS = ("fig4", "fig5", "fig6_7", "table2", "topology", "elastic",
               "serve")


@pytest.mark.parametrize("name", _SPEC_CELLS)
def test_checked_in_records_match_registered_specs(name):
    """The ported cell spec-graphs reproduce the legacy grids EXACTLY: the
    registry's spec hashes equal the migrated records' stamped hashes,
    which were computed from each record's own pre-campaign echo.  This is
    the byte-identity pin for the benchmark -> cell migration."""
    cell = registry.get_cell(name)
    with open(registry.results_path(cell, RESULTS_DIR)) as f:
        env = json.load(f)
    stamped = [r["spec_hash"] for r in env["records"]]
    assert stamped == registry.cell_spec_hashes(cell)
    for rec in env["records"]:
        assert spec_hash_from_echo(rec["spec"]) == rec["spec_hash"]


def test_all_paper_envelopes_current():
    for cell in registry.cells_in("paper"):
        status, detail = campaign.cell_status(cell,
                                              results_dir=RESULTS_DIR)
        assert status == "CURRENT", f"{cell.name}: {status} ({detail})"


def test_envelopes_carry_campaign_stamp():
    for cell in registry.cells_in("paper"):
        data = registry.load_envelope(cell, RESULTS_DIR)
        assert data["schema_version"] == SCHEMA_VERSION
        assert data["cell"] == cell.name
        assert data["campaign"]["cell_hash"] == registry.cell_hash(cell)


# ---------------------------------------------------------------------------
# validate: staleness + --migrate
# ---------------------------------------------------------------------------
def _copy_envelope(tmp_path, name="fig4"):
    cell = registry.get_cell(name)
    src = registry.results_path(cell, RESULTS_DIR)
    dst = os.path.join(str(tmp_path), os.path.basename(src))
    with open(src) as f:
        data = json.load(f)
    with open(dst, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return dst, data


def test_validate_flags_legacy_envelope_and_migrates(tmp_path):
    dst, data = _copy_envelope(tmp_path)
    legacy = copy.deepcopy(data)
    legacy["schema_version"] = 1
    legacy.pop("cell", None)
    legacy.pop("campaign", None)
    for rec in legacy["records"]:
        rec.pop("spec_hash", None)
    with open(dst, "w") as f:
        json.dump(legacy, f, indent=1, default=float)

    rows = validate.staleness_report([str(tmp_path)])
    assert rows[0][1] == "STALE"
    assert validate.main([str(tmp_path), "--strict"]) == 1
    assert validate.main([str(tmp_path)]) == 0      # warn-only without strict

    assert validate.migrate_file(dst) == "migrated"
    with open(dst) as f:
        migrated = json.load(f)
    assert migrated == data                          # round-trips exactly
    assert validate.migrate_file(dst) == "current"   # idempotent
    assert validate.main([str(tmp_path), "--strict"]) == 0


def test_validate_flags_mismatched_record_hash(tmp_path):
    dst, data = _copy_envelope(tmp_path)
    data["records"][0]["spec_hash"] = "f" * 16
    with open(dst, "w") as f:
        json.dump(data, f, indent=1, default=float)
    rows = validate.staleness_report([str(tmp_path)])
    assert rows[0][1] == "STALE"


def test_validate_ignores_unregistered_files(tmp_path):
    with open(tmp_path / "adhoc.json", "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "benchmark": "adhoc",
                   "records": [], "derived": {}, "cell": None,
                   "campaign": None}, f)
    rows = validate.staleness_report([str(tmp_path)])
    assert rows[0][1] == "UNREGISTERED"
    assert validate.main([str(tmp_path), "--strict"]) == 0
