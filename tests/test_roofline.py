"""Roofline machinery: HLO collective parsing, term math, runtime model."""

import numpy as np
import pytest

from repro.launch import roofline as rl
from repro.core import tradeoff as to


HLO_SAMPLE = """
HloModule jit_step, num_partitions=256
 %all-reduce = f32[16,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
 %all-gather-start.1 = (bf16[128,128]{1,0}, bf16[2048,128]{1,0}) all-gather-start(%p), channel_id=2, replica_groups=[1,16]<=[16], dimensions={0}
 %all-gather-done.1 = bf16[2048,128]{1,0} all-gather-done(%all-gather-start.1)
 %reduce-scatter = f32[64]{0} reduce-scatter(%x), channel_id=3, replica_groups=[2,8]<=[16], dimensions={0}, to_apply=%add
 %cp = u32[4,4]{1,0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1}}
 %a2a = bf16[32,32]{1,0} all-to-all(%z), channel_id=5, replica_groups=[4,4]<=[16], dimensions={0}
"""


def test_collective_parse_kinds_and_bytes():
    got = rl.collective_bytes(HLO_SAMPLE)
    # all-reduce: 16*256*4 bytes, group 4 → 2·B·(3/4)
    ar = 16 * 256 * 4
    assert got["all-reduce"] == pytest.approx(2 * ar * 3 / 4)
    # all-gather counted at -done: 2048*128*2 bytes, group 16 → B·15/16
    ag = 2048 * 128 * 2
    assert got["all-gather"] == pytest.approx(ag * 15 / 16)
    # reduce-scatter: result 64*4 bytes, group 8 → B·(8−1)
    assert got["reduce-scatter"] == pytest.approx(64 * 4 * 7)
    # collective-permute: result bytes
    assert got["collective-permute"] == pytest.approx(4 * 4 * 4)
    # all-to-all: B·(g−1)/g with g=4
    assert got["all-to-all"] == pytest.approx(32 * 32 * 2 * 3 / 4)
    assert got["total"] == pytest.approx(sum(
        v for k, v in got.items() if k != "total"))


def test_collective_parse_ignores_start_tuple():
    """-start lines (tuple results) must not double count."""
    only_start = "\n".join(l for l in HLO_SAMPLE.splitlines()
                           if "-done" not in l)
    got = rl.collective_bytes(only_start)
    assert got["all-gather"] == 0.0


def test_roofline_terms_and_dominance():
    r = rl.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    hlo_flops=197e12, hlo_bytes=819e9 * 2,
                    coll_bytes=50e9 * 0.5, model_flops=197e12 * 256 * 0.75)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.75)


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    from repro.config import INPUT_SHAPES
    moe = get_config("llama4-maverick-400b-a17b")
    assert moe.active_param_count() < 0.2 * moe.param_count()
    f_train = rl.model_flops(moe, INPUT_SHAPES["train_4k"])
    assert f_train == pytest.approx(
        6.0 * moe.active_param_count() * 256 * 4096)


# ---------------------------------------------------------------------------
# runtime model (paper Figs. 8/9, Tables 1-2)
# ---------------------------------------------------------------------------
def test_overlap_ordering_matches_table1():
    """Rudra-adv* ≫ Rudra-adv > Rudra-base in communication overlap for the
    adversarial scenario (μ = 4, big model, ~60 learners)."""
    wl = to.WorkloadModel(model_bytes=300e6)
    o_base = to.communication_overlap("base", 4, 60, wl=wl)
    o_adv = to.communication_overlap("adv", 4, 60, wl=wl)
    o_star = to.communication_overlap("adv*", 4, 60, wl=wl)
    assert o_base < o_adv < o_star
    assert o_star > 0.95


def test_speedup_monotone_and_hardsync_worst():
    hw = to.calibrate_to_baseline()
    for mu in (128, 4):
        s_soft = to.speedup_table("base", "softsync", mu, hw=hw)
        assert s_soft[30] > s_soft[10] > s_soft[1] * 0.99
        s_hard = to.speedup_table("base", "hardsync", mu, hw=hw)
        assert s_hard[30] <= s_soft[30]


def test_calibration_matches_paper_baseline():
    hw = to.calibrate_to_baseline(22_392.0)
    t = to.training_time("base", "hardsync", 128, 1, hw)
    # compute terms are scaled exactly; the (tiny, unscaled) λ=1 wire cost
    # leaves a sub-0.1% residual
    assert t == pytest.approx(22_392.0, rel=1e-3)


def test_gemm_efficiency_penalty_small_mu():
    hw = to.HardwareModel()
    t4 = to.compute_time(4, hw) / 4
    t128 = to.compute_time(128, hw) / 128
    assert t4 > 2 * t128   # per-sample cost much worse at μ = 4
