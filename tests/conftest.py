"""Pytest path setup: make `benchmarks` (repo root) importable regardless of
how pytest is invoked.  Deliberately does NOT touch XLA flags — tests must
see the real single CPU device (the 512-device override lives only in
repro.launch.dryrun / subprocess tests)."""

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)
