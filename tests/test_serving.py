"""Serving engine: decode-vs-forward consistency, sliding-window caches,
generation, and per-family state caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, RunConfig
from repro.models import init_caches, init_model, model_forward
from repro.serve.engine import generate, init_serve_state, prefill, serve_step

pytestmark = pytest.mark.slow   # decode parity sweeps: the heavy lane

RUN = RunConfig(attn_impl="chunked", attn_q_chunk=16, attn_kv_chunk=16)


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=96, vocab_size=64)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("cfg", [
    _dense_cfg(),
    _dense_cfg(qk_norm=True, qkv_bias=True),
    ModelConfig(name="r", family="ssm", n_layers=2, d_model=64, n_heads=0,
                n_kv_heads=0, d_ff=96, vocab_size=64,
                block_pattern=("rwkv",), rwkv_head_dim=16),
    ModelConfig(name="z", family="hybrid", n_layers=3, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=96, vocab_size=64,
                block_pattern=("shared_attn", "mamba", "mamba"),
                ssm_state=16, ssm_head_dim=16),
], ids=["dense", "dense-qknorm-bias", "rwkv", "hybrid"])
def test_decode_matches_forward(cfg):
    """Token-by-token decode logits == full-sequence forward logits."""
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = model_forward(cfg, RUN, params, {"tokens": toks})

    state = init_serve_state(cfg, B, S + 4)
    dec_logits, state = prefill(cfg, RUN, params, {"tokens": toks}, state)
    # bf16 accumulation differences; the mamba-heavy hybrid stacks three
    # SSM state updates per unit and lands at 0.0625 on ~0.03% of logits
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), atol=7e-2)


def test_sliding_window_cache_is_ring_buffer():
    cfg = _dense_cfg(sliding_window=8)
    caches = init_caches(cfg, 2, 64)
    # window-limited cache: seq capacity == window, not 64
    k = jax.tree.leaves(caches)[0]
    assert 8 in k.shape


def test_sliding_window_decode_matches_forward():
    cfg = _dense_cfg(sliding_window=8)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = model_forward(cfg, RUN, params, {"tokens": toks})
    state = init_serve_state(cfg, B, S)
    dec_logits, _ = prefill(cfg, RUN, params, {"tokens": toks}, state)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), atol=6e-2)


def test_generate_deterministic_greedy():
    cfg = _dense_cfg()
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((2, 4), jnp.int32)
    out1 = generate(cfg, RUN, params, prompt, 6)
    out2 = generate(cfg, RUN, params, prompt, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)


def test_moe_decode_capacity_path():
    """Decode batches fold into one dispatch group (S=1 < E)."""
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=64,
                      block_pattern=("moe",), n_experts=4, top_k=2)
    params = init_model(cfg, jax.random.PRNGKey(0))
    caches = init_caches(cfg, 3, 16)
    nxt, _ = serve_step(cfg, RUN, params, jnp.zeros((3, 1), jnp.int32),
                        jnp.int32(0), caches)
    assert nxt.shape == (3, 1)
