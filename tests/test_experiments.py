"""The experiment surface (DESIGN.md §5): declarative spec → run() →
RunResult.  Pins the three ISSUE-3 contracts — (a) run(spec) ≡ hand-wired
schedule+replay bit-for-bit, (b) vmapped batch replay ≡ sequential replay
across a protocol × seed grid, (c) RunResult JSON round-trip — plus the
Sweep grid builder, the problem registry, vectorized staging, RunConfig
.replace validation, the unified duration grammar, and the legacy-engine
rejection paths (the deprecated core shims are gone)."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig
from repro.core.engine import replay, replay_batch
from repro.core.trace import schedule
from repro.experiments import (ExperimentSpec, RunResult, Sweep,
                               get_problem, register_problem, run,
                               run_sweep, updates_for_epochs,
                               validate_record, validate_results_file)
from repro.experiments.result import envelope


# ---------------------------------------------------------------------------
# a tiny custom problem: linear regression (registered once per session)
# ---------------------------------------------------------------------------
class _LinRegProblem:
    """Minimal problem-protocol example: no vectorized staging hook, so the
    driver's per-slot fallback path gets exercised."""

    def __init__(self, n_features=6, n_out=3):
        key = jax.random.PRNGKey(0)
        self.w_true = jax.random.normal(key, (n_features, n_out))
        self.x = np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (64, n_features)))
        self.y = np.asarray(self.x @ self.w_true)
        self.init = jnp.zeros((n_features, n_out))
        self.dataset_size = 64
        self._grad = jax.jit(jax.grad(
            lambda p, b: jnp.mean((b[0] @ p - b[1]) ** 2)))

    def grad_fn(self, p, batch):
        return self._grad(p, batch)

    def batch_fn_for(self, mu, seed=0):
        def fn(learner, step):
            rng = np.random.default_rng(seed * 77 + learner * 9973 + step)
            idx = rng.integers(0, 64, size=mu)
            return self.x[idx], self.y[idx]
        return fn

    def eval_fn(self, p):
        return {"mse": float(np.mean((self.x @ np.asarray(p)
                                      - self.y) ** 2))}


register_problem("linreg_test", _LinRegProblem)


def _spec(**kw):
    base = dict(
        run=RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                      minibatch=8, base_lr=0.2,
                      lr_policy="staleness_inverse", optimizer="momentum",
                      seed=3),
        problem="mlp_teacher", steps=40)
    base.update(kw)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# (a) run(spec) ≡ hand-wired schedule + replay, bit-for-bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
def test_run_equals_handwired_pipeline_bitwise(optimizer):
    spec = _spec(run=_spec().run.replace(optimizer=optimizer))
    res = run(spec)
    prob = get_problem("mlp_teacher")
    trace = schedule(spec.run, spec.steps)
    sim = replay(trace, spec.run, grad_fn=prob.grad_fn,
                 init_params=prob.init,
                 batch_fn=prob.batch_fn_for(spec.run.minibatch))
    for k in res.params:
        np.testing.assert_array_equal(np.asarray(res.params[k]),
                                      np.asarray(sim.params[k]))
    assert res.metrics["test_error"] == prob.eval_fn(sim.params)["test_error"]
    assert res.runtime["simulated_time"] == trace.simulated_time
    assert res.staleness["ring_buffer_K"] == trace.max_staleness + 1


# ---------------------------------------------------------------------------
# (b) vmapped batch replay ≡ sequential replay over a protocol × seed grid
# ---------------------------------------------------------------------------
def test_batched_sweep_equals_sequential_protocol_seed_grid():
    sweep = Sweep.over(_spec(eval_every=20), cases=[
        {"protocol": "softsync", "n_softsync": 2,
         "lr_policy": "staleness_inverse"},
        {"protocol": "async", "lr_policy": "per_gradient"},
        {"protocol": "hardsync", "lr_policy": "sqrt_scale"},
    ], seed=[0, 1, 2])
    batched = run_sweep(sweep)
    sequential = run_sweep(sweep, batch=False)
    assert len(batched) == len(sequential) == 9
    for b, s in zip(batched, sequential):
        assert b.tag == s.tag
        for k in b.params:
            np.testing.assert_allclose(np.asarray(b.params[k]),
                                       np.asarray(s.params[k]),
                                       rtol=0, atol=2e-6)
        assert b.metrics["test_error"] == pytest.approx(
            s.metrics["test_error"], abs=1e-6)
        assert [r["update"] for r in b.curve] == \
            [r["update"] for r in s.curve]
        for rb, rs in zip(b.curve, s.curve):
            assert rb["time"] == pytest.approx(rs["time"])
            assert rb["test_error"] == pytest.approx(rs["test_error"],
                                                     abs=1e-6)
        assert b.staleness == s.staleness


def test_batched_sweep_custom_problem_per_slot_fallback():
    """No stage_minibatches on the problem ⇒ per-slot staging, still one
    vmapped program, still equivalent."""
    sweep = Sweep.over(_spec(problem="linreg_test",
                             run=_spec().run.replace(base_lr=0.05)),
                       seed=[0, 1], base_lr=[0.02, 0.05])
    batched = run_sweep(sweep)
    sequential = run_sweep(sweep, batch=False)
    for b, s in zip(batched, sequential):
        np.testing.assert_allclose(np.asarray(b.params),
                                   np.asarray(s.params), rtol=0, atol=2e-6)
        assert b.metrics["mse"] == pytest.approx(s.metrics["mse"],
                                                 rel=1e-6)
    # it learns, too
    assert batched[-1].metrics["mse"] < 0.5 * float(
        np.mean(get_problem("linreg_test").y ** 2))


def test_replay_batch_rejects_incompatible_members():
    prob = get_problem("mlp_teacher")
    r1 = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                   minibatch=8, optimizer="momentum", seed=0)
    r2 = r1.replace(n_softsync=8)                       # different c
    t1, t2 = schedule(r1, 20), schedule(r2, 20)
    kw = dict(grad_fn=prob.grad_fn, init_params=prob.init,
              batch_fns=[prob.batch_fn_for(8)] * 2)
    with pytest.raises(ValueError, match="share trace shape"):
        replay_batch([t1, t2], [r1, r2], **kw)
    r3 = r1.replace(optimizer="adamw")
    with pytest.raises(ValueError, match="optimizer spec|flat lane"):
        replay_batch([t1, schedule(r3, 20)], [r1, r3], **kw)
    with pytest.raises(ValueError, match="exactly one"):
        replay_batch([t1], [r1], grad_fn=prob.grad_fn,
                     init_params=prob.init)


def test_adamw_sweep_falls_back_to_sequential():
    sweep = Sweep.over(_spec(run=_spec().run.replace(optimizer="adamw",
                                                     base_lr=0.01),
                             steps=15), seed=[0, 1])
    results = run_sweep(sweep)                # must not raise
    assert all(np.isfinite(r.metrics["test_error"]) for r in results)


# ---------------------------------------------------------------------------
# (c) RunResult JSON round-trip + schema validation
# ---------------------------------------------------------------------------
def test_runresult_json_roundtrip():
    res = run(_spec(steps=10, eval_every=5))
    rec = res.record()
    validate_record(rec)
    again = RunResult.from_json(res.to_json())
    assert again.record() == rec
    assert json.loads(res.to_json()) == rec        # record is pure JSON
    assert again.spec["run"]["protocol"] == "softsync"
    assert again.spec["steps"] == 10


def test_results_file_envelope_validation(tmp_path):
    res = run(_spec(steps=5))
    good = tmp_path / "good.json"
    good.write_text(json.dumps(envelope("t", [res], {"claim": True})))
    assert validate_results_file(str(good)) == 1

    bad = tmp_path / "bad.json"
    rec = res.record()
    del rec["staleness"]
    bad.write_text(json.dumps(envelope("t", [rec])))
    with pytest.raises(ValueError, match="missing keys"):
        validate_results_file(str(bad))
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps({"some": "freeform"}))
    with pytest.raises(ValueError, match="envelope"):
        validate_results_file(str(legacy))


def test_shipped_results_files_validate():
    results_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "results")
    files = [f for f in os.listdir(results_dir) if f.endswith(".json")]
    assert files, "no results files shipped"
    for f in files:
        validate_results_file(os.path.join(results_dir, f))


# ---------------------------------------------------------------------------
# measure mode + spec semantics
# ---------------------------------------------------------------------------
def test_measure_mode_matches_schedule():
    cfg = RunConfig(protocol="softsync", n_softsync=4, n_learners=16,
                    minibatch=16, seed=5)
    res = run(ExperimentSpec(run=cfg, steps=300))
    tr = schedule(cfg, 300)
    log = tr.clock_log()
    assert res.metrics == {} and res.curve == []
    assert res.staleness["mean"] == log.mean_staleness()
    assert res.staleness["ring_buffer_K"] == tr.max_staleness + 1
    assert res.runtime["simulated_time"] == tr.simulated_time
    assert res.runtime["minibatches"] == tr.minibatches
    validate_record(res.record())


def test_spec_validation():
    with pytest.raises(ValueError, match="exactly one of steps"):
        ExperimentSpec(run=RunConfig(), problem="mlp_teacher")
    with pytest.raises(ValueError, match="exactly one of steps"):
        ExperimentSpec(run=RunConfig(), problem="mlp_teacher", steps=5,
                       epochs=1)
    with pytest.raises(ValueError, match="engine"):
        ExperimentSpec(run=RunConfig(), steps=5, engine="warp")
    with pytest.raises(ValueError, match="measure mode needs explicit"):
        ExperimentSpec(run=RunConfig(), epochs=2)
    with pytest.raises(ValueError, match="duration"):
        ExperimentSpec(run=RunConfig(), steps=5, duration="calibrated:tpu")
    with pytest.raises(KeyError, match="unknown problem"):
        ExperimentSpec(run=RunConfig(), problem="nope",
                       steps=5).resolve_problem()


def test_epochs_resolution_matches_updates_for_epochs():
    spec = _spec(steps=None, epochs=2.0)
    prob = get_problem("mlp_teacher")
    want = updates_for_epochs(2.0, spec.run.minibatch,
                              spec.run.gradients_per_update,
                              prob.dataset_size)
    assert spec.resolved_steps() == want


# ---------------------------------------------------------------------------
# Sweep grid builder
# ---------------------------------------------------------------------------
def test_sweep_grid_product_order_and_tags():
    sweep = Sweep.over(_spec(), protocol=["softsync", "async"],
                       seed=[0, 1])
    specs = sweep.specs()
    assert len(sweep) == len(specs) == 4
    assert [s.tag for s in specs] == [
        "protocol=softsync/seed=0", "protocol=softsync/seed=1",
        "protocol=async/seed=0", "protocol=async/seed=1"]
    assert specs[2].run.protocol == "async" and specs[2].run.seed == 0


def test_sweep_axes_split_run_and_spec_fields():
    sweep = Sweep.over(_spec(), steps=[10, 20], minibatch=[4, 8])
    for s in sweep:
        assert s.steps in (10, 20) and s.run.minibatch in (4, 8)
    with pytest.raises(ValueError, match="unknown axis"):
        Sweep.over(_spec(), nonsense=[1])
    with pytest.raises(ValueError, match="empty"):
        Sweep.over(_spec(), seed=[])
    with pytest.raises(ValueError, match="unknown sweep field"):
        Sweep.over(_spec(), cases=[{"wat": 1}]).specs()


def test_sweep_cases_tag_override():
    sweep = Sweep.over(_spec(), cases=[
        {"protocol": "hardsync", "lr_policy": "sqrt_scale",
         "tag": "barrier"}])
    (spec,) = sweep.specs()
    assert spec.tag == "barrier"
    assert spec.run.protocol == "hardsync"
    assert spec.run.lr_policy == "sqrt_scale"


# ---------------------------------------------------------------------------
# satellites: RunConfig.replace, vectorized staging, deprecated shims
# ---------------------------------------------------------------------------
def test_runconfig_replace_reruns_validation():
    cfg = RunConfig(protocol="softsync", n_softsync=4)
    assert cfg.replace(minibatch=4).minibatch == 4
    assert cfg.replace(minibatch=4) == dataclasses.replace(cfg, minibatch=4)
    with pytest.raises(ValueError, match="unknown protocol"):
        cfg.replace(protocol="gossip")
    with pytest.raises(ValueError, match="unknown duration_model"):
        cfg.replace(duration_model="uniform")


def test_stage_minibatches_matches_per_slot_batch_fn():
    prob = get_problem("mlp_teacher")
    cfg = RunConfig(protocol="softsync", n_softsync=2, n_learners=8,
                    minibatch=4, seed=2)
    tr = schedule(cfg, 25)
    x, y = prob.stage_minibatches(tr.learner, tr.mb_index, 4)
    fn = prob.batch_fn_for(4)
    for j in (0, 7, 24):
        for i in range(tr.c):
            xs, ys = fn(int(tr.learner[j, i]), int(tr.mb_index[j, i]))
            np.testing.assert_array_equal(x[j, i], xs)
            np.testing.assert_array_equal(y[j, i], ys)


def test_validate_cli_fails_loudly_on_missing_or_empty(tmp_path):
    """The CI schema gate must exit non-zero when there is nothing to
    validate — an empty or missing results directory is a failure, not a
    silent pass (ISSUE-4 satellite)."""
    from repro.experiments.validate import main, validate_paths
    assert main([str(tmp_path / "does_not_exist")]) == 1
    empty = tmp_path / "results"
    empty.mkdir()
    assert main([str(empty)]) == 1
    with pytest.raises(ValueError):
        validate_paths([str(empty)])
    with pytest.raises(ValueError):
        validate_paths([])
    bad = empty / "broken.json"
    bad.write_text("{not json")
    assert main([str(empty)]) == 1
    good = empty / "ok.json"
    good.write_text(json.dumps(envelope("ok")))
    bad.unlink()
    assert main([str(empty)]) == 0


def test_deprecated_shims_are_gone():
    """The PR-3 shims were deprecated one release and are now removed;
    the experiment surface / driver.execute are the only entry points."""
    import repro.core as core
    import repro.core.engine as engine
    import repro.core.simulator as simulator
    for mod in (core, engine, simulator):
        assert not hasattr(mod, "simulate_compiled")
        assert not hasattr(mod, "simulate_measure")


def test_legacy_engine_rejects_nonflat_configs():
    """The legacy per-arrival loop models the flat static Rudra-base
    server: topology / elastic membership / backup configs must be
    rejected loudly, never silently run on the flat static path."""
    from repro.core import MembershipTimeline, simulate
    prob = get_problem("linreg_test")
    kw = dict(steps=5, grad_fn=prob.grad_fn, init_params=prob.init,
              batch_fn=prob.batch_fn_for(8))
    churn = MembershipTimeline.crash_restart([0], 1.0, 2.0)
    base = dict(protocol="softsync", n_softsync=2, n_learners=4,
                minibatch=8, seed=1)
    with pytest.raises(ValueError, match="core.engine"):
        simulate(RunConfig(**base, membership=churn), **kw)
    with pytest.raises(ValueError, match="core.engine"):
        simulate(RunConfig(protocol="hardsync", n_learners=4, minibatch=8,
                           backup=1), **kw)
    with pytest.raises(ValueError, match="core.engine"):
        simulate(RunConfig(**base, shards=2), **kw)
    # the same configs are rejected at spec level for engine="legacy"
    with pytest.raises(ValueError, match="legacy"):
        ExperimentSpec(run=RunConfig(**base, membership=churn),
                       problem="linreg_test", steps=5, engine="legacy")
    with pytest.raises(ValueError, match="legacy"):
        ExperimentSpec(run=RunConfig(protocol="hardsync", n_learners=4,
                                     minibatch=8, backup=1),
                       problem="linreg_test", steps=5, engine="legacy")
    # measure mode (no gradients) IS the schedule pass — elastic is fine
    from repro.core.simulator import simulate as sim_fn
    res = sim_fn(RunConfig(**base, membership=churn), steps=20)
    assert res.updates == 20


def test_duration_model_grammar_unified():
    """RunConfig.duration_model accepts the same calibrated grammar as
    ExperimentSpec.duration (one shared parser), and rejects junk with a
    message that names both grammars."""
    cfg = RunConfig(duration_model="calibrated:base:300mb")
    assert cfg.duration_model == "calibrated:base:300mb"
    from repro.core.trace import make_duration_sampler
    sampler = make_duration_sampler(cfg)
    d = sampler(np.random.default_rng(0), 4, 0)
    assert d > 0
    with pytest.raises(ValueError, match="calibrated:<arch>"):
        RunConfig(duration_model="calibrated:mega")
    with pytest.raises(ValueError, match="calibrated:<arch>"):
        RunConfig(duration_model="warp_speed")
    with pytest.raises(ValueError, match="calibrated:<arch>"):
        ExperimentSpec(problem="linreg_test", steps=5,
                       duration="calibrated:base:300gb")
    # spec-level calibrated strings still parse (and agree with RunConfig)
    spec = ExperimentSpec(problem="linreg_test", steps=5,
                          duration="calibrated:adv:300mb")
    assert spec.duration_sampler() is not None
