"""Unit tests for the staleness accounting (paper §3.1, Eq. 2) and the
vector-clock log's trace-native (matrix-backed) path, including the
histogram edge cases."""

import numpy as np
import pytest

from repro.core.clock import StalenessRecord, VectorClockLog


# ---------------------------------------------------------------------------
# Eq.-2 accounting
# ---------------------------------------------------------------------------
def test_eq2_average_staleness():
    rec = StalenessRecord(update_index=10, gradient_timestamps=[7, 8, 9])
    assert rec.average_staleness == pytest.approx((10 - 1) - 8.0)
    assert rec.staleness_values == [2, 1, 0]


def test_eq2_fresh_gradient_zero_staleness():
    # a gradient computed on the current weights (ts = i − 1) has σ = 0
    rec = StalenessRecord(update_index=1, gradient_timestamps=[0, 0])
    assert rec.staleness_values == [0, 0]
    assert rec.average_staleness == 0.0


def test_record_and_matrix_paths_agree():
    ts = np.array([[0, 0], [0, 1], [1, 1], [2, 3]])
    by_record = VectorClockLog()
    for j, row in enumerate(ts):
        by_record.record(j + 1, row.tolist())
    by_matrix = VectorClockLog.from_matrix(ts)
    np.testing.assert_array_equal(np.sort(by_record.all_staleness_values()),
                                  np.sort(by_matrix.all_staleness_values()))
    np.testing.assert_allclose(by_record.average_staleness_series(),
                               by_matrix.average_staleness_series())
    assert by_record.mean_staleness() == by_matrix.mean_staleness()
    np.testing.assert_allclose(by_record.staleness_histogram(),
                               by_matrix.staleness_histogram())
    # lazily materialized records carry the Eq.-2 semantics
    assert by_matrix.records[3].update_index == 4
    assert by_matrix.records[3].staleness_values == [1, 0]


def test_record_after_from_matrix_appends():
    log = VectorClockLog.from_matrix(np.array([[0, 0]]))
    log.record(2, [1, 1])
    assert len(log.records) == 2
    assert log.mean_staleness() == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# histogram edge cases
# ---------------------------------------------------------------------------
def test_histogram_empty_log_default():
    h = VectorClockLog().staleness_histogram()
    np.testing.assert_array_equal(h, [0.0])


def test_histogram_empty_log_explicit_bins():
    h = VectorClockLog().staleness_histogram(max_sigma=3)
    np.testing.assert_array_equal(h, [0.0, 0.0, 0.0, 0.0])


def test_histogram_explicit_max_sigma_zero():
    log = VectorClockLog()
    log.record(1, [0, 0])            # two σ = 0 gradients
    log.record(2, [0])               # one σ = 1 gradient
    h = log.staleness_histogram(max_sigma=0)
    # single bin holding P(σ = 0); mass above max_sigma excluded
    np.testing.assert_allclose(h, [2.0 / 3.0])


def test_histogram_default_spans_max_observed():
    log = VectorClockLog.from_matrix(np.array([[0], [0], [0]]))  # σ 0, 1, 2
    h = log.staleness_histogram()
    np.testing.assert_allclose(h, [1 / 3, 1 / 3, 1 / 3])
    assert h.sum() == pytest.approx(1.0)


def test_fraction_exceeding_and_mean_on_empty():
    log = VectorClockLog()
    assert log.fraction_exceeding(0) == 0.0
    assert log.mean_staleness() == 0.0
