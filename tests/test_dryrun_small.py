"""Dry-run machinery on a small faked-device mesh, via subprocess (the
XLA_FLAGS device-count override must NOT leak into the main test process)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # subprocess lowering: the heavy lane

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax
    from repro.config import INPUT_SHAPES, InputShape
    from repro.configs import get_smoke
    from repro.launch.specs import build_lowerable, make_run_config
    from repro.launch import roofline as rl

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke("{arch}")
    shape = InputShape("mini_{kind}", {seq}, {batch}, "{kind}")
    run, eng = make_run_config(cfg, shape, mesh, protocol="softsync",
                               n_softsync=2, num_microbatches=1,
                               attn_q_chunk=32, attn_kv_chunk=32)
    with mesh:
        fn, specs = build_lowerable(cfg, shape, mesh, run, engine=eng)
        compiled = fn.lower(*specs).compile()
        cost = rl.normalize_cost_analysis(compiled.cost_analysis())
        coll = rl.collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
    print(json.dumps({{
        "flops": float(cost.get("flops", 0)),
        "coll_total": coll["total"],
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
    }}))
""")


def _run(arch: str, kind: str, batch: int = 8, seq: int = 64) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch, kind=kind,
                                             batch=batch, seq=seq)],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("qwen2_1_5b", "train"),          # seq-parallel dense
    ("zamba2_7b", "train"),           # head-parallel hybrid
    ("llama4_maverick_400b_a17b", "train"),   # expert-parallel MoE
    ("qwen2_1_5b", "decode"),
    ("rwkv6_7b", "decode"),
])
def test_lower_compile_small_mesh(arch, kind):
    res = _run(arch, kind)
    assert res["flops"] > 0
    assert res["temp_bytes"] >= 0


def test_train_step_induces_gradient_collectives():
    """Data-parallel gradients must produce cross-learner reduction traffic."""
    res = _run("qwen2_1_5b", "train")
    assert res["coll_total"] > 0
